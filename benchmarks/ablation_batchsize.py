"""Paper Tab. 6: accuracy vs calibration batch size. COMQ's op count is
independent of calibration size (only the one-time Gram pass scales)."""
from benchmarks.common import PLAN, calib_tokens, eval_loss, timed, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model()
    rows = [("t6/fp_baseline", 0.0, round(eval_loss(params, cfg), 4))]
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")
    for n_tokens in (64, 128, 256, 512, 1024, 2048):
        calib = calib_tokens(cfg, n_tokens=n_tokens)
        (qp, _), us = timed(quantize_model, params, cfg, PLAN, calib, spec)
        loss = eval_loss(materialize(qp, cfg), cfg)
        rows.append((f"t6/comq_w4_calib{n_tokens}", round(us, 1),
                     round(loss, 4)))
    return rows
