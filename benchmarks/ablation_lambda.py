"""Paper Tab. 10: λ-initialization ablation at 2 bits (λ=0.71 near-optimal
in the paper; λ=1 over-spreads the grid at ultra-low bit-width)."""
from benchmarks.common import PLAN, calib_tokens, eval_loss, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model()
    calib = calib_tokens(cfg)
    rows = [("t10/fp_baseline", 0.0, round(eval_loss(params, cfg), 4))]
    for lam in (0.5, 0.71, 0.9, 1.0):
        spec = QuantSpec(bits=2, granularity="per_channel", lam=lam,
                         sweeps=3, order="greedy")
        qp, _ = quantize_model(params, cfg, PLAN, calib, spec)
        loss = eval_loss(materialize(qp, cfg), cfg)
        rows.append((f"t10/comq_w2_lam{lam}", 0.0, round(loss, 4)))
    return rows
