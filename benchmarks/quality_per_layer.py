"""Paper Tab. 3 analogue: *per-layer* (single shared scale) weight-only
quantization — greedy vs cyclic COMQ (the paper's Ours vs Ours†)."""
from benchmarks.common import PLAN, calib_tokens, eval_loss, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model()
    calib = calib_tokens(cfg)
    rows = [("t3/fp_baseline", 0.0, round(eval_loss(params, cfg), 4))]
    for bits in (4, 3):
        for order in ("greedy", "cyclic"):
            spec = QuantSpec(bits=bits, granularity="per_layer", sweeps=3,
                             order=order)
            qp, _ = quantize_model(params, cfg, PLAN, calib, spec)
            loss = eval_loss(materialize(qp, cfg), cfg)
            tag = "" if order == "greedy" else "_cyclic"
            rows.append((f"t3/comq_perlayer_w{bits}{tag}", 0.0,
                         round(loss, 4)))
    return rows
