"""Benchmark harness — one module per paper table (+ systems tables).

    PYTHONPATH=src python -m benchmarks.run [--only t1,t9] [--json PATH]

Prints ``name,us_per_call,derived`` CSV. Quality tables train a cached
small model on the structured synthetic stream and report held-out eval
loss as the accuracy stand-in (no ImageNet in this container); systems
tables read the dry-run artifacts.

``--json PATH`` additionally writes the rows as a JSON object — the
BENCH_*.json files checked in at the repo root track the perf trajectory
(solver schedule + fused-tap ratios) across PRs.
"""
import argparse
import json
import sys
import traceback

MODULES = [
    ("t1_weight_only", "benchmarks.quality_weight_only"),
    ("t2_full_quant", "benchmarks.quality_full_quant"),
    ("t3_per_layer", "benchmarks.quality_per_layer"),
    ("t4_per_channel", "benchmarks.quality_per_channel"),
    ("t6_batchsize", "benchmarks.ablation_batchsize"),
    ("t7_iterations", "benchmarks.ablation_iterations"),
    ("t8_fig3_order", "benchmarks.ablation_order"),
    ("t9_runtime", "benchmarks.runtime_compare"),
    ("policy", "benchmarks.policy_compare"),
    ("serve", "benchmarks.serve_bench"),
    ("solver_shard", "benchmarks.shard_compare"),
    ("t10_lambda", "benchmarks.ablation_lambda"),
    ("kernels", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated table keys (e.g. t1,t9,roofline)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as JSON (BENCH_*.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    collected = {}
    for key, modname in MODULES:
        if only and not any(key.startswith(o) for o in only):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
                collected[name] = {"us_per_call": us, "derived": derived}
        except Exception as e:
            failures += 1
            print(f"{key},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.json:
        # merge into an existing BENCH_*.json: partial runs (--only) must
        # not clobber rows tracked by other tables/jobs
        merged = {}
        try:
            with open(args.json) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            pass
        merged.update(collected)
        with open(args.json, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
            f.write("\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
