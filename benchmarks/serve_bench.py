"""Serving-runtime benches (BENCH_serve.json rows):

* serve/paged_vs_dense_cache — continuous-batching Runtime (paged KV pool)
  vs the static-slot Engine (dense per-slot max_len cache) on the same
  equal-length greedy batch; `derived` = dense/paged wall ratio. On CPU
  this tracks the gather-fallback + scheduler overhead against the dense
  masked attend, not the HBM savings a TPU sees — the *capacity* win
  (pages scale with live tokens, not slots x max_len) is the point.
* serve/packed_qt_vs_materialized — the Runtime serving a packed QT-leaf
  tree (quant_matmul path, no materialize) vs the same COMQ codes
  materialized to dense; `derived` = materialized/packed wall ratio.
  Also reports the params-tree bytes ratio as serve/packed_qt_bytes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import QuantSpec, materialize, quantize_model, serving_params
from repro.ckpt import tree_bytes
from repro.models import BuildPlan, init_params
from repro.serve import Engine, Runtime, ServeConfig

ARCH = "qwen2-7b"
N_REQ, PROMPT, MAX_NEW = 4, 32, 16


def _runtime_for(params, cfg, plan):
    return Runtime(params, cfg, plan,
                   ServeConfig(max_slots=N_REQ, block_size=16,
                               num_blocks=N_REQ * 4, buckets=(PROMPT,),
                               max_blocks_per_slot=4))


def _time_runtime(params, cfg, plan, prompts, repeats=3):
    rt = _runtime_for(params, cfg, plan)   # reused: jit caches stay warm
    rt.generate([p for p in prompts], max_new_tokens=MAX_NEW)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        rt.generate([p for p in prompts], max_new_tokens=MAX_NEW)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    cfg = get_smoke_config(ARCH)
    plan = BuildPlan(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, plan)
    prompts = np.asarray(
        jax.random.randint(key, (N_REQ, PROMPT), 0, cfg.vocab_size))

    # --- paged runtime vs dense static engine -----------------------------
    t_paged = _time_runtime(params, cfg, plan, prompts)
    eng = Engine(params, cfg, plan, max_len=PROMPT + MAX_NEW)
    eng.generate_batch(prompts, max_new_tokens=MAX_NEW)      # compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        eng.generate_batch(prompts, max_new_tokens=MAX_NEW)
        best = min(best, time.perf_counter() - t0)
    rows.append(("serve/paged_vs_dense_cache", round(t_paged * 1e6, 1),
                 round(best / t_paged, 3)))

    # --- packed QT vs materialized ----------------------------------------
    calib = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec)
    packed = serving_params(qparams, cfg)
    mat = materialize(qparams, cfg)
    t_packed = _time_runtime(packed, cfg, plan, prompts)
    t_mat = _time_runtime(mat, cfg, plan, prompts)
    rows.append(("serve/packed_qt_vs_materialized",
                 round(t_packed * 1e6, 1), round(t_mat / t_packed, 3)))
    rows.append(("serve/packed_qt_bytes", tree_bytes(packed),
                 round(tree_bytes(mat) / tree_bytes(packed), 3)))
    return rows
