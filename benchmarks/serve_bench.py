"""Serving-runtime benches (BENCH_serve.json rows):

* serve/paged_vs_dense_cache — continuous-batching Runtime (paged KV pool)
  vs the static-slot Engine (dense per-slot max_len cache) on the same
  equal-length greedy batch; `derived` = dense/paged wall ratio. On CPU
  this tracks the gather-fallback + scheduler overhead against the dense
  masked attend, not the HBM savings a TPU sees — the *capacity* win
  (pages scale with live tokens, not slots x max_len) is the point.
* serve/obs_overhead — instrumentation cost of a fully live Tracer +
  MetricsRegistry on the decode hot path; `us_per_call` = microbenched
  per-decode-step hook-sequence cost (µs), `derived` = 1 + that cost
  over the measured per-step wall, hard-gated < 1.02 (the DESIGN.md
  §10.3 budget) with a bit-identical-tokens gate on top.
* serve/packed_qt_vs_materialized — the Runtime serving a packed QT-leaf
  tree (quant_matmul path, no materialize) vs the same COMQ codes
  materialized to dense; `derived` = materialized/packed wall ratio.
  Also reports the params-tree bytes ratio as serve/packed_qt_bytes.
* serve/preempt_occupancy_vs_reserved — the same over-subscribed mixed
  workload under admission policy "preempt" (incremental pages +
  preemption-by-page-reclaim) vs "reserve" (PR-4 full-lifetime
  reservation); `derived` = preempt/reserve mean live-token occupancy
  (pages holding real K/V rows / pool size, averaged per decode step) —
  > 1.0 means reclaiming idle reservations keeps more of the pool doing
  useful work. Correctness-gated: both policies must emit exactly the
  solo-run tokens for every request. serve/preempt_itl_p99 reports the
  tail inter-token latency cost of the recompute-based resumes.
* serve/paged_int8_vs_bf16 — the Runtime on int8 KV pages (per-page
  scales, kv_bits=8) vs bf16 pages, same workload; `derived` =
  bf16/int8 wall ratio. serve/paged_int8_vs_bf16_bytes reports the
  pool-bytes ratio (code payload + scale tensors vs bf16 rows),
  hard-gated >= 1.8x. Token identity is gated on the preempt oracle:
  int8 must match its own solo runs exactly under mixed + staggered +
  preempted traffic; 4-bit gates a prefix-agreement drift bound
  (serve/paged_kv4_prefix_agreement).
* roofline/kv_bytes_predicted_vs_measured — the analytic
  bytes-per-decode-token model (roofline/kv_bytes.py) vs the
  HLO-measured decode-step bytes of the compiled runtime; `derived` =
  predicted/measured int8-vs-bf16 ratio-of-ratios, hard-gated within
  [0.75, 1.25] (the ISSUE's 25% accuracy bar).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import QuantSpec, materialize, quantize_model, serving_params
from repro.ckpt import tree_bytes
from repro.models import BuildPlan, init_params
from repro.serve import Engine, Runtime, ServeConfig

ARCH = "qwen2-7b"
N_REQ, PROMPT, MAX_NEW = 4, 32, 16


def _runtime_for(params, cfg, plan, **kw):
    return Runtime(params, cfg, plan,
                   ServeConfig(max_slots=N_REQ, block_size=16,
                               num_blocks=N_REQ * 4, buckets=(PROMPT,),
                               max_blocks_per_slot=4), **kw)


def _time_runtime(params, cfg, plan, prompts, repeats=3):
    rt = _runtime_for(params, cfg, plan)   # reused: jit caches stay warm
    rt.generate([p for p in prompts], max_new_tokens=MAX_NEW)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        rt.generate([p for p in prompts], max_new_tokens=MAX_NEW)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    cfg = get_smoke_config(ARCH)
    plan = BuildPlan(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, plan)
    prompts = np.asarray(
        jax.random.randint(key, (N_REQ, PROMPT), 0, cfg.vocab_size))

    # --- paged runtime vs dense static engine -----------------------------
    t_paged = _time_runtime(params, cfg, plan, prompts)
    eng = Engine(params, cfg, plan, max_len=PROMPT + MAX_NEW)
    eng.generate_batch(prompts, max_new_tokens=MAX_NEW)      # compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        eng.generate_batch(prompts, max_new_tokens=MAX_NEW)
        best = min(best, time.perf_counter() - t0)
    rows.append(("serve/paged_vs_dense_cache", round(t_paged * 1e6, 1),
                 round(best / t_paged, 3)))

    # --- observability overhead (DESIGN.md §10.3 budget) ------------------
    # Two hard gates on a fully instrumented Runtime (live Tracer +
    # MetricsRegistry): (1) it must emit bit-identical tokens to the
    # plain one; (2) its per-decode-step instrumentation cost must stay
    # under 2% of the measured decode-step wall. The cost side is NOT
    # taken by differencing two whole-run walls -- on shared CI boxes
    # per-call jitter (+-50% observed) dwarfs the ~1% true overhead and
    # any such gate flakes. Instead the exact per-step hook sequence
    # (decode_step span, one token_event + counter per live slot, the
    # two pool gauges) is replayed standalone, where it microbenches
    # stably at the microsecond level, and divided by the median
    # per-step wall of the real traced run. An events-per-step
    # cross-check pins the replayed sequence to what Runtime.step
    # actually emits, so a new hook on the hot path can't silently
    # dodge the gate.
    from repro.obs import MetricsRegistry, Tracer
    OBS_MAX_NEW = 48
    obs_cfg = dict(max_slots=N_REQ, block_size=16, num_blocks=N_REQ * 6,
                   buckets=(PROMPT,), max_blocks_per_slot=6)
    rt_plain = Runtime(params, cfg, plan, ServeConfig(**obs_cfg))
    rt_traced = Runtime(params, cfg, plan, ServeConfig(**obs_cfg),
                        tracer=Tracer(run="bench"),
                        metrics=MetricsRegistry(run="bench"))
    toks_plain = rt_plain.generate([p for p in prompts],
                                   max_new_tokens=OBS_MAX_NEW)   # compile
    toks_traced = rt_traced.generate([p for p in prompts],
                                     max_new_tokens=OBS_MAX_NEW)  # compile
    for a, b in zip(toks_plain, toks_traced):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    tr, reg = rt_traced.tracer, rt_traced.metrics
    ev0, st0 = len(tr.events), rt_traced.steps
    walls = []
    for _ in range(6):
        t0 = time.perf_counter()
        rt_traced.generate([p for p in prompts],
                           max_new_tokens=OBS_MAX_NEW)
        walls.append(time.perf_counter() - t0)
    real_steps = rt_traced.steps - st0
    real_ev_per_step = (len(tr.events) - ev0) / real_steps
    wall_per_step = float(np.median(walls)) * 6 / real_steps

    m_tok = reg.counter("serve.tokens_emitted")
    m_free = reg.gauge("serve.pool_free_blocks")
    m_occ = reg.gauge("serve.pool_live_occupancy")
    m_kvb = reg.gauge("serve.pool_kv_bytes")

    def obs_step(i):
        # mirror of Runtime.step()'s per-step instrumentation with all
        # N_REQ slots live (the traced workload's steady state)
        with tr.span("decode_step", device=True, step=i, slots=N_REQ):
            pass
        now_us = time.time() * 1e6
        for s in range(N_REQ):
            tr.token_event(s, i, 42, now_us)
            m_tok.inc()
        m_free.set(8)
        m_occ.set(0.5)
        m_kvb.set(123456)

    ev0 = len(tr.events)
    obs_step(0)
    replay_ev_per_step = len(tr.events) - ev0
    # lifecycle events (submit/admit/first_token/retire) amortize to
    # well under one event per step; anything bigger means the replay
    # no longer mirrors the real hot path
    assert abs(replay_ev_per_step - real_ev_per_step) <= 1.0, (
        f"obs replay drift: step() emits {real_ev_per_step:.2f} "
        f"events/step, replay emits {replay_ev_per_step}")
    REPS = 20000
    t0 = time.perf_counter()
    for i in range(REPS):
        obs_step(i)
    obs_s_per_step = (time.perf_counter() - t0) / REPS
    ratio = 1.0 + obs_s_per_step / wall_per_step
    assert ratio < 1.02, (f"obs overhead {ratio:.3f} breaches the 2% "
                          f"tokens/s budget ({obs_s_per_step * 1e6:.1f}us "
                          f"per {wall_per_step * 1e6:.0f}us step)")
    rows.append(("serve/obs_overhead", round(obs_s_per_step * 1e6, 2),
                 round(ratio, 3)))

    # --- packed QT vs materialized ----------------------------------------
    calib = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec)
    packed = serving_params(qparams, cfg)
    mat = materialize(qparams, cfg)
    t_packed = _time_runtime(packed, cfg, plan, prompts)
    t_mat = _time_runtime(mat, cfg, plan, prompts)
    rows.append(("serve/packed_qt_vs_materialized",
                 round(t_packed * 1e6, 1), round(t_mat / t_packed, 3)))
    rows.append(("serve/packed_qt_bytes", tree_bytes(packed),
                 round(tree_bytes(mat) / tree_bytes(packed), 3)))

    # --- preempt vs reserve occupancy -------------------------------------
    # Short prompts with a long decode tail: each request's lifetime bound
    # is 3 pages (prompt + 16 decode rows @ block 8) but it only *lives*
    # in 1 page for its first ~8 decode steps. "reserve" ties up the idle
    # tail pages at admission (8-page pool -> 2 concurrent lifetimes);
    # "preempt" admits all four on prefill footprint and reclaims pages on
    # demand, trading a couple of recompute-resumes (visible in the
    # preempt_itl_p99 tail) for strictly higher live occupancy.
    P_MAX_NEW = 17
    rs = np.random.RandomState(0)
    mixed = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
             for l in (8, 7, 8, 6)]
    solo_rt = Runtime(params, cfg, plan,
                      ServeConfig(max_slots=1, block_size=8, num_blocks=3,
                                  buckets=(8, 16, 32), max_blocks_per_slot=3))
    solo = [solo_rt.generate([p], max_new_tokens=P_MAX_NEW)[0]
            for p in mixed]
    occ = {}
    for policy in ("preempt", "reserve"):
        rt = Runtime(params, cfg, plan,
                     ServeConfig(max_slots=4, block_size=8, num_blocks=8,
                                 buckets=(8, 16, 32), max_blocks_per_slot=3,
                                 policy=policy))
        reqs = [rt.submit(p, max_new_tokens=P_MAX_NEW) for p in mixed]
        m = rt.run()
        for r, want in zip(reqs, solo):     # correctness gate
            np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
        occ[policy] = m
    rows.append(("serve/preempt_occupancy_vs_reserved",
                 round(occ["preempt"]["mean_live_occupancy"], 4),
                 round(occ["preempt"]["mean_live_occupancy"]
                       / occ["reserve"]["mean_live_occupancy"], 3)))
    rows.append(("serve/preempt_itl_p99",
                 round(occ["preempt"]["itl_p99_s"] * 1e6, 1),
                 round(occ["reserve"]["itl_p99_s"]
                       / max(occ["preempt"]["itl_p99_s"], 1e-9), 3)))

    # --- quantized KV pages: int8 pool vs bf16 pool (DESIGN.md §11) -------
    # Time row reuses the first section's bf16 paged wall; bytes row is the
    # pool-accounting ratio (code payload + per-page scales vs bf16 rows),
    # hard-gated >= 1.8x. Correctness rides the preempt oracle above: the
    # int8 runtime must emit, under the over-subscribed mixed + staggered
    # workload with preemption-by-page-reclaim, exactly the tokens its own
    # solo (one-slot, unpreempted) runs emit — quantization error must be a
    # pure function of the written pages, never of scheduling history.
    # 4-bit pages trade exactness for bytes: the same workload gates a
    # prefix-agreement drift bound instead (rounding at 15 levels shifts
    # near-tie logits a few steps into some decodes).
    from repro.serve.kv_cache import paged_cache_bytes
    plan_q8 = plan.replace(kv_bits=8)
    t_q8 = _time_runtime(params, cfg, plan_q8, prompts)
    rows.append(("serve/paged_int8_vs_bf16", round(t_q8 * 1e6, 1),
                 round(t_paged / t_q8, 3)))
    b_bf16 = paged_cache_bytes(cfg, plan, N_REQ * 4, 16)
    b_q8 = paged_cache_bytes(cfg, plan_q8, N_REQ * 4, 16)
    bytes_ratio = b_bf16 / b_q8
    assert bytes_ratio >= 1.8, (
        f"int8 pool bytes reduction {bytes_ratio:.3f}x < 1.8x")
    rows.append(("serve/paged_int8_vs_bf16_bytes", b_q8,
                 round(bytes_ratio, 3)))

    for kv_bits, exact in ((8, True), (4, False)):
        plan_kv = plan.replace(kv_bits=kv_bits)
        solo_kv_rt = Runtime(params, cfg, plan_kv,
                             ServeConfig(max_slots=1, block_size=8,
                                         num_blocks=3, buckets=(8, 16, 32),
                                         max_blocks_per_slot=3))
        solo_kv = [solo_kv_rt.generate([p], max_new_tokens=P_MAX_NEW)[0]
                   for p in mixed]
        rt = Runtime(params, cfg, plan_kv,
                     ServeConfig(max_slots=4, block_size=8, num_blocks=8,
                                 buckets=(8, 16, 32), max_blocks_per_slot=3,
                                 policy="preempt"))
        reqs = [rt.submit(p, max_new_tokens=P_MAX_NEW) for p in mixed]
        rt.run()
        if exact:
            for r, want in zip(reqs, solo_kv):
                np.testing.assert_array_equal(np.asarray(r.out_tokens),
                                              np.asarray(want))
        else:
            agree = []
            for r, want in zip(reqs, solo_kv):
                got, want = np.asarray(r.out_tokens), np.asarray(want)
                n = min(len(got), len(want))
                same = got[:n] == want[:n]
                pfx = int(np.argmin(same)) if not same.all() else n
                agree.append(pfx / P_MAX_NEW)
            mean_agree = float(np.mean(agree))
            assert mean_agree >= 0.5, (
                f"4-bit pages drifted past the tolerance: mean prefix "
                f"agreement {mean_agree:.3f} < 0.5 ({agree})")
            rows.append(("serve/paged_kv4_prefix_agreement",
                         round(mean_agree, 4), round(min(agree), 3)))

    # --- roofline: predicted vs measured decode-step bytes ratio ----------
    # The analytic byte model (roofline/kv_bytes.py) must predict the
    # HLO-measured int8-vs-bf16 decode-step bytes ratio within 25%. The
    # f32-cache config is the one the write-once cost model tracks: with
    # bf16 pages the CPU backend inserts f32-upcast copies that push the
    # measured ratio above what any storage-width model can produce.
    from repro.roofline.kv_bytes import predicted_vs_measured_ratio
    plan_rf = BuildPlan(remat=False, cache_dtype=jnp.float32)
    rf_sc = ServeConfig(max_slots=N_REQ, block_size=16, num_blocks=64,
                        buckets=(PROMPT,), max_blocks_per_slot=16)
    rf = predicted_vs_measured_ratio(
        params, cfg, plan_rf, plan_rf.replace(kv_bits=8),
        max_slots=N_REQ, block_size=16, max_blocks_per_slot=16,
        num_blocks=64,
        make_runtime=lambda p: Runtime(params, cfg, p, rf_sc))
    rr = rf["ratio_of_ratios"]
    assert 0.75 <= rr <= 1.25, (
        f"roofline kv-bytes model off by >25%: predicted "
        f"{rf['predicted']:.3f}x vs measured {rf['measured']:.3f}x")
    rows.append(("roofline/kv_bytes_predicted_vs_measured",
                 round(rf["measured"], 3), round(rr, 3)))
    return rows
