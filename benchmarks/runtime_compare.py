"""Paper Tab. 9: solver runtime — COMQ (backprop-free, no Hessian inverse)
vs GPTQ (needs H⁻¹) vs RTN, on fixed-size layers. Also the blocked/panel
schedule vs row-at-a-time (the TPU-shaped variant, DESIGN.md §3.2)."""
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import (QuantSpec, comq_quantize_blocked, comq_quantize_h,
                        gptq_quantize, gram, rtn_quantize)


def run():
    rows = []
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")
    spec_shared = QuantSpec(bits=4, granularity="per_channel", lam=0.9,
                            sweeps=3, order="greedy_shared")
    for (m, n) in ((256, 256), (512, 512), (1024, 1024)):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m))
        x = jax.random.normal(k1, (2 * m, m))
        w = jax.random.normal(k2, (m, n)) * 0.05
        h = gram(x)
        solvers = {
            "rtn": jax.jit(lambda hh, ww: rtn_quantize(ww, spec, h=hh).q),
            "gptq": jax.jit(lambda hh, ww: gptq_quantize(hh, ww, spec).q),
            "comq": jax.jit(lambda hh, ww: comq_quantize_h(hh, ww, spec).q),
            "comq_blocked": jax.jit(
                lambda hh, ww: comq_quantize_blocked(hh, ww, spec_shared,
                                                     block=128).q),
        }
        for name, fn in solvers.items():
            _, us = timed(fn, h, w, repeats=2)
            rows.append((f"t9/{name}_{m}x{n}", round(us, 1), m * n))
    return rows
