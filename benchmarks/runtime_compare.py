"""Paper Tab. 9: solver runtime — COMQ (backprop-free, no Hessian inverse)
vs GPTQ (needs H⁻¹) vs RTN, on fixed-size layers. Also the solver-schedule
A/B rows tracked in BENCH_*.json from PR 1 on (DESIGN.md §3.3):

* solver/blocked_trailing_vs_refresh — per-sweep wall time of the
  trailing-update blocked schedule; `derived` = refresh/trailing speedup.
* solver/fused_shared_tap_vs_separate — one fused [wq|wk|wv] solve (shared
  Gram) vs three per-leaf solves with per-leaf Grams; `derived` = speedup.

And the pipeline-schedule rows from PR 2 on (DESIGN.md §4.1/§4.2),
committed as BENCH_pipeline.json:

* pipeline/staged_vs_legacy — end-to-end quantize_model wall time on the
  staged one-forward-per-layer schedule; `derived` = legacy/staged speedup
  (the two-forward schedule pays 2× calibration forward FLOPs).
* pipeline/{staged,legacy}_wall_per_layer — per-layer wall time (µs).
* pipeline/journaled_vs_plain — quantize_model with a crash-safe
  QuantJournal (one host sync + spill + fsync'd record per tap group)
  vs the sync-free plain walk; `derived` = journaled/plain wall ratio,
  the durability tax of DESIGN.md §8.1.
* pipeline/sharded_gram_vs_single — shard_map + single-psum Gram vs the
  single-device Gram; `derived` = single/sharded. On one device this
  tracks the pure shard_map dispatch overhead the data-parallel path
  pays; with real shards the local XᵀX is 1/|data| of the FLOPs.

And the column-sharded solve rows from PR 3 on (DESIGN.md §4.3),
produced by `colsharded_rows()` on a *forced-8-device* host platform
(subprocess, (2, 4) mesh — the CI multidevice job writes them to
BENCH_solver.json via benchmarks/shard_compare.py):

* solver/colsharded_vs_replicated — wall time of the column-sharded
  trailing-update solve (W's output columns over a 4-way "model" axis,
  H replicated, zero collectives) vs the replicated solve; `derived` =
  replicated/sharded. On forced host devices all shards share the same
  cores, so this tracks shard_map dispatch + per-shard-width overhead,
  not real-accelerator speedup.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import (QuantSpec, comq_quantize_blocked, comq_quantize_h,
                        gptq_quantize, gram, quantize_model, rtn_quantize)


def run():
    rows = []
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")
    spec_shared = QuantSpec(bits=4, granularity="per_channel", lam=0.9,
                            sweeps=3, order="greedy_shared")
    for (m, n) in ((256, 256), (512, 512), (1024, 1024)):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m))
        x = jax.random.normal(k1, (2 * m, m))
        w = jax.random.normal(k2, (m, n)) * 0.05
        h = gram(x)
        solvers = {
            "rtn": jax.jit(lambda hh, ww: rtn_quantize(ww, spec, h=hh).q),
            "gptq": jax.jit(lambda hh, ww: gptq_quantize(hh, ww, spec).q),
            "comq": lambda hh, ww: comq_quantize_h(hh, ww, spec).q,
            "comq_blocked": lambda hh, ww: comq_quantize_blocked(
                hh, ww, spec_shared, block=128).q,
        }
        for name, fn in solvers.items():
            _, us = timed(fn, h, w, repeats=2)
            rows.append((f"t9/{name}_{m}x{n}", round(us, 1), m * n))

    # --- schedule A/B: trailing-update vs legacy per-panel refresh --------
    for (m, n) in ((512, 512), (1024, 1024)):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m))
        h = gram(jax.random.normal(k1, (2 * m, m)))
        w = jax.random.normal(k2, (m, n)) * 0.05
        _, us_t = timed(lambda: comq_quantize_blocked(
            h, w, spec_shared, block=128).q, repeats=3)
        _, us_r = timed(lambda: comq_quantize_blocked(
            h, w, spec_shared, block=128, schedule="refresh").q, repeats=3)
        per_sweep = us_t / spec_shared.sweeps
        rows.append((f"solver/blocked_trailing_per_sweep_{m}x{n}",
                     round(per_sweep, 1), round(us_t, 1)))
        rows.append((f"solver/blocked_trailing_vs_refresh_{m}x{n}",
                     round(us_t, 1), round(us_r / us_t, 3)))

    # --- fused shared-tap solve vs per-leaf solves ------------------------
    m, n = 512, 512
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    tap = jax.random.normal(k1, (4, 256, m))
    wqkv = [jax.random.normal(jax.random.fold_in(k2, i), (m, n)) * 0.05
            for i in range(3)]
    wcat = jnp.concatenate(wqkv, axis=1)

    def fused():
        h = gram(tap.reshape(-1, m))
        return comq_quantize_h(h, wcat, spec).q

    def separate():
        qs = []
        for wl in wqkv:                     # per-leaf Gram + solve (pre-PR1)
            h = gram(tap.reshape(-1, m))
            qs.append(comq_quantize_h(h, wl, spec).q)
        return qs[-1]

    _, us_f = timed(fused, repeats=2)
    _, us_s = timed(separate, repeats=2)
    rows.append((f"solver/fused_shared_tap_vs_separate_{m}x3x{n}",
                 round(us_f, 1), round(us_s / us_f, 3)))

    # --- pipeline schedule A/B: staged one-forward vs legacy two-forward -
    from repro.configs import get_smoke_config
    from repro.models import BuildPlan, init_params
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg, plan)
    # calibration-realistic token count: forward FLOPs dominate, which is
    # exactly the regime the staged schedule halves
    tokens = jax.random.randint(jax.random.PRNGKey(1), (16, 512), 0,
                                cfg.vocab_size)
    qspec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                      order="greedy")

    def run_pipe(mode):
        return quantize_model(params, cfg, plan, tokens, qspec,
                              propagation=mode)[1]

    _, us_staged = timed(run_pipe, "staged", repeats=2)
    _, us_legacy = timed(run_pipe, "legacy", repeats=2)
    rows.append(("pipeline/staged_wall_per_layer",
                 round(us_staged / cfg.n_layers, 1), round(us_staged, 1)))
    rows.append(("pipeline/legacy_wall_per_layer",
                 round(us_legacy / cfg.n_layers, 1), round(us_legacy, 1)))
    rows.append(("pipeline/staged_vs_legacy", round(us_staged, 1),
                 round(us_legacy / us_staged, 3)))

    # --- journaled (crash-safe) vs plain walk (DESIGN.md §8.1) ------------
    # the journal forces one host sync + spill + fsync'd record per tap
    # group where the plain walk stays sync-free; this row tracks that
    # durability tax (derived = journaled/plain wall ratio)
    import shutil
    import tempfile
    jtok = jax.random.randint(jax.random.PRNGKey(3), (4, 128), 0,
                              cfg.vocab_size)

    def run_plain():
        return quantize_model(params, cfg, plan, jtok, qspec)[1]

    def run_journaled():
        jd = tempfile.mkdtemp(prefix="bench_qjournal_")
        try:
            return quantize_model(params, cfg, plan, jtok, qspec,
                                  journal=jd)[1]
        finally:
            shutil.rmtree(jd, ignore_errors=True)

    _, us_plain = timed(run_plain, repeats=2)
    _, us_journaled = timed(run_journaled, repeats=2)
    rows.append(("pipeline/journaled_vs_plain", round(us_journaled, 1),
                 round(us_journaled / us_plain, 3)))

    # --- per-leaf timing semantics (DESIGN.md §3/§10) ---------------------
    # LayerReport.dispatch_seconds is host dispatch time of the sync-free
    # walk; wall_seconds (tracer-enabled runs only) blocks on the solved
    # codes per tap group, so summed wall is the real solve cost. This row
    # tracks how far the two drift apart (derived = wall/dispatch ratio —
    # large on async backends, ~1 on CPU XLA which computes eagerly-ish).
    from repro.obs import Tracer
    rep_traced = quantize_model(params, cfg, plan, jtok, qspec,
                                tracer=Tracer(run="bench"))[1]
    disp = sum(r.dispatch_seconds for r in rep_traced.layers)
    wall = sum(r.wall_seconds for r in rep_traced.layers)
    rows.append(("pipeline/report_wall_vs_dispatch", round(wall * 1e6, 1),
                 round(wall / max(disp, 1e-9), 3)))

    # --- sharded Gram (shard_map + one psum) vs single-device Gram --------
    # both sides jitted so the row isolates the shard_map/psum overhead,
    # not jit-vs-eager dispatch
    from repro.core.calibrate import gram_from_tap
    from repro.dist import data_mesh, sharded_gram
    mesh = data_mesh()
    tap = jax.random.normal(jax.random.PRNGKey(2), (16, 512, 256))
    single_j = jax.jit(gram_from_tap)
    _, us_sh = timed(lambda: sharded_gram(mesh, tap), repeats=3)
    _, us_sg = timed(lambda: single_j(tap), repeats=3)
    rows.append(("pipeline/sharded_gram_vs_single", round(us_sh, 1),
                 round(us_sg / us_sh, 3)))
    return rows


_COLSHARD_BENCH = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp
from repro.core import QuantSpec, comq_quantize_blocked, gram
from repro.dist import calib_mesh, sharded_solve

mesh = calib_mesh(model=4)                      # (2, 4)
spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                 order="cyclic")
out = {}
for (m, n) in ((256, 768), (512, 1536)):        # fused [wq|wk|wv] widths
    k1, k2 = jax.random.split(jax.random.PRNGKey(m))
    h = gram(jax.random.normal(k1, (2 * m, m)))
    w = jax.random.normal(k2, (m, n)) * 0.05


    def rep():
        return comq_quantize_blocked(h, w, spec, block=128).q


    def sh():
        return sharded_solve(mesh, h, w, spec, "comq_blocked", block=128)[0]


    for f in (rep, sh):                          # compile warmup
        jax.block_until_ready(f())
    times = {}
    for name, f in (("rep", rep), ("sh", sh)):
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(f())
        times[name] = (time.perf_counter() - t0) / 3 * 1e6
    out[f"{m}x{n}"] = times
print("BENCHJSON " + json.dumps(out))
"""


def colsharded_rows():
    """solver/colsharded_vs_replicated rows, measured on a forced-8-device
    (2, 4) mesh in a subprocess (conftest forbids in-process XLA_FLAGS; the
    parent may be single-device). Emits ERROR-free empty rows on failure so
    a bench run never hard-fails on an exotic host."""
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run([sys.executable, "-c", _COLSHARD_BENCH],
                              env=env, capture_output=True, text=True,
                              timeout=600)
        line = next(l for l in proc.stdout.splitlines()
                    if l.startswith("BENCHJSON "))
        data = json.loads(line[len("BENCHJSON "):])
    except Exception as e:                         # noqa: BLE001
        print(f"# colsharded bench skipped: {type(e).__name__}: {e}",
              flush=True)
        return []
    rows = []
    for shape, t in sorted(data.items()):
        rows.append((f"solver/colsharded_vs_replicated_{shape}",
                     round(t["sh"], 1), round(t["rep"] / t["sh"], 3)))
    return rows
