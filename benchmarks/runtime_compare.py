"""Paper Tab. 9: solver runtime — COMQ (backprop-free, no Hessian inverse)
vs GPTQ (needs H⁻¹) vs RTN, on fixed-size layers. Also the solver-schedule
A/B rows tracked in BENCH_*.json from PR 1 on (DESIGN.md §3.3):

* solver/blocked_trailing_vs_refresh — per-sweep wall time of the
  trailing-update blocked schedule; `derived` = refresh/trailing speedup.
* solver/fused_shared_tap_vs_separate — one fused [wq|wk|wv] solve (shared
  Gram) vs three per-leaf solves with per-leaf Grams; `derived` = speedup.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import (QuantSpec, comq_quantize_blocked, comq_quantize_h,
                        gptq_quantize, gram, rtn_quantize)


def run():
    rows = []
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")
    spec_shared = QuantSpec(bits=4, granularity="per_channel", lam=0.9,
                            sweeps=3, order="greedy_shared")
    for (m, n) in ((256, 256), (512, 512), (1024, 1024)):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m))
        x = jax.random.normal(k1, (2 * m, m))
        w = jax.random.normal(k2, (m, n)) * 0.05
        h = gram(x)
        solvers = {
            "rtn": jax.jit(lambda hh, ww: rtn_quantize(ww, spec, h=hh).q),
            "gptq": jax.jit(lambda hh, ww: gptq_quantize(hh, ww, spec).q),
            "comq": lambda hh, ww: comq_quantize_h(hh, ww, spec).q,
            "comq_blocked": lambda hh, ww: comq_quantize_blocked(
                hh, ww, spec_shared, block=128).q,
        }
        for name, fn in solvers.items():
            _, us = timed(fn, h, w, repeats=2)
            rows.append((f"t9/{name}_{m}x{n}", round(us, 1), m * n))

    # --- schedule A/B: trailing-update vs legacy per-panel refresh --------
    for (m, n) in ((512, 512), (1024, 1024)):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m))
        h = gram(jax.random.normal(k1, (2 * m, m)))
        w = jax.random.normal(k2, (m, n)) * 0.05
        _, us_t = timed(lambda: comq_quantize_blocked(
            h, w, spec_shared, block=128).q, repeats=3)
        _, us_r = timed(lambda: comq_quantize_blocked(
            h, w, spec_shared, block=128, schedule="refresh").q, repeats=3)
        per_sweep = us_t / spec_shared.sweeps
        rows.append((f"solver/blocked_trailing_per_sweep_{m}x{n}",
                     round(per_sweep, 1), round(us_t, 1)))
        rows.append((f"solver/blocked_trailing_vs_refresh_{m}x{n}",
                     round(us_t, 1), round(us_r / us_t, 3)))

    # --- fused shared-tap solve vs per-leaf solves ------------------------
    m, n = 512, 512
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    tap = jax.random.normal(k1, (4, 256, m))
    wqkv = [jax.random.normal(jax.random.fold_in(k2, i), (m, n)) * 0.05
            for i in range(3)]
    wcat = jnp.concatenate(wqkv, axis=1)

    def fused():
        h = gram(tap.reshape(-1, m))
        return comq_quantize_h(h, wcat, spec).q

    def separate():
        qs = []
        for wl in wqkv:                     # per-leaf Gram + solve (pre-PR1)
            h = gram(tap.reshape(-1, m))
            qs.append(comq_quantize_h(h, wl, spec).q)
        return qs[-1]

    _, us_f = timed(fused, repeats=2)
    _, us_s = timed(separate, repeats=2)
    rows.append((f"solver/fused_shared_tap_vs_separate_{m}x3x{n}",
                 round(us_f, 1), round(us_s / us_f, 3)))
    return rows
