"""Systems table: the three-term roofline per (arch × shape × mesh) from
the dry-run artifacts (launch/dryrun.py must have been run; cells without
artifacts are skipped). `derived` column = dominant-term seconds."""
import glob
import json
import os

from repro.configs import get_config
from repro.roofline.analysis import (CostTotals, roofline_terms, PEAK_FLOPS,
                                     HBM_BW)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch tokens."""
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    if s.kind == "train":
        toks = s.global_batch * s.seq_len
        return 6.0 * n * toks
    if s.kind == "prefill":
        return 2.0 * n * s.global_batch * s.seq_len
    return 2.0 * n * s.global_batch  # decode: one token per sequence


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if "hlo" not in d or "error" in d:
            continue
        if d.get("overrides"):
            continue  # baselines only; hillclimb variants live in §Perf
        h = d["hlo"]
        cost = CostTotals(flops=h["flops_per_device"],
                          bytes_accessed=h["bytes_per_device"],
                          collective_bytes=h["collective_bytes"])
        chips = 512 if d["mesh"] == "2x16x16" else 256
        t = roofline_terms(cost, n_chips=chips)
        mf = model_flops(d["arch"], d["shape"])
        useful = mf / chips / max(h["flops_per_device"], 1.0)
        tag = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        rows.append((tag + "/compute_s", 0.0, round(t["compute_s"], 6)))
        rows.append((tag + "/memory_s", 0.0, round(t["memory_s"], 6)))
        rows.append((tag + "/collective_s", 0.0,
                     round(t["collective_s"], 6)))
        rows.append((tag + "/dominant=" + t["dominant"], 0.0,
                     round(t["bound_s"], 6)))
        rows.append((tag + "/useful_flops_frac", 0.0, round(useful, 4)))
        rows.append((tag + "/mem_gb_per_dev", 0.0,
                     d["memory"]["per_device_total_gb"]))
    return rows
