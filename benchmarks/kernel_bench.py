"""Kernel-level microbenches: quant_matmul HBM-traffic advantage (the
mechanism behind the decode-cell §Perf win) and solver-schedule comparison.
Wall-times are CPU XLA (relative only); `derived` reports the analytic
HBM-byte ratio that holds on TPU."""
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels import ops


def run():
    rows = []
    M, K, N = 32, 2048, 2048
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (M, K), jnp.bfloat16)
    w = jax.random.normal(k2, (K, N), jnp.bfloat16)
    u8 = jax.random.randint(k2, (K, N), 0, 256).astype(jnp.uint8)
    scale = jnp.full((N,), 0.01)
    z = jnp.full((N,), -128, jnp.int32)

    dense = jax.jit(lambda a, b: a @ b)
    _, us_dense = timed(dense, x, w, repeats=3)
    rows.append(("kernel/dense_matmul_2048", round(us_dense, 1),
                 K * N * 2))

    qmm = jax.jit(lambda a, c, s, zz: ops.quant_matmul(a, c, s, zz, bits=8,
                                                       mode="xla"))
    _, us_q8 = timed(qmm, x, u8, scale, z, repeats=3)
    rows.append(("kernel/quant_matmul_w8_2048", round(us_q8, 1), K * N))

    from repro.core.quantizer import pack_int4
    u4 = jax.random.randint(k2, (K, N), 0, 16).astype(jnp.uint8)
    p4 = pack_int4(u4)
    qmm4 = jax.jit(lambda a, c, s, zz: ops.quant_matmul(a, c, s, zz, bits=4,
                                                        mode="xla"))
    _, us_q4 = timed(qmm4, x, p4, scale, z, repeats=3)
    rows.append(("kernel/quant_matmul_w4_2048", round(us_q4, 1), K * N // 2))
    # derived column = weight bytes streamed from HBM: bf16 4x of int4
    rows.append(("kernel/w4_weight_bytes_ratio_vs_bf16", 0.0, 4.0))

    # fused panel sweep (lazy ΔW-emitting form, DESIGN.md §3.2): the jnp
    # oracle timing tracks the schedule's sequential cost per panel
    from repro.core.comq_hessian import panel_sweep_dq_ref
    B, n = 128, 512
    kh, ks, kq = jax.random.split(jax.random.PRNGKey(1), 3)
    hb = jax.random.normal(kh, (B, B))
    h_bb = hb @ hb.T + jnp.eye(B) * B
    s0 = jax.random.normal(ks, (B, n))
    qf = jax.random.normal(kq, (B, n))
    delta = jnp.full((n,), 0.05)
    zlo = jnp.full((n,), -8.0)
    zhi = jnp.full((n,), 7.0)
    sweep = jax.jit(lambda s, q: panel_sweep_dq_ref(
        h_bb, s, q, delta, zlo, zhi, jnp.diag(h_bb))[0])
    _, us_panel = timed(sweep, s0, qf, repeats=3)
    rows.append(("kernel/comq_panel_dq_sweep_128x512", round(us_panel, 1),
                 B * n))
    return rows
