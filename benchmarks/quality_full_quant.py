"""Paper Tab. 2/5 analogue: full quantization — COMQ weights + uniform
dynamic per-tensor activation quantization at the residual-stream block
boundaries (a simplified stand-in for RepQ-ViT's reparameterized A-quant;
the paper likewise plugs an external A-quant scheme into COMQ)."""
import jax
import jax.numpy as jnp

from benchmarks.common import PLAN, calib_tokens, eval_loss, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def _act_quant_constrain(abits: int):
    """Dynamic symmetric per-tensor A-quant (scale from the live absmax —
    fully in-graph, so it composes with the scanned layer stack)."""
    qmax = 2.0 ** (abits - 1) - 1

    def constrain(x, kind):
        if kind != "residual":
            return x
        x32 = x.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-6) / qmax
        q = jnp.clip(jnp.round(x32 / s), -qmax, qmax)
        return (q * s).astype(x.dtype)

    return constrain


def run():
    cfg, params = trained_model()
    calib = calib_tokens(cfg)
    fp = eval_loss(params, cfg)
    rows = [("t2/fp_baseline", 0.0, round(fp, 4))]
    for wbits, abits in ((4, 8), (4, 4), (2, 4)):
        spec = QuantSpec(bits=wbits, granularity="per_channel",
                         lam=0.9 if wbits > 2 else 0.71, sweeps=3,
                         order="greedy")
        qp, _ = quantize_model(params, cfg, PLAN, calib, spec)
        mat = materialize(qp, cfg)
        plan_aq = PLAN.replace(constrain=_act_quant_constrain(abits))
        loss = eval_loss(mat, cfg, plan=plan_aq)
        rows.append((f"t2/comq_w{wbits}a{abits}", 0.0, round(loss, 4)))
    return rows
