"""Column-sharded-solve BENCH rows only (DESIGN.md §4.3).

    PYTHONPATH=src python -m benchmarks.run --only solver_shard \
        --json BENCH_solver.json

A thin entry so the CI multidevice-smoke job can refresh the
solver/colsharded_vs_replicated rows into BENCH_solver.json without
re-running the whole t9 table; the measurement itself lives in
benchmarks/runtime_compare.py::colsharded_rows (forced-8-device (2, 4)
mesh in a subprocess).
"""
from benchmarks.runtime_compare import colsharded_rows


def run():
    return colsharded_rows()
