"""Column-sharded-solve BENCH rows only (DESIGN.md §4.3).

    PYTHONPATH=src python -m benchmarks.run --only solver_shard \
        --json BENCH_solver.json

A thin entry so the CI multidevice-smoke job can refresh the
solver/colsharded_vs_replicated rows into BENCH_solver.json without
re-running the whole t9 table; the measurement itself lives in
benchmarks/runtime_compare.py::colsharded_rows (forced-8-device (2, 4)
mesh in a subprocess).

Also emits solver/w2_vs_w4_decode_matmul: the decode-shaped (M=4)
quant_matmul at 2-bit quad-packed (4 codes/byte, the Pallas kernel's
in-register quad unpack) vs 4-bit nibble-packed. Wall is CPU XLA
(relative only); `derived` is the weight-byte stream ratio (2.0: the
2-bit panel is half the 4-bit bytes) that holds on TPU, where the
kernel's unpack stays in registers instead of materializing.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from benchmarks.runtime_compare import colsharded_rows
from repro.core.quantizer import pack_codes
from repro.kernels import ops


def _w2_w4_rows():
    rows = []
    M, K, N = 4, 2048, 2048
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (M, K), jnp.bfloat16)
    scale = jnp.full((N,), 0.01)
    z = jnp.full((N,), 0, jnp.int32)
    times = {}
    for bits in (2, 4):
        u = jax.random.randint(k2, (K, N), 0, 2 ** bits).astype(jnp.uint8)
        packed, cpb = pack_codes(u, bits)
        fn = jax.jit(lambda a, c, s, zz, b=bits, cc=cpb: ops.quant_matmul(
            a, c, s, zz, bits=b, cpb=cc, mode="xla"))
        _, times[bits] = timed(fn, x, packed, scale, z, repeats=3)
    rows.append(("solver/w2_vs_w4_decode_matmul", round(times[2], 1),
                 round((K * N // 2) / (K * N // 4), 1)))
    return rows


def run():
    return colsharded_rows() + _w2_w4_rows()
