"""Paper Tab. 7: accuracy vs sweep count K (expect saturation at K≈3-4)."""
from benchmarks.common import PLAN, calib_tokens, eval_loss, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model()
    calib = calib_tokens(cfg)
    rows = [("t7/fp_baseline", 0.0, round(eval_loss(params, cfg), 4))]
    for k in (1, 2, 3, 4, 5):
        spec = QuantSpec(bits=4, granularity="per_layer", sweeps=k,
                         order="greedy")
        qp, _ = quantize_model(params, cfg, PLAN, calib, spec)
        loss = eval_loss(materialize(qp, cfg), cfg)
        rows.append((f"t7/comq_perlayer_w4_K{k}", 0.0, round(loss, 4)))
    return rows
