"""Shared benchmark machinery: a cached trained smoke model (the PTQ
subject), eval metrics, timing."""
from __future__ import annotations

import os
import pickle
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.data import SyntheticLM
from repro.models import BuildPlan, lm_loss
from repro.train.trainer import Trainer

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")
PLAN = BuildPlan(remat=False)

_MEM: Dict[str, Tuple] = {}


def trained_model(arch: str = "h2o-danube-1.8b", steps: int = 80,
                  seed: int = 0):
    """Train (or load from cache) a reduced-config model on the structured
    synthetic stream — the quantization subject for every quality table."""
    key = f"{arch}_{steps}_{seed}"
    if key in _MEM:
        return _MEM[key]
    cfg = get_smoke_config(arch)
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, key + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            params = pickle.load(f)
        params = jax.tree_util.tree_map(jnp.asarray, params)
    else:
        run_cfg = RunConfig(arch=arch, ckpt_dir=os.path.join(CACHE_DIR, key),
                            ckpt_every=10_000, total_steps=steps,
                            learning_rate=3e-3, warmup_steps=5,
                            async_ckpt=False, seed=seed)
        t = Trainer(cfg, PLAN, run_cfg)
        out = t.run_loop(total_steps=steps, seq_len=64, global_batch=8)
        params = out["state"]["params"]
        with open(path, "wb") as f:
            pickle.dump(jax.tree_util.tree_map(
                lambda a: jax.device_get(a), params), f)
    _MEM[key] = (cfg, params)
    return cfg, params


def eval_loss(params, cfg, plan=PLAN, batches: int = 4) -> float:
    tot = 0.0
    for i in range(batches):
        d = SyntheticLM(cfg.vocab_size, seed=0).sample(8, 64, step=10_000 + i)
        b = {"tokens": jnp.asarray(d["tokens"]),
             "labels": jnp.asarray(d["labels"])}
        tot += float(lm_loss(params, cfg, plan, b)[0])
    return tot / batches


def calib_tokens(cfg, n_tokens: int = 512, seed: int = 0):
    seq = 64
    batch = max(1, n_tokens // seq)
    d = SyntheticLM(cfg.vocab_size, seed=0).sample(batch, seq, step=5_000)
    return jnp.asarray(d["tokens"])


def timed(fn, *args, repeats: int = 1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0]) \
        if jax.tree_util.tree_leaves(out) else None
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6   # µs
