"""Paper Tab. 4/5 analogue: per-channel weight-only across methods/bits on a
second architecture family (qwen2: GQA with qkv-bias)."""
from benchmarks.common import PLAN, calib_tokens, eval_loss, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model("qwen2-7b")
    calib = calib_tokens(cfg)
    rows = [("t4/fp_baseline", 0.0, round(eval_loss(params, cfg), 4))]
    for bits in (4, 3, 2):
        for method in ("comq", "gptq", "rtn"):
            spec = QuantSpec(bits=bits, granularity="per_channel",
                             lam=0.9 if bits > 2 else 0.71, sweeps=3,
                             order="greedy")
            qp, _ = quantize_model(params, cfg, PLAN, calib, spec,
                                   method=method)
            loss = eval_loss(materialize(qp, cfg), cfg)
            rows.append((f"t4/{method}_w{bits}", 0.0, round(loss, 4)))
    return rows
