"""Paper Tab. 1 analogue: weight-only per-channel PTQ quality at 4/3/2 bits.

The subject is a small LM trained on the structured synthetic stream; the
metric is held-out eval loss (lower = better; the stand-in for ImageNet
top-1 in this environment). Compares COMQ (greedy) vs RTN vs GPTQ."""
import jax.numpy as jnp

from benchmarks.common import PLAN, calib_tokens, eval_loss, timed, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model()
    calib = calib_tokens(cfg)
    fp = eval_loss(params, cfg)
    rows = [("t1/fp_baseline", 0.0, round(fp, 4))]
    for bits in (4, 3, 2):
        for method in ("comq", "gptq", "rtn"):
            spec = QuantSpec(bits=bits, granularity="per_channel",
                             lam=0.9 if bits > 2 else 0.71, sweeps=3,
                             order="greedy")
            (qp, rep), us = timed(quantize_model, params, cfg, PLAN, calib,
                                  spec, method=method)
            loss = eval_loss(materialize(qp, cfg), cfg)
            rows.append((f"t1/{method}_w{bits}", round(us, 1),
                         round(loss, 4)))
    return rows
