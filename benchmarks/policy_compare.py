"""Mixed-precision policy benches (BENCH_pipeline.json rows):

* policy/mixed_vs_uniform_err — total COMQ reconstruction error of a
  budget-allocated mixed 2/3/4/8-bit policy vs the uniform policy at the
  same bits-per-param budget; `derived` = mixed/uniform error ratio
  (< 1 means the allocator's per-leaf spend beats flat bits — the
  Hubara-style layerwise-IP result reproduced on COMQ's free error
  evals). `us_per_call` is the allocator+curves wall time.
* policy/mixed_vs_uniform_bytes — packed serving-tree bytes of the mixed
  policy vs uniform; `derived` = mixed/uniform bytes ratio (≈ 1 at a
  matched budget: the allocator trades bits between leaves, it does not
  spend more of them).
"""
from __future__ import annotations

import time

import jax

from repro.ckpt import tree_bytes
from repro.configs import get_smoke_config
from repro.core import (QuantSpec, policy_from_budget, quantize_model,
                        serving_params)
from repro.models import BuildPlan, init_params

ARCH = "qwen2-7b"
BUDGET = 4.0          # bits/param — the uniform comparison point is b=4


def run():
    rows = []
    cfg = get_smoke_config(ARCH).replace(n_layers=4)
    plan = BuildPlan(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, plan)
    tokens = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    base = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")

    t0 = time.perf_counter()
    policy, alloc, sizes = policy_from_budget(params, cfg, plan, tokens,
                                              base, BUDGET)
    alloc_us = (time.perf_counter() - t0) * 1e6

    qp_u, rep_u = quantize_model(params, cfg, plan, tokens, base)
    qp_m, rep_m = quantize_model(params, cfg, plan, tokens, policy)

    err_u = sum(r.err_after for r in rep_u.layers)
    err_m = sum(r.err_after for r in rep_m.layers)
    rows.append(("policy/mixed_vs_uniform_err", round(alloc_us, 1),
                 round(err_m / max(err_u, 1e-12), 4)))

    by_u = tree_bytes(serving_params(qp_u, cfg)["layers"])
    sl = serving_params(qp_m, cfg)["layers"]
    by_m = tree_bytes(sl)
    rows.append(("policy/mixed_vs_uniform_bytes", 0.0,
                 round(by_m / max(by_u, 1), 4)))
    return rows
