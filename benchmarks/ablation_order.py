"""Paper Tab. 8 + Fig. 3: greedy vs cyclic update order — end metric and
per-layer reconstruction errors (the Fig. 3 curves, printed as derived
aggregate: mean greedy/cyclic error ratio across layers)."""
from benchmarks.common import PLAN, calib_tokens, eval_loss, trained_model
from repro.core import QuantSpec, materialize, quantize_model


def run():
    cfg, params = trained_model()
    calib = calib_tokens(cfg)
    rows = []
    per_layer_errs = {}
    for bits in (4, 3, 2):
        for order in ("greedy", "cyclic"):
            spec = QuantSpec(bits=bits, granularity="per_channel",
                             lam=0.9 if bits > 2 else 0.71, sweeps=3,
                             order=order)
            qp, rep = quantize_model(params, cfg, PLAN, calib, spec)
            loss = eval_loss(materialize(qp, cfg), cfg)
            per_layer_errs[(bits, order)] = [r.err_after for r in rep.layers]
            rows.append((f"t8/{order}_w{bits}", 0.0, round(loss, 4)))
        g = per_layer_errs[(bits, "greedy")]
        c = per_layer_errs[(bits, "cyclic")]
        ratio = sum(gv / max(cv, 1e-12) for gv, cv in zip(g, c)) / len(g)
        rows.append((f"fig3/err_ratio_greedy_over_cyclic_w{bits}", 0.0,
                     round(ratio, 4)))
    return rows
