"""End-to-end driver (deliverable b): train a ~1M-param reduced config for
a few hundred steps on the structured synthetic stream, quantize it with
COMQ at 4 bits, write a packed quantized checkpoint, then serve a
mixed-length continuous-batching request set *directly from the packed
codes* (serve.Runtime + core.serving_params — no materialize) — the full
production workflow.

    PYTHONPATH=src python examples/quantize_and_serve.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import (CheckpointManager, pack_tree, strip_for_serving,
                        tree_bytes)
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core import QuantSpec, quantize_model, serving_params
from repro.data import SyntheticLM
from repro.models import BuildPlan, count_params, lm_loss
from repro.serve import Runtime, ServeConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--workdir", default="/tmp/repro_e2e")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    plan = BuildPlan(remat=False)
    print(f"[1/4] training {cfg.name} ({count_params(cfg):,} params) "
          f"for {args.steps} steps")
    run_cfg = RunConfig(arch=args.arch, ckpt_dir=args.workdir + "/ckpt",
                        ckpt_every=100, total_steps=args.steps,
                        learning_rate=3e-3, warmup_steps=10)
    trainer = Trainer(cfg, plan, run_cfg)
    out = trainer.run_loop(total_steps=args.steps, seq_len=64,
                           global_batch=8)
    params = out["state"]["params"]
    print(f"      loss {out['metrics'][0]['loss']:.3f} -> "
          f"{out['metrics'][-1]['loss']:.3f}")

    print(f"[2/4] COMQ {args.bits}-bit per-channel quantization (greedy)")
    calib = jnp.asarray(SyntheticLM(cfg.vocab_size, 0)
                        .sample(8, 64, step=777)["tokens"])
    spec = QuantSpec(bits=args.bits, granularity="per_channel", lam=0.9,
                     sweeps=3, order="greedy")
    t0 = time.time()
    qparams, report = quantize_model(params, cfg, plan, calib, spec)
    print(f"      {len(report.layers)} projections in {time.time()-t0:.1f}s;"
          f" error vs RTN improved {report.total_improvement():.1%}")

    print("[3/4] packed quantized checkpoint")
    packed = pack_tree(strip_for_serving(qparams))
    mgr = CheckpointManager(args.workdir + "/quant", keep=1)
    mgr.save(0, packed, extra={"bits": args.bits})
    dense_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(params))
    print(f"      {tree_bytes(packed):,} bytes vs {dense_bytes:,} dense "
          f"({dense_bytes / tree_bytes(packed):.1f}x smaller)")

    print("[4/4] continuous-batching serve straight from the packed codes")
    sp = serving_params(qparams, cfg)     # QT leaves — never materialized
    data = SyntheticLM(cfg.vocab_size, 0).sample(4, 32, step=31337)
    toks = np.asarray(data["tokens"])
    prompts = [toks[i, :l] for i, l in enumerate((32, 20, 27, 12))]
    rt = Runtime(sp, cfg, plan,
                 ServeConfig(max_slots=4, block_size=16, num_blocks=16,
                             buckets=(16, 32)))
    t0 = time.time()
    rt.generate(prompts, max_new_tokens=16)
    dt = time.time() - t0
    n_new = 4 * 16
    print(f"      {n_new} tokens in {dt:.1f}s ({n_new / dt:.1f} tok/s CPU, "
          f"mixed prompt lens {[len(p) for p in prompts]}, "
          f"peak cache occupancy "
          f"{rt.allocator.peak_in_use}/{rt.allocator.num_blocks} pages)")
    ev = {"tokens": jnp.asarray(data["tokens"]),
          "labels": jnp.asarray(data["labels"])}
    print(f"      fp-loss {float(lm_loss(params, cfg, plan, ev)[0]):.3f}  "
          f"quant-loss {float(lm_loss(sp, cfg, plan, ev)[0]):.3f}")
    print("done.")


if __name__ == "__main__":
    main()
