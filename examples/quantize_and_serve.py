"""End-to-end driver (deliverable b): train a ~1M-param reduced config for
a few hundred steps on the structured synthetic stream, quantize it with
COMQ at 4 bits, write a packed quantized checkpoint, then serve batched
requests from the quantized model — the full production workflow.

    PYTHONPATH=src python examples/quantize_and_serve.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, pack_tree, tree_bytes
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core import QuantSpec, materialize, quantize_model
from repro.data import SyntheticLM
from repro.models import BuildPlan, count_params, lm_loss
from repro.serve.engine import Engine
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--workdir", default="/tmp/repro_e2e")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    plan = BuildPlan(remat=False)
    print(f"[1/4] training {cfg.name} ({count_params(cfg):,} params) "
          f"for {args.steps} steps")
    run_cfg = RunConfig(arch=args.arch, ckpt_dir=args.workdir + "/ckpt",
                        ckpt_every=100, total_steps=args.steps,
                        learning_rate=3e-3, warmup_steps=10)
    trainer = Trainer(cfg, plan, run_cfg)
    out = trainer.run_loop(total_steps=args.steps, seq_len=64,
                           global_batch=8)
    params = out["state"]["params"]
    print(f"      loss {out['metrics'][0]['loss']:.3f} -> "
          f"{out['metrics'][-1]['loss']:.3f}")

    print(f"[2/4] COMQ {args.bits}-bit per-channel quantization (greedy)")
    calib = jnp.asarray(SyntheticLM(cfg.vocab_size, 0)
                        .sample(8, 64, step=777)["tokens"])
    spec = QuantSpec(bits=args.bits, granularity="per_channel", lam=0.9,
                     sweeps=3, order="greedy")
    t0 = time.time()
    qparams, report = quantize_model(params, cfg, plan, calib, spec)
    print(f"      {len(report.layers)} projections in {time.time()-t0:.1f}s;"
          f" error vs RTN improved {report.total_improvement():.1%}")

    print("[3/4] packed quantized checkpoint")
    packed = pack_tree(qparams["__qlayers__"])
    mgr = CheckpointManager(args.workdir + "/quant", keep=1)
    mgr.save(0, packed, extra={"bits": args.bits})
    dense_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(params))
    print(f"      {tree_bytes(packed):,} bytes vs {dense_bytes:,} dense "
          f"({dense_bytes / tree_bytes(packed):.1f}x smaller)")

    print("[4/4] serving batched requests from the quantized model")
    mat = materialize(qparams, cfg)
    data = SyntheticLM(cfg.vocab_size, 0).sample(4, 32, step=31337)
    eng = Engine(mat, cfg, plan)
    t0 = time.time()
    outs = eng.generate_batch(np.asarray(data["tokens"]),
                              max_new_tokens=16)
    dt = time.time() - t0
    ev = {"tokens": jnp.asarray(data["tokens"]),
          "labels": jnp.asarray(data["labels"])}
    print(f"      {outs.size} tokens in {dt:.1f}s "
          f"({outs.size / dt:.1f} tok/s CPU)")
    print(f"      fp-loss {float(lm_loss(params, cfg, plan, ev)[0]):.3f}  "
          f"quant-loss {float(lm_loss(mat, cfg, plan, ev)[0]):.3f}")
    print("done.")


if __name__ == "__main__":
    main()
