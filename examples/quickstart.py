"""Quickstart: COMQ on a single linear layer in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Solves min ‖X·W_q − X·W‖² with 4-bit per-channel codes and compares the
three solvers + RTN (paper §3, Alg. 2).
"""
import jax
import jax.numpy as jnp

from repro.core import (QuantSpec, comq_quantize, comq_quantize_blocked,
                        comq_quantize_h, gram, gptq_quantize, rtn_quantize)
from repro.core.comq_hessian import _h_error

key = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(key)
X = jax.random.normal(k1, (512, 256))          # calibration features
W = jax.random.normal(k2, (256, 128)) * 0.05   # pre-trained weight
H = gram(X)

spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                 order="greedy")


def err(r):
    return float(_h_error(H, W, r.q.astype(jnp.float32) * r.delta))


rtn = rtn_quantize(W, spec, h=H)
gptq = gptq_quantize(H, W, spec)
comq_x = comq_quantize(X, W, spec)                      # paper-faithful
comq_h = comq_quantize_h(H, W, spec)                    # Gram-space (scale)
comq_b = comq_quantize_blocked(H, W, spec, block=64)    # TPU panel schedule

print(f"reconstruction error ‖X(W - W_q)‖:")
print(f"  RTN          : {err(rtn):.4f}")
print(f"  GPTQ         : {err(gptq):.4f}")
print(f"  COMQ (X)     : {err(comq_x):.4f}")
print(f"  COMQ (H)     : {err(comq_h):.4f}   "
      f"bit-identical to X-space: {bool(jnp.all(comq_x.q == comq_h.q))}")
print(f"  COMQ (panel) : {err(comq_b):.4f}")
print(f"per-sweep error trajectory: "
      f"{[round(float(e), 4) for e in comq_x.errors]}")
