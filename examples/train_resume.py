"""Fault-tolerance demo: a training run is killed twice by an injected
"node failure"; run_with_restarts resumes each time from the latest
committed (atomic, async-written) checkpoint and finishes all steps.

    PYTHONPATH=src python examples/train_resume.py
"""
import shutil

from repro.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.ft import run_with_restarts
from repro.models import BuildPlan
from repro.train.trainer import Trainer

WORKDIR = "/tmp/repro_ft_demo"


def main():
    shutil.rmtree(WORKDIR, ignore_errors=True)
    cfg = get_smoke_config("h2o-danube-1.8b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="h2o-danube-1.8b", ckpt_dir=WORKDIR,
                        ckpt_every=5, total_steps=25, async_ckpt=True,
                        learning_rate=3e-3, warmup_steps=3)
    crashes = {"left": 2}

    def bomb(step):
        if step in (8, 17) and crashes["left"] > 0:
            crashes["left"] -= 1
            print(f"  !! injected node failure at step {step}")
            raise RuntimeError("node failure")

    attempts = {"n": 0}

    def attempt(resume_step):
        attempts["n"] += 1
        print(f"attempt {attempts['n']}: resuming from "
              f"{'scratch' if resume_step is None else f'step {resume_step}'}")
        t = Trainer(cfg, plan, run_cfg, failure_hook=bomb)
        out = t.run_loop(total_steps=25, seq_len=64, global_batch=8)
        print(f"  finished at step {out['final_step']}, "
              f"loss {out['metrics'][-1]['loss']:.3f}")
        return out["final_step"]

    def latest():
        return CheckpointManager(WORKDIR).latest_step()

    final = run_with_restarts(attempt, latest, max_restarts=4)
    print(f"completed {final}/25 steps across {attempts['n']} attempts "
          f"({2 - crashes['left']} injected failures survived)")


if __name__ == "__main__":
    main()
