"""Distributed COMQ: shard the per-channel solve across devices.

Per-channel COMQ columns are independent given H (paper eq. 3) — the solve
needs ZERO communication after one H all-reduce. This example forces 8
host devices, builds the (data, model) calibration mesh, and runs the
production column-sharded path (`repro.dist.sharded_solve`, DESIGN.md
§4.3): W's output columns shard over "model", each shard runs the
unmodified maintained-P trailing-update solver on its slice, and the
result is bit-identical to the replicated solve with no collectives in
the compiled HLO.

    PYTHONPATH=src python examples/distributed_quantize.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import QuantSpec, comq_quantize_blocked, gram  # noqa: E402
from repro.dist import calib_mesh, sharded_solve  # noqa: E402
# internal, imported only to inspect the compiled HLO for collectives —
# the solve itself goes through the public sharded_solve above
from repro.dist.calibrate import _solve_fn  # noqa: E402


def main():
    assert jax.device_count() >= 8, "needs 8 host devices"
    mesh = calib_mesh(model=4)            # (data=2, model=4)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    X = jax.random.normal(k1, (1024, 256))
    W = jax.random.normal(k2, (256, 510)) * 0.05   # 510: pads to 512 cols
    H = gram(X)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")      # blocked solver -> greedy_shared

    q_sh, d_sh, z_sh, _, _ = sharded_solve(mesh, H, W, spec, "comq_blocked",
                                           block=128)
    ref = comq_quantize_blocked(H, W, spec, block=128)
    same = bool(jnp.all(q_sh == ref.q)) and bool(jnp.all(z_sh == ref.z_lo))
    print(f"columns sharded over {mesh.shape['model']} devices "
          f"(+ {mesh.shape['data']}-way data axis for the Gram psum)")
    print(f"codes/zero-points bit-identical to the replicated solve: {same}")
    d_ulp = float(jnp.max(jnp.abs(d_sh - ref.delta)
                          / jnp.maximum(jnp.abs(ref.delta), 1e-30)))
    print(f"scales within f32 rounding: max rel diff {d_ulp:.2e}")

    # count collectives in the compiled solve — COMQ needs none
    wp = jnp.pad(W.astype(jnp.float32), ((0, 0), (0, 2)))
    perm = jnp.arange(H.shape[0], dtype=jnp.int32)
    txt = _solve_fn(mesh, spec, "comq_blocked", 128).lower(
        H, wp, perm).compile().as_text()
    n_coll = sum(txt.count(c) for c in
                 ("all-reduce(", "all-gather(", "reduce-scatter(",
                  "all-to-all(", "collective-permute("))
    print(f"collectives in the compiled solve: {n_coll}")
    assert same and n_coll == 0


if __name__ == "__main__":
    main()
