"""Distributed COMQ: shard the per-channel solve across devices.

Per-channel COMQ columns are independent given H (paper eq. 3) — the solve
needs ZERO communication after one H all-reduce. This example forces 8
host devices, shards W's output columns across them with pjit, and checks
bit-identity with the single-device solve.

    PYTHONPATH=src python examples/distributed_quantize.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import QuantSpec, comq_quantize_h, gram  # noqa: E402


def main():
    assert jax.device_count() >= 8, "needs 8 host devices"
    mesh = jax.make_mesh((8,), ("model",))
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    X = jax.random.normal(k1, (1024, 256))
    W = jax.random.normal(k2, (256, 512)) * 0.05
    H = gram(X)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")

    def solve(h, w):
        r = comq_quantize_h(h, w, spec)
        return r.q, r.delta

    with mesh:
        sharded = jax.jit(
            solve,
            in_shardings=(NamedSharding(mesh, P()),               # H replicated
                          NamedSharding(mesh, P(None, "model"))),  # cols sharded
            out_shardings=(NamedSharding(mesh, P(None, "model")),
                           NamedSharding(mesh, P("model"))))
        q_sh, d_sh = sharded(H, W)

    q_ref, d_ref = solve(H, W)
    same = bool(jnp.all(q_sh == q_ref))
    print(f"columns sharded over {mesh.shape['model']} devices")
    print(f"bit-identical to single-device solve: {same}")
    # count collectives in the compiled solve — COMQ needs none
    txt = jax.jit(solve, in_shardings=(
        NamedSharding(mesh, P()), NamedSharding(mesh, P(None, "model"))),
        out_shardings=(NamedSharding(mesh, P(None, "model")),
                       NamedSharding(mesh, P("model")))
    ).lower(H, W).compile().as_text()
    n_coll = sum(txt.count(c) for c in
                 ("all-reduce(", "all-gather(", "reduce-scatter(",
                  "all-to-all("))
    print(f"collectives in the compiled solve: {n_coll} — all from scalar "
          f"norm/diagnostic reductions; the per-coordinate sweep itself "
          f"runs with zero cross-column communication")
    assert same


if __name__ == "__main__":
    main()
