"""Whole-model COMQ: GPTQ-style sequential layer-by-layer quantization with
*quantized propagation* — layer l+1 is calibrated on the activations
produced by the already-quantized layers 1..l, so downstream layers absorb
upstream quantization error (standard PTQ pipeline structure).

The pipeline walks the stacked layer params, uses the model's activation
taps (models/*.py `taps=` hooks) to get the exact input X of every
projection, solves COMQ in H-space per projection, and returns a params
pytree where quantized leaves are `QTensor` dicts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import calibrate
from repro.core.baselines import gptq_quantize, rtn_quantize
from repro.core.comq_hessian import _h_error, comq_quantize_blocked, comq_quantize_h
from repro.core.quantizer import QuantSpec
from repro.models import transformer as tfm
from repro.models.common import apply_norm, dtype_of

Array = jax.Array

# which tap feeds which weight leaf, per layer family
DENSE_TAPS = {
    ("attn", "wq"): "attn_in", ("attn", "wk"): "attn_in",
    ("attn", "wv"): "attn_in", ("attn", "wo"): "wo_in",
    ("mlp", "w_gate"): "mlp_in", ("mlp", "w_up"): "mlp_in",
    ("mlp", "w_down"): "down_in",
}
MOE_TAPS = {
    ("attn", "wq"): "attn_in", ("attn", "wk"): "attn_in",
    ("attn", "wv"): "attn_in", ("attn", "wo"): "wo_in",
    ("moe", "w_gate"): "expert_in", ("moe", "w_up"): "expert_in",
    ("moe", "w_down"): "expert_down_in",
}
RWKV_TAPS = {
    ("tm", "w_r"): "tm_r_in", ("tm", "w_k"): "tm_k_in",
    ("tm", "w_v"): "tm_v_in", ("tm", "w_g"): "tm_g_in",
    ("tm", "w_o"): "tm_o_in",
    ("cm", "w_k"): "cm_k_in", ("cm", "w_r"): "cm_r_in",
    ("cm", "w_v"): "cm_v_in",
}
SSM_EXTRA_TAPS = {
    ("ssm", "w_in"): "ssm_in", ("ssm", "w_out"): "ssm_out_in",
}
CROSS_TAPS = {
    ("xattn", "wq"): "xattn_q_in", ("xattn", "wo"): "xattn_wo_in",
    ("mlp", "w_gate"): "mlp_in", ("mlp", "w_up"): "mlp_in",
    ("mlp", "w_down"): "down_in",
}


def taps_for(cfg) -> Dict[Tuple[str, str], str]:
    if cfg.attn_free:
        return dict(RWKV_TAPS)
    t = dict(MOE_TAPS if cfg.moe is not None else DENSE_TAPS)
    if cfg.parallel_ssm_heads:
        t.update(SSM_EXTRA_TAPS)
    return t


def is_qtensor(leaf) -> bool:
    return isinstance(leaf, dict) and leaf.get("__qtensor__", False) is True


def make_qtensor(q: Array, delta: Array, z_lo: Array, shape) -> dict:
    """Codes stored offset-binary (q - z_lo ∈ [0, 2^b-1]) as uint8 so any
    zero-point fits; dequant restores W_q = δ·(u + z)."""
    u = (q - z_lo).astype(jnp.uint8)
    return {"__qtensor__": True, "codes": u,
            "scale": jnp.asarray(delta, jnp.float32),
            "z_lo": jnp.asarray(z_lo, jnp.int32),
            "shape": tuple(int(s) for s in shape)}


def dequant_qtensor(t: dict, dtype=jnp.float32) -> Array:
    q = t["codes"].astype(jnp.int32) + t["z_lo"]
    w2d = q.astype(jnp.float32) * t["scale"]
    return w2d.reshape(t["shape"]).astype(dtype)


def dequantize_tree(tree):
    """Replace every QTensor leaf with its dequantized dense weight."""
    def walk(node):
        if is_qtensor(node):
            return dequant_qtensor(node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(tree)


@dataclass
class LayerReport:
    layer: int
    name: str
    err_before: float     # ‖X(W - RTN(W))‖ on the COMQ grid init
    err_after: float      # ‖X(W - W_q)‖ after COMQ
    seconds: float


@dataclass
class QuantReport:
    layers: List[LayerReport] = field(default_factory=list)

    def total_improvement(self) -> float:
        b = sum(r.err_before for r in self.layers)
        a = sum(r.err_after for r in self.layers)
        return (b - a) / max(b, 1e-12)


# ---------------------------------------------------------------------------
# solver dispatch
# ---------------------------------------------------------------------------

def solve(h: Array, w2d: Array, spec: QuantSpec, method: str = "comq",
          block: int = 256):
    if method == "comq":
        return comq_quantize_h(h, w2d, spec)
    if method == "comq_blocked":
        return comq_quantize_blocked(h, w2d, spec, block=block)
    if method == "rtn":
        return rtn_quantize(w2d, spec, h=h)
    if method == "gptq":
        return gptq_quantize(h, w2d, spec)
    raise ValueError(f"unknown method {method!r}")


def _quantize_leaf(w: Array, tap: Array, spec: QuantSpec, method: str,
                   per_expert: bool = False):
    """w: any-rank weight; 2D view (in, out...) flattened appropriately.

    Attention weights (d, H, hd) flatten to (d, H*hd); wo (H, hd, d) to
    (H*hd, d); MoE (E, d, f) are solved per-expert with per-expert Grams.
    Returns (qtensor, err_before, err_after)."""
    shape = w.shape
    if per_expert:
        # stacked experts: (E, d, f) with tap (E, C, d)
        hs = calibrate.batched_gram(tap)                 # (E, d, d)

        def one(h_e, w_e):
            r = solve(h_e, w_e, spec, method)
            rt = rtn_quantize(w_e, spec, h=h_e)
            return (r.q, r.delta, r.z_lo, r.errors[-1], rt.errors[-1])

        q, delta, z_lo, ea, eb = jax.vmap(one)(hs, w.astype(jnp.float32))
        # reshape per-expert scale/zero to broadcast against (E, m, n)
        delta_b = (jnp.asarray(delta, jnp.float32)[:, None, :]
                   if delta.ndim == 2
                   else jnp.asarray(delta, jnp.float32)[:, None, None])
        z_b = (z_lo[:, None, :] if z_lo.ndim == 2 else z_lo[:, None, None])
        qt = make_qtensor(q, delta_b, z_b, shape)
        return qt, float(jnp.sum(eb)), float(jnp.sum(ea))

    # general: the weight's input dim must match the tap's feature dim
    m = tap.shape[-1]
    if w.ndim == 2:
        w2d = w
    elif w.ndim == 3 and shape[0] == m:            # (d, H, hd)
        w2d = w.reshape(m, shape[1] * shape[2])
    elif w.ndim == 3 and shape[0] * shape[1] == m:  # (H, hd, d)
        w2d = w.reshape(m, shape[2])
    else:
        raise ValueError(f"cannot 2D-ify weight {shape} for tap dim {m}")

    h = calibrate.gram_from_tap(tap)
    r = solve(h, w2d, spec, method)
    rt = rtn_quantize(w2d, spec, h=h)
    qt = make_qtensor(r.q, r.delta, r.z_lo, shape)
    return qt, float(rt.errors[-1]), float(r.errors[-1])


# ---------------------------------------------------------------------------
# the sequential pipeline
# ---------------------------------------------------------------------------

def _tree_slice(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _tree_set(tree, i, sub):
    return jax.tree_util.tree_map(lambda a, s: a.at[i].set(s), tree, sub)


def quantize_model(params, cfg, plan, tokens: Array, spec: QuantSpec,
                   method: str = "comq",
                   vision_embeds: Optional[Array] = None,
                   quantize_unembed: bool = False):
    """Quantize all projection weights of an LM. `tokens`: (B, T) calib batch.

    Returns (qparams, QuantReport). qparams has QTensor leaves; use
    `dequantize_tree` (or the quantized serving path) to run it.
    """
    from repro.models.model import embed_tokens, _vlm_group_counts
    report = QuantReport()
    cd = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params, cfg, plan, tokens)
    qparams = jax.tree_util.tree_map(lambda a: a, params)  # shallow copy
    tapmap = taps_for(cfg)

    layer_full_j = jax.jit(
        lambda lp, x, st: _layer_with_taps(lp, x, st, cfg, plan))

    if cfg.family == "vlm":
        return _quantize_vlm(params, cfg, plan, x, spec, method,
                             vision_embeds, report)

    init_states = None
    if cfg.attn_free:
        from repro.models.rwkv import init_rwkv_state
        init_states = init_rwkv_state(x.shape[0], cfg)
    elif cfg.parallel_ssm_heads:
        from repro.models.ssm import init_ssm_state
        init_states = init_ssm_state(x.shape[0], cfg)

    state = init_states
    for l in range(cfg.n_layers):
        lp = _tree_slice(params["layers"], l)
        t0 = time.time()
        _, taps, _ = layer_full_j(lp, x, state)
        lp_q = dict(lp)
        for (mod, leaf), tapname in tapmap.items():
            if mod not in lp or leaf not in lp[mod]:
                continue
            qt, eb, ea = _quantize_leaf(lp[mod][leaf], taps[tapname], spec,
                                        method,
                                        per_expert=tapname.startswith("expert"))
            lp_q = _set_nested(lp_q, mod, leaf, qt)
            report.layers.append(LayerReport(l, f"{mod}.{leaf}", eb, ea,
                                             time.time() - t0))
        # propagate through the *quantized* layer
        lp_deq = dequantize_tree(lp_q)
        x, _, state = layer_full_j(lp_deq, x, state)
        qparams = _store_layer(qparams, l, lp_q)

    if quantize_unembed and "unembed" in params:
        xn = apply_norm(params["final_norm"], x, cfg)
        qt, eb, ea = _quantize_leaf(params["unembed"], xn, spec, method)
        qparams["unembed"] = qt
        report.layers.append(LayerReport(-1, "unembed", eb, ea, 0.0))
    return qparams, report


def _set_nested(lp, mod, leaf, value):
    lp = dict(lp)
    lp[mod] = dict(lp[mod])
    lp[mod][leaf] = value
    return lp


def _store_layer(qparams, l, lp_q):
    """Store per-layer QTensors under a side table (stacked storage would
    force all layers to share scales)."""
    qparams = dict(qparams)
    table = dict(qparams.get("__qlayers__", {}))
    table[str(l)] = lp_q
    qparams["__qlayers__"] = table
    return qparams


def _layer_with_taps(lp, x, state, cfg, plan):
    taps: Dict[str, Array] = {}
    rwkv_state = state if cfg.attn_free else None
    ssm_state = state if cfg.parallel_ssm_heads else None
    y, _, _, new_state = tfm.layer_full(lp, x, cfg, plan, False,
                                        rwkv_state=rwkv_state,
                                        ssm_state=ssm_state, taps=taps)
    return y, taps, new_state


def _quantize_vlm(params, cfg, plan, x, spec, method, vision_embeds, report):
    from repro.models.model import _vlm_group_counts
    g, spg = _vlm_group_counts(cfg)
    cd = x.dtype
    ve = jnp.einsum("bnv,vd->bnd", vision_embeds.astype(cd),
                    params["vision_proj"].astype(cd))
    qparams = dict(params)
    table = {}
    for gi in range(g):
        for si in range(spg):
            lp = _tree_slice(_tree_slice(params["groups"]["self"], gi), si)
            taps: Dict[str, Array] = {}
            y, _, _, _ = tfm.layer_full(lp, x, cfg, plan, False, taps=taps)
            lp_q = dict(lp)
            for (mod, leaf), tapname in DENSE_TAPS.items():
                if mod not in lp or leaf not in lp[mod]:
                    continue
                qt, eb, ea = _quantize_leaf(lp[mod][leaf], taps[tapname],
                                            spec, method)
                lp_q = _set_nested(lp_q, mod, leaf, qt)
                report.layers.append(
                    LayerReport(gi * (spg + 1) + si, f"{mod}.{leaf}", eb, ea, 0.0))
            x, _, _, _ = tfm.layer_full(dequantize_tree(lp_q), x, cfg, plan,
                                        False)
            table[f"self_{gi}_{si}"] = lp_q
        cp = _tree_slice(params["groups"]["cross"], gi)
        taps = {}
        vkv = tfm.vision_kv_for_layer(cp, ve)
        _ = tfm.cross_layer_full(cp, x, cfg, plan, vkv, taps=taps)
        cp_q = dict(cp)
        for (mod, leaf), tapname in CROSS_TAPS.items():
            if mod not in cp or leaf not in cp[mod]:
                continue
            qt, eb, ea = _quantize_leaf(cp[mod][leaf], taps[tapname], spec,
                                        method)
            cp_q = _set_nested(cp_q, mod, leaf, qt)
            report.layers.append(
                LayerReport(gi * (spg + 1) + spg, f"cross.{mod}.{leaf}",
                            eb, ea, 0.0))
        x = tfm.cross_layer_full(dequantize_tree(cp_q), x, cfg, plan, vkv)
        table[f"cross_{gi}"] = cp_q
    qparams["__qlayers__"] = table
    return qparams, report


# ---------------------------------------------------------------------------
# materialize a runnable dequantized model
# ---------------------------------------------------------------------------

def materialize(qparams, cfg) -> Any:
    """Fold the __qlayers__ side table back into stacked dense params."""
    params = {k: v for k, v in qparams.items() if k != "__qlayers__"}
    table = qparams.get("__qlayers__", {})
    if not table:
        return params
    if cfg.family == "vlm":
        from repro.models.model import _vlm_group_counts
        g, spg = _vlm_group_counts(cfg)
        self_p = params["groups"]["self"]
        cross_p = params["groups"]["cross"]
        for gi in range(g):
            for si in range(spg):
                deq = dequantize_tree(table[f"self_{gi}_{si}"])
                self_p = jax.tree_util.tree_map(
                    lambda a, s: a.at[gi, si].set(s.astype(a.dtype)),
                    self_p, deq)
            deq = dequantize_tree(table[f"cross_{gi}"])
            cross_p = jax.tree_util.tree_map(
                lambda a, s: a.at[gi].set(s.astype(a.dtype)), cross_p, deq)
        params = dict(params)
        params["groups"] = {"self": self_p, "cross": cross_p}
        return params
    layers = params["layers"]
    for key, lp_q in table.items():
        l = int(key)
        deq = dequantize_tree(lp_q)
        layers = jax.tree_util.tree_map(
            lambda a, s: a.at[l].set(s.astype(a.dtype)), layers, deq)
    params = dict(params)
    params["layers"] = layers
    if is_qtensor(params.get("unembed", None)):
        params["unembed"] = dequant_qtensor(params["unembed"])
    return params
