"""Whole-model COMQ: GPTQ-style sequential layer-by-layer quantization with
*quantized propagation* — layer l+1 is calibrated on the activations
produced by the already-quantized layers 1..l, so downstream layers absorb
upstream quantization error (standard PTQ pipeline structure).

The pipeline walks the stacked layer params, uses the model's activation
taps (models/*.py `taps=` hooks) to get the exact input X of every
projection, solves COMQ in H-space per projection, and returns a params
pytree where quantized leaves are `QTensor` dicts.

Two propagation schedules (DESIGN.md §4.1):

* ``staged`` (default) — **one forward per layer**: the layer's single
  tap-collecting forward quantizes each leaf group *in tap order*
  (attn_in → wo_in → mlp_in → down_in) via the `quantize_cb` hook in
  models/*.py, so every downstream sub-path is computed with the already-
  quantized upstream sub-blocks. Halves calibration forward FLOPs and
  makes intra-layer taps exact w.r.t. the quantized model.
* ``legacy`` — the two-forward schedule (float tap forward, then a second
  quantized-propagation forward), kept for A/B
  (benchmarks/runtime_compare.py::pipeline/staged_vs_legacy).

Reporting is sync-free: per-leaf errors stay on device during the walk and
are materialized by one batched transfer at the end (`_finalize_report`).
With a ``mesh`` (a "data" axis), calibration is data-parallel: tokens are
sharded over the mesh and each tap's (m, m) Gram block reduces with a
single psum — the only communication (repro.dist, DESIGN.md §4.2).

"Which bits" is a per-leaf decision, not a constructor argument: every
solve receives the QuantSpec a `core.policy.QuantPolicy` resolves for
that (layer, leaf) — pattern rules, first/last overrides, or a budgeted
backprop-free allocation (DESIGN.md §6). A plain QuantSpec still works
everywhere and is bit-identical to the pre-policy pipeline.
"""
from __future__ import annotations

import functools
import json
import sys
import time
import zlib
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate
from repro.core import guards as _guards
from repro.core.baselines import gptq_quantize, rtn_quantize
from repro.core.comq_hessian import comq_quantize_blocked, comq_quantize_h
from repro.core.guards import GuardContext, GuardEvent, guarded_solve
from repro.core.policy import as_policy, policy_to_dict
from repro.core.quantizer import QuantSpec
from repro.ft.inject import InjectedFault, SimulatedKill
from repro.ft.journal import QuantJournal, ResumeMismatch
from repro.obs.metrics import NULL_METRICS
from repro.obs.trace import NULL_TRACER
from repro.models import transformer as tfm
from repro.models.common import apply_norm

Array = jax.Array

# which tap feeds which weight leaf, per layer family
DENSE_TAPS = {
    ("attn", "wq"): "attn_in", ("attn", "wk"): "attn_in",
    ("attn", "wv"): "attn_in", ("attn", "wo"): "wo_in",
    ("mlp", "w_gate"): "mlp_in", ("mlp", "w_up"): "mlp_in",
    ("mlp", "w_down"): "down_in",
}
MOE_TAPS = {
    ("attn", "wq"): "attn_in", ("attn", "wk"): "attn_in",
    ("attn", "wv"): "attn_in", ("attn", "wo"): "wo_in",
    ("moe", "w_gate"): "expert_in", ("moe", "w_up"): "expert_in",
    ("moe", "w_down"): "expert_down_in",
}
RWKV_TAPS = {
    ("tm", "w_r"): "tm_r_in", ("tm", "w_k"): "tm_k_in",
    ("tm", "w_v"): "tm_v_in", ("tm", "w_g"): "tm_g_in",
    ("tm", "w_o"): "tm_o_in",
    ("cm", "w_k"): "cm_k_in", ("cm", "w_r"): "cm_r_in",
    ("cm", "w_v"): "cm_v_in",
}
SSM_EXTRA_TAPS = {
    ("ssm", "w_in"): "ssm_in", ("ssm", "w_out"): "ssm_out_in",
}
CROSS_TAPS = {
    ("xattn", "wq"): "xattn_q_in", ("xattn", "wo"): "xattn_wo_in",
    ("mlp", "w_gate"): "mlp_in", ("mlp", "w_up"): "mlp_in",
    ("mlp", "w_down"): "down_in",
}


def taps_for(cfg) -> Dict[Tuple[str, str], str]:
    if cfg.attn_free:
        return dict(RWKV_TAPS)
    t = dict(MOE_TAPS if cfg.moe is not None else DENSE_TAPS)
    if cfg.parallel_ssm_heads:
        t.update(SSM_EXTRA_TAPS)
    return t


def is_qtensor(leaf) -> bool:
    # bool(), not `is True`: CheckpointManager restores scalar leaves as
    # 0-d ndarrays, and a restored QTensor table must still be recognized
    return isinstance(leaf, dict) and bool(leaf.get("__qtensor__", False))


def make_qtensor(q: Array, delta: Array, z_lo: Array, shape,
                 bits: int = 8) -> dict:
    """Codes stored offset-binary (q - z_lo ∈ [0, 2^b-1]) as uint8 so any
    zero-point fits; dequant restores W_q = δ·(u + z). `bits` records the
    width the solve used — the packing/serving layers dispatch on it
    instead of inspecting code values (core/apply, ckpt/quantized)."""
    u = (q - z_lo).astype(jnp.uint8)
    return {"__qtensor__": True, "codes": u,
            "scale": jnp.asarray(delta, jnp.float32),
            "z_lo": jnp.asarray(z_lo, jnp.int32),
            "shape": tuple(int(s) for s in shape),
            "bits": int(bits)}


def qtensor_bits(t: dict) -> int:
    """Bit width of a pipeline QTensor (pre-policy trees default to 8:
    codes were stored one-per-byte and packers re-inspect nothing)."""
    return int(t.get("bits", 8))


def dequant_qtensor(t: dict, dtype=jnp.float32) -> Array:
    q = t["codes"].astype(jnp.int32) + t["z_lo"]
    w2d = q.astype(jnp.float32) * t["scale"]
    return w2d.reshape(t["shape"]).astype(dtype)


def dequantize_tree(tree):
    """Replace every QTensor leaf with its dequantized dense weight."""
    def walk(node):
        if is_qtensor(node):
            return dequant_qtensor(node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(tree)


@dataclass
class LayerReport:
    layer: int
    name: str
    err_before: float     # ‖X(W - RTN(W))‖ on the COMQ grid init
    err_after: float      # ‖X(W - W_q)‖ after COMQ
    # host time spent *dispatching* this leaf's solve: the walk is sync-free
    # (errors stay on device until one batched transfer at the end), so on
    # an async backend this is not the solve's compute time
    dispatch_seconds: float = 0.0
    # span-derived wall time of the leaf's solve (dispatch + device
    # compute), measured by the `leaf_solve` tracer span which blocks on
    # the solved codes before closing. Only populated when a tracer is
    # enabled — with tracing off the walk stays sync-free and this is
    # 0.0 (unmeasured). Fused shared-tap groups split the group wall
    # evenly, like dispatch_seconds.
    wall_seconds: float = 0.0
    # comma-joined guard-event kinds for this leaf ("" = no intervention;
    # e.g. "dead_columns,damping_escalated") — see QuantReport.guard_events
    # for the full records
    guard: str = ""

    @property
    def seconds(self) -> float:
        """Pre-PR-9 alias. The old field recorded dispatch time since the
        sync-free walk landed but consumers still read it as wall time —
        use `dispatch_seconds` or `wall_seconds` explicitly."""
        return self.dispatch_seconds


@dataclass
class QuantReport:
    layers: List[LayerReport] = field(default_factory=list)
    # end-to-end quantize_model wall time (measured around the whole walk,
    # after the finalizing device_get — includes all device compute)
    wall_seconds: float = 0.0
    # every numeric-guard intervention of the run (core/guards.GuardEvent):
    # NaN/Inf sentinels, dead columns, damping escalations, solver
    # fallbacks — empty on a healthy run
    guard_events: List[GuardEvent] = field(default_factory=list)
    # leaves re-applied from the quantization journal instead of re-solved
    resumed_leaves: int = 0

    def total_improvement(self) -> float:
        b = sum(r.err_before for r in self.layers)
        a = sum(r.err_after for r in self.layers)
        return (b - a) / max(b, 1e-12)


# ---------------------------------------------------------------------------
# solver dispatch + shared-tap fused solves
# ---------------------------------------------------------------------------

def solve(h: Array, w2d: Array, spec: QuantSpec, method: str = "comq",
          block: int = 256, schedule: Optional[str] = None):
    """`schedule` only applies to comq_blocked (None = trailing); the
    guard fallback chain (core/guards.solver_chain) uses it to retry a
    failed trailing-update solve on the per-panel-refresh schedule."""
    if method == "comq":
        return comq_quantize_h(h, w2d, spec)
    if method == "comq_blocked":
        return comq_quantize_blocked(h, w2d, spec, block=block,
                                     schedule=schedule or "trailing")
    if method == "rtn":
        return rtn_quantize(w2d, spec, h=h)
    if method == "gptq":
        return gptq_quantize(h, w2d, spec)
    raise ValueError(f"unknown method {method!r}")


def _col_shardable(spec: QuantSpec, method: str) -> bool:
    """True when the solve can run with W's output columns sharded over the
    "model" mesh axis bit-identically to the replicated solve.

    Requires per-channel granularity (per-layer shares one δ across all
    columns) and a solver whose per-column computation is robust to running
    on a column *slice*: the blocked trailing-update solver (its one
    column-coupled quantity — the shared visit order — is precomputed on
    the full W and passed in; see comq_hessian.shared_order) and RTN
    (elementwise). The row-at-a-time solvers (comq/gptq) are column-
    *separable* in exact arithmetic but their per-coordinate descent
    cascades FP-rounding differences across sweeps under a different XLA
    fusion context, so they stay replicated."""
    if spec.granularity != "per_channel":
        return False
    return method in ("comq_blocked", "rtn")


def _fusable(spec: QuantSpec, method: str) -> bool:
    """True when leaves sharing a tap can be solved as one column-
    concatenated matrix with results identical to per-leaf solves.

    Per-channel grids have column-wise δ/zero-points, and per-channel COMQ
    columns are independent given δ (paper eq. (3)) — so fusion is exact
    whenever the visit order is also per-column (cyclic, exact greedy).
    Shared-order solvers (greedy_shared; blocked's shared greedy) derive the
    order from *all* columns, so fusing would change it."""
    if spec.granularity != "per_channel":
        return False
    if method == "comq_blocked":
        return spec.order == "cyclic"
    if method in ("rtn", "gptq"):
        return True
    return spec.order in ("cyclic", "greedy")


def _w2d(w: Array, m: int) -> Array:
    """2D view (m, cols) of an any-rank weight against tap feature dim m:
    attention (d, H, hd) flattens to (d, H·hd); wo (H, hd, d) to (H·hd, d)."""
    if w.ndim == 2:
        return w
    if w.ndim == 3 and w.shape[0] == m:
        return w.reshape(m, w.shape[1] * w.shape[2])
    if w.ndim == 3 and w.shape[0] * w.shape[1] == m:
        return w.reshape(m, w.shape[2])
    raise ValueError(f"cannot 2D-ify weight {w.shape} for tap dim {m}")


@jax.jit
def _col_err2(h: Array, w: Array, wq: Array) -> Array:
    """Per-column squared reconstruction error Σ_i R⊙(HR): lets one fused
    H·R matmul attribute exact per-leaf errors after a concatenated solve."""
    r = w - wq
    return jnp.sum(r * (h @ r), axis=0)


def _norm_of(e2_slice: Array) -> Array:
    """Device scalar — never forces a host sync; see _finalize_report."""
    return jnp.sqrt(jnp.maximum(jnp.sum(e2_slice), 0.0))


def _expert_norm_sum(e2: Array) -> Array:
    """(E, cols) per-column err² -> sum over experts of per-expert norms,
    matching the historical per-leaf MoE reporting (device scalar)."""
    return jnp.sum(jnp.sqrt(jnp.maximum(jnp.sum(e2, axis=1), 0.0)))


def _uniform(specs) -> bool:
    return all(s == specs[0] for s in specs)


def _results_finite(results) -> bool:
    """Host bool: every (qt, eb, ea, secs) row has finite scales and
    errors — one batched transfer (the post-solve guard sentinel)."""
    flags = [jnp.all(jnp.isfinite(qt["scale"]))
             & jnp.isfinite(jnp.asarray(eb, jnp.float32))
             & jnp.isfinite(jnp.asarray(ea, jnp.float32))
             for qt, eb, ea, _ in results]
    return bool(jax.device_get(jnp.all(jnp.stack(flags))))  # comq: allow(host-sync) one batched finiteness verdict


def _solve_group(ws, h: Array, specs, method: str,
                 block: int = 256, solve_sh=None, *,
                 gctx: Optional[GuardContext] = None, layer: int = -1,
                 names=None):
    """Solve the weight leaves `ws` (all calibrated by the same Gram h),
    each under its own resolved per-leaf spec (`specs`, same length).

    When the group's specs are identical AND fusion is exact (see
    _fusable), the leaves are solved as one column-concatenated
    [w_a|w_b|…] matrix — one solver invocation and one grid init per tap
    instead of one per leaf — then split back per leaf. Mixed-bit groups
    fall back to per-leaf solves: the δ grid init depends on the bit
    width, so fusing across widths would change every column's grid.

    `solve_sh` (from quantize_model when the mesh has a nontrivial "model"
    axis) runs the solve with output columns sharded over "model"
    (dist.sharded_solve): bit-identical codes, zero solve-time collectives.
    The sharded path mirrors the replicated fusion decision exactly — the
    fused concatenation solves as one column-sharded matrix, per-leaf
    solves shard per leaf (each with its own spec) — so sharded and
    replicated pipelines agree at every bit width.
    With an enabled `gctx` (core/guards.GuardContext) the group runs the
    full guard policy: one batched health check sanitizes NaN/Inf in H
    and the weights and counts dead Gram columns, solves go through
    `guarded_solve` (escalating damping + solver fallback chain), and a
    sharded solve whose output is non-finite is redone replicated under
    the guarded chain. A healthy group takes the exact unguarded compute
    path, so guarded and unguarded pipelines are bit-identical unless a
    guard actually fires.

    Returns [(qtensor, err_before, err_after, seconds), ...]."""
    m = h.shape[0]
    w2ds = [_w2d(w, m) for w in ws]
    spec0 = specs[0]
    guarding = gctx is not None and gctx.enabled
    if names is None:
        names = [f"leaf{i}" for i in range(len(ws))]
    if guarding:
        n_bad_h, n_dead, n_bad_ws = _guards.gram_health(h, w2ds)
        if n_bad_h:
            h = jnp.where(jnp.isfinite(h), h, jnp.zeros((), h.dtype))
            for nm in names:
                gctx.record(layer, nm, "nonfinite_gram", count=n_bad_h)
        for i, (nb, nm) in enumerate(zip(n_bad_ws, names)):
            if nb:
                w2ds[i] = jnp.where(jnp.isfinite(w2ds[i]), w2ds[i],
                                    jnp.zeros((), w2ds[i].dtype))
                gctx.record(layer, nm, "nonfinite_weight", count=nb)
        if n_dead:
            for nm in names:
                gctx.record(layer, nm, "dead_columns", warn=False,
                            count=n_dead)

    if solve_sh is not None and _col_shardable(spec0, method):
        fuse = len(ws) > 1 and _uniform(specs) and _fusable(spec0, method)
        t0 = time.time()
        if fuse:
            wcat = jnp.concatenate([w.astype(jnp.float32) for w in w2ds],
                                   axis=1)
            q, delta, z_lo, e2b, e2a = solve_sh(h, wcat, spec=spec0,
                                                block=block)
            secs = (time.time() - t0) / len(ws)
            out, lo = [], 0
            for w, w2d in zip(ws, w2ds):
                hi = lo + w2d.shape[1]
                qt = make_qtensor(q[:, lo:hi], delta[lo:hi], z_lo[lo:hi],
                                  w.shape, bits=spec0.bits)
                out.append((qt, _norm_of(e2b[lo:hi]), _norm_of(e2a[lo:hi]),
                            secs))
                lo = hi
        else:
            out = []
            for w, w2d, spec in zip(ws, w2ds, specs):
                t0 = time.time()
                q, delta, z_lo, e2b, e2a = solve_sh(h, w2d, spec=spec,
                                                    block=block)
                qt = make_qtensor(q, delta, z_lo, w.shape, bits=spec.bits)
                out.append((qt, _norm_of(e2b), _norm_of(e2a),
                            time.time() - t0))
        if guarding and not _results_finite(out):
            # the sharded program has no guard hooks — redo this group
            # replicated under the full guarded chain
            for nm in names:
                gctx.record(layer, nm, "sharded_solve_nonfinite")
            return _solve_group(ws, h, specs, method, block, None,
                                gctx=gctx, layer=layer, names=names)
        return out

    if len(ws) > 1 and _uniform(specs) and _fusable(spec0, method):
        t0 = time.time()
        wcat = jnp.concatenate([w.astype(jnp.float32) for w in w2ds], axis=1)
        if guarding:
            r = guarded_solve(h, wcat, spec0, method, block=block,
                              gctx=gctx, layer=layer, names=names,
                              solve_fn=solve, presanitized=True)
        else:
            r = solve(h, wcat, spec0, method, block=block)
        e2_after = _col_err2(h, wcat, r.q.astype(jnp.float32) * r.delta)
        rt = rtn_quantize(wcat, spec0)
        e2_before = _col_err2(h, wcat, rt.q.astype(jnp.float32) * rt.delta)
        secs = (time.time() - t0) / len(ws)
        out, lo = [], 0
        for w, w2d in zip(ws, w2ds):
            hi = lo + w2d.shape[1]
            qt = make_qtensor(r.q[:, lo:hi], r.delta[lo:hi], r.z_lo[lo:hi],
                              w.shape, bits=spec0.bits)
            out.append((qt, _norm_of(e2_before[lo:hi]),
                        _norm_of(e2_after[lo:hi]), secs))
            lo = hi
        return out

    out = []
    for i, (w, w2d, spec) in enumerate(zip(ws, w2ds, specs)):
        t0 = time.time()
        if guarding:
            r = guarded_solve(h, w2d, spec, method, block=block, gctx=gctx,
                              layer=layer, names=names[i:i + 1],
                              solve_fn=solve, presanitized=True)
        else:
            r = solve(h, w2d, spec, method, block=block)
        rt = rtn_quantize(w2d, spec, h=h)
        qt = make_qtensor(r.q, r.delta, r.z_lo, w.shape, bits=spec.bits)
        out.append((qt, rt.errors[-1], r.errors[-1], time.time() - t0))
    return out


def _expert_qtensor(q, delta, z_lo, shape, bits: int):
    """Per-expert scale/zero reshaped to broadcast against (E, m, n)."""
    delta_b = (jnp.asarray(delta, jnp.float32)[:, None, :]
               if delta.ndim == 2
               else jnp.asarray(delta, jnp.float32)[:, None, None])
    z_b = (z_lo[:, None, :] if z_lo.ndim == 2 else z_lo[:, None, None])
    return make_qtensor(q, delta_b, z_b, shape, bits=bits)


def _solve_group_experts(ws, hs: Array, specs, method: str, *,
                         gctx: Optional[GuardContext] = None,
                         layer: int = -1, names=None):
    """Stacked-expert leaves (E, d, f_k) sharing per-expert Grams hs
    (E, d, d): vmapped per-expert solves, column-fused across leaves when
    exact (identical specs only — mixed-bit expert groups solve per leaf).

    The vmapped solve body cannot host-sync per expert, so the guard
    policy here is group-batched: sanitize non-finite Grams up front, run
    the unguarded solve, and only if the *group's* results are non-finite
    retry the whole group under escalating damping, finally falling back
    to (data-free) RTN. A healthy group is bit-identical to the unguarded
    path. Returns [(qtensor, err_before, err_after, seconds), ...]."""

    def one_fn(spec, meth):
        def one(h_e, w_e):
            r = solve(h_e, w_e, spec, meth)
            rt = rtn_quantize(w_e, spec)
            e2a = _col_err2(h_e, w_e, r.q.astype(jnp.float32) * r.delta)
            e2b = _col_err2(h_e, w_e, rt.q.astype(jnp.float32) * rt.delta)
            return r.q, r.delta, r.z_lo, e2a, e2b
        return one

    spec0 = specs[0]

    def run(hs_in, meth):
        if len(ws) > 1 and _uniform(specs) and _fusable(spec0, meth):
            t0 = time.time()
            wcat = jnp.concatenate([w.astype(jnp.float32) for w in ws],
                                   axis=-1)
            q, delta, z_lo, e2a, e2b = jax.vmap(one_fn(spec0, meth))(
                hs_in, wcat)
            secs = (time.time() - t0) / len(ws)
            out, lo = [], 0
            for w in ws:
                hi = lo + w.shape[-1]
                qt = _expert_qtensor(q[:, :, lo:hi], delta[:, lo:hi],
                                     z_lo[:, lo:hi], w.shape, spec0.bits)
                out.append((qt, _expert_norm_sum(e2b[:, lo:hi]),
                            _expert_norm_sum(e2a[:, lo:hi]), secs))
                lo = hi
            return out
        out = []
        for w, spec in zip(ws, specs):
            t0 = time.time()
            q, delta, z_lo, e2a, e2b = jax.vmap(one_fn(spec, meth))(
                hs_in, w.astype(jnp.float32))
            qt = _expert_qtensor(q, delta, z_lo, w.shape, spec.bits)
            out.append((qt, _expert_norm_sum(e2b), _expert_norm_sum(e2a),
                        time.time() - t0))
        return out

    guarding = gctx is not None and gctx.enabled
    if not guarding:
        return run(hs, method)
    if names is None:
        names = [f"leaf{i}" for i in range(len(ws))]
    n_bad = _guards.nonfinite_count(hs)
    if n_bad:
        hs = jnp.where(jnp.isfinite(hs), hs, jnp.zeros((), hs.dtype))
        for nm in names:
            gctx.record(layer, nm, "nonfinite_gram", count=n_bad)
    out = run(hs, method)
    if _results_finite(out):
        return out
    for mult in _guards.DAMP_MULTS:
        out = run(_guards.damp_hessian(hs, mult), method)
        if _results_finite(out):
            for nm in names:
                gctx.record(layer, nm, "damping_escalated", mult=mult)
            return out
    out = run(hs, "rtn")
    for nm in names:
        gctx.record(layer, nm, "fallback", solver="rtn")
    return out


# ---------------------------------------------------------------------------
# crash-safe run context: journaling, resume, fault injection (DESIGN.md §8)
# ---------------------------------------------------------------------------

def _spec_digest(spec: QuantSpec, method: str) -> int:
    """crc32 of the resolved spec + solver — part of the journal key, so a
    journaled leaf is only re-applied when a re-solve would have received
    the identical spec (a changed policy/method invalidates it)."""
    payload = {**asdict(spec), "method": method}
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


def _run_digest(cfg, policy, method: str, propagation: str, tok_host,
                quantize_unembed: bool, mesh) -> int:
    """crc32 over everything that must match for journaled leaves to be
    bit-identical to a fresh solve: architecture, solver, policy,
    propagation schedule, the calibration token bytes, and the mesh shape
    (a different mesh reduces Grams in a different order)."""
    tok = np.asarray(tok_host)
    payload = {
        "arch": cfg.name, "family": cfg.family, "n_layers": cfg.n_layers,
        "method": method, "propagation": propagation,
        "policy": policy_to_dict(policy),
        "unembed": bool(quantize_unembed),
        "tokens": [zlib.crc32(tok.tobytes()), list(tok.shape),
                   str(tok.dtype)],
        "mesh": (sorted([str(k), int(v)] for k, v in mesh.shape.items())
                 if mesh is not None else None),
    }
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


def _calib_leaf_dims(cfg) -> Dict[str, int]:
    """Leaf-class input dims for the calibration coverage check: a Gram
    over fewer tokens than columns is guaranteed rank-deficient."""
    dims = {"d_model": cfg.d_model}
    if not cfg.attn_free:
        dims["wo_in"] = cfg.n_heads * cfg.resolved_head_dim
        dims["down_in"] = cfg.d_ff
    return dims


class _RunCtx:
    """Per-run plumbing threaded through the layer walk: the numeric-guard
    context (core/guards), the quantization journal (resume lookup +
    durable leaf commit, ft/journal.QuantJournal), and the fault injector
    (ft/inject). A default-constructed ctx without journal/injector and a
    disabled gctx is a no-op at every hook — the historical pipeline."""

    def __init__(self, method: str, gctx: Optional[GuardContext] = None,
                 journal: Optional[QuantJournal] = None, solved=None,
                 injector=None, progress_cb=None, tracer=None,
                 metrics=None):
        self.method = method
        self.gctx = gctx
        self.journal = journal
        self.solved = dict(solved or {})   # (layer, name) -> leaf record
        self.injector = injector
        self.progress_cb = progress_cb
        self.resumed = 0
        # observability (DESIGN.md §10): null singletons when disabled
        self.tracer = tracer or NULL_TRACER
        self.metrics = metrics or NULL_METRICS
        self.m_layers = self.metrics.counter("quant.layers_done")
        self.m_leaves = self.metrics.counter("quant.leaves_solved")

    # -- fault injection ----------------------------------------------------

    def fault(self, point: str, exc=InjectedFault) -> None:
        if self.injector is not None:
            self.injector.check(point, exc=exc)

    def poison_tap(self, tap: Array) -> Array:
        """nan_tap fault: poison one tap entry instead of raising —
        exercises the NaN sentinels end-to-end."""
        if self.injector is not None and self.injector.fire("nan_tap"):
            tap = tap.at[(0,) * tap.ndim].set(jnp.nan)
        return tap

    def sanitize_tap(self, tap: Array, layer: int, names) -> Array:
        """Tap-collection NaN/Inf sentinel: scrub (and record) non-finite
        activations before they poison the Gram."""
        if self.gctx is None or not self.gctx.enabled:
            return tap
        n_bad = _guards.nonfinite_count(tap)
        if n_bad:
            tap = jnp.where(jnp.isfinite(tap), tap, jnp.zeros((), tap.dtype))
            for nm in names:
                self.gctx.record(layer, nm, "nonfinite_tap", count=n_bad)
        return tap

    # -- journal: resume lookup + durable commit ----------------------------

    def lookup(self, layer: int, names, specs):
        """All-or-nothing journal hit for one tap group: every leaf must
        be journaled under its current spec digest, else the whole group
        re-solves (a partial hit would change fused-solve membership).
        Returns [(qtensor, leaf record), ...] or None."""
        if self.journal is None or not self.solved:
            return None
        recs = []
        for nm, spec in zip(names, specs):
            rec = self.solved.get((layer, nm))
            if rec is None or rec["spec"] != _spec_digest(spec, self.method):
                return None
            recs.append(rec)
        loaded = []
        for rec in recs:
            qt_host = QuantJournal.load_leaf(self.journal.dir, rec)
            # intern the dict keys: each spill unpickles fresh string
            # objects, and downstream pickles (ckpt --save-packed) would
            # lose key memo-sharing vs a freshly-solved tree — the bytes
            # must be identical, not just the values
            qt = {sys.intern(str(k)): (jnp.asarray(v)
                                       if isinstance(v, np.ndarray) else v)
                  for k, v in qt_host.items()}
            loaded.append((qt, rec))
        self.resumed += len(loaded)
        return loaded

    def commit(self, layer: int, names, specs, results):
        """Durably persist each solved leaf — spill (atomic packed file)
        strictly before its journal record, so a journaled leaf always
        has a valid spill — and return rows with host-float errors.
        Journaling forces one host sync per group (durability needs the
        bytes); without a journal the walk stays sync-free."""
        if self.journal is None:
            return results
        errs = jax.device_get(  # comq: allow(host-sync) journal commit: one batched pull per run

            jnp.stack([jnp.stack([jnp.asarray(eb, jnp.float32),
                                  jnp.asarray(ea, jnp.float32)])
                       for _, eb, ea, *_ in results]))
        rows = []
        for (nm, spec, (qt, _, _, secs, wall)), (ebf, eaf) in zip(
                zip(names, specs, results), errs):
            # comq: allow(host-sync) journal payloads must be host arrays
            qt_host = {k: np.asarray(jax.device_get(v))
                       if isinstance(v, jax.Array) else v
                       for k, v in qt.items()}
            fname, crc = self.journal.spill_leaf(
                layer, nm, qt_host, fault_cb=self._ckpt_write_fault)
            self.journal.record_leaf(layer, nm,
                                     _spec_digest(spec, self.method),
                                     fname, crc, float(ebf), float(eaf))
            rows.append((qt, float(ebf), float(eaf), secs, wall))
        return rows

    def _ckpt_write_fault(self) -> None:
        self.fault("ckpt_write")

    def layer_done(self, layer: int) -> None:
        """End-of-layer hook: journal the marker, report progress to the
        supervisor, and give the (shared) kill fault point its between-
        layers shot — after the layer's leaves are durably journaled."""
        if self.journal is not None:
            self.journal.record_layer_done(layer)
        self.m_layers.inc()
        if self.progress_cb is not None:
            self.progress_cb(layer)
        self.fault("kill", SimulatedKill)


def _timed_solve(ctx: "_RunCtx", layer: int, tapname: str, names,
                 solve_thunk):
    """Run one tap group's solve under a `leaf_solve` tracer span and
    extend each (qt, eb, ea, secs) row with a span-derived wall_seconds.

    With tracing on, the span blocks on the solved codes before closing,
    so its duration — split evenly across the group like dispatch secs —
    is true solve wall time. With tracing off the thunk runs bare and the
    walk stays exactly sync-free (wall 0.0 = unmeasured)."""
    if not ctx.tracer.enabled:
        results = solve_thunk()
        ctx.m_leaves.inc(len(results))
        return [r + (0.0,) for r in results]
    with ctx.tracer.span("leaf_solve", device=True, layer=layer,
                         tap=tapname, leaves=",".join(names)) as sp:
        results = solve_thunk()
        # comq: allow(host-sync) span wall time: tracing-on path only
        jax.block_until_ready([qt["codes"] for qt, *_ in results])
        wall = sp.elapsed_s / max(len(results), 1)
    ctx.m_leaves.inc(len(results))
    return [r + (wall,) for r in results]


def _tap_groups(lp, tapmap) -> Dict[str, List[Tuple[str, str]]]:
    """tapname -> [(mod, leaf), ...] for the leaves present in this layer."""
    groups: Dict[str, List[Tuple[str, str]]] = {}
    for (mod, leaf), tapname in tapmap.items():
        if mod not in lp or leaf not in lp[mod]:
            continue
        groups.setdefault(tapname, []).append((mod, leaf))
    return groups


def _gram_fns(mesh):
    """(gram_fn, batched_fn) for (B,T,d) and (E,C,d) taps. With a mesh the
    Gram reduces via shard_map + one psum over the "data" axis (expert taps
    fall back to the replicated Gram when the routed capacity doesn't
    divide the axis — see dist.calibrate)."""
    if mesh is None:
        return (lambda tap: calibrate.gram_from_tap(tap),
                lambda tap: calibrate.batched_gram(tap))
    from repro import dist
    return (lambda tap: dist.sharded_gram(mesh, tap),
            lambda tap: dist.sharded_batched_gram(mesh, tap))


def _group_specs(resolve, layer_idx: int, entries, prefix: str = ""):
    """Resolved per-leaf specs for one tap group, in entry order."""
    return [resolve(layer_idx, f"{prefix}{mod}.{leaf}")
            for mod, leaf in entries]


def _quantize_layer_leaves(lp, taps, tapmap, resolve, method: str,
                           pending: List[tuple], layer_idx: int,
                           gram_fn=None, batched_fn=None, prefix: str = "",
                           solve_sh=None, ctx: Optional[_RunCtx] = None):
    """Legacy-schedule body: quantize every mapped leaf of one layer from a
    pre-collected `taps` dict, grouped by activation tap (TapGramCache: one
    Gram per tap; fused solves when exact). `resolve(layer_idx, name)`
    supplies each leaf's QuantSpec (core/policy). Returns the layer params
    with QTensor leaves; appends per-leaf (idx, name, err, err, secs)
    records with the errors left on device (host floats when journaling)."""
    if ctx is None:
        ctx = _RunCtx(method)
    cache = calibrate.TapGramCache(gram_fn=gram_fn, batched_fn=batched_fn)
    groups = _tap_groups(lp, tapmap)

    lp_q = dict(lp)
    for tapname, entries in groups.items():
        ws = [lp[mod][leaf] for mod, leaf in entries]
        specs = _group_specs(resolve, layer_idx, entries, prefix)
        names = [f"{prefix}{mod}.{leaf}" for mod, leaf in entries]
        cached = ctx.lookup(layer_idx, names, specs)
        if cached is not None:
            for (mod, leaf), nm, (qt, rec) in zip(entries, names, cached):
                lp_q = _set_nested(lp_q, mod, leaf, qt)
                pending.append((layer_idx, nm, rec["err_before"],
                                rec["err_after"], 0.0, 0.0))
            continue
        ctx.fault("gram_accumulate")
        tap = ctx.sanitize_tap(ctx.poison_tap(taps[tapname]), layer_idx,
                               names)
        for _ in names:
            ctx.fault("leaf_solve")
        if tapname.startswith("expert"):
            hs = cache.batched(tapname, tap)
            results = _timed_solve(
                ctx, layer_idx, tapname, names,
                lambda: _solve_group_experts(ws, hs, specs, method,
                                             gctx=ctx.gctx, layer=layer_idx,
                                             names=names))
        else:
            h = cache.gram(tapname, tap)
            results = _timed_solve(
                ctx, layer_idx, tapname, names,
                lambda: _solve_group(ws, h, specs, method,
                                     solve_sh=solve_sh, gctx=ctx.gctx,
                                     layer=layer_idx, names=names))
        results = ctx.commit(layer_idx, names, specs, results)
        for (mod, leaf), nm, (qt, eb, ea, secs, wall) in zip(entries, names,
                                                             results):
            lp_q = _set_nested(lp_q, mod, leaf, qt)
            pending.append((layer_idx, nm, eb, ea, secs, wall))
    return lp_q


def _staged_cb(lp, groups, taps, resolve, method: str,
               pending: List[tuple], layer_idx: int, holder: dict,
               gram_fn, batched_fn, prefix: str = "", solve_sh=None,
               ctx: Optional[_RunCtx] = None):
    """The staged-schedule `quantize_cb`: invoked by the model's tap hooks
    mid-forward, right after tap `tapname` is recorded and before the
    weights it feeds are applied. Solves the tap's leaf group (each leaf
    under its resolved per-leaf spec), stashes the QTensors, and returns
    dequantized replacements so the rest of the forward runs on the
    quantized sub-blocks.

    On `--resume` the ctx journal lookup short-circuits the solve: the
    journaled QTensors are re-applied through this same callback, so the
    forward still propagates through the identical quantized sub-blocks
    and every downstream tap — and therefore every remaining solve — is
    bit-identical to the uninterrupted run."""
    if ctx is None:
        ctx = _RunCtx(method)

    def cb(tapname: str):
        entries = groups.get(tapname)
        if not entries:
            return {}
        ws = [lp[mod][leaf] for mod, leaf in entries]
        specs = _group_specs(resolve, layer_idx, entries, prefix)
        names = [f"{prefix}{mod}.{leaf}" for mod, leaf in entries]
        cached = ctx.lookup(layer_idx, names, specs)
        if cached is not None:
            repl = {}
            for (mod, leaf), nm, (qt, rec) in zip(entries, names, cached):
                holder["lp_q"] = _set_nested(holder["lp_q"], mod, leaf, qt)
                pending.append((layer_idx, nm, rec["err_before"],
                                rec["err_after"], 0.0, 0.0))
                repl[leaf] = dequant_qtensor(qt)
            return repl
        ctx.fault("gram_accumulate")
        tap = ctx.sanitize_tap(ctx.poison_tap(taps[tapname]), layer_idx,
                               names)
        for _ in names:
            ctx.fault("leaf_solve")
        if tapname.startswith("expert"):
            hs = batched_fn(tap)
            results = _timed_solve(
                ctx, layer_idx, tapname, names,
                lambda: _solve_group_experts(ws, hs, specs, method,
                                             gctx=ctx.gctx, layer=layer_idx,
                                             names=names))
        else:
            h = gram_fn(tap)
            results = _timed_solve(
                ctx, layer_idx, tapname, names,
                lambda: _solve_group(ws, h, specs, method,
                                     solve_sh=solve_sh, gctx=ctx.gctx,
                                     layer=layer_idx, names=names))
        results = ctx.commit(layer_idx, names, specs, results)
        repl = {}
        for (mod, leaf), nm, (qt, eb, ea, secs, wall) in zip(entries, names,
                                                             results):
            holder["lp_q"] = _set_nested(holder["lp_q"], mod, leaf, qt)
            pending.append((layer_idx, nm, eb, ea, secs, wall))
            repl[leaf] = dequant_qtensor(qt)
        return repl
    return cb


def _staged_ctx(lp, tapmap, resolve, method: str,
                pending: List[tuple], layer_idx: int, gram_fn, batched_fn,
                prefix: str = "", solve_sh=None,
                ctx: Optional[_RunCtx] = None):
    """(taps, holder, cb) for one staged layer walk — shared by the
    homogeneous, VLM-self, and VLM-cross paths so the callback protocol
    has a single definition."""
    taps: Dict[str, Array] = {}
    holder = {"lp_q": lp}
    cb = _staged_cb(lp, _tap_groups(lp, tapmap), taps, resolve, method,
                    pending, layer_idx, holder, gram_fn, batched_fn,
                    prefix=prefix, solve_sh=solve_sh, ctx=ctx)
    return taps, holder, cb


def _quantize_layer_staged(lp, x, state, cfg, plan, tapmap,
                           resolve, method: str,
                           pending: List[tuple], layer_idx: int,
                           gram_fn, batched_fn, solve_sh=None,
                           ctx: Optional[_RunCtx] = None):
    """Staged schedule: ONE `layer_full` evaluation quantizes the layer in
    tap order *and* propagates x through the quantized sub-blocks — every
    downstream tap is exact w.r.t. the quantized upstream. Returns
    (lp_q, new_x, new_state)."""
    taps, holder, cb = _staged_ctx(lp, tapmap, resolve, method, pending,
                                   layer_idx, gram_fn, batched_fn,
                                   solve_sh=solve_sh, ctx=ctx)
    rwkv_state = state if cfg.attn_free else None
    ssm_state = state if cfg.parallel_ssm_heads else None
    y, _, _, new_state = tfm.layer_full(lp, x, cfg, plan, False,
                                        rwkv_state=rwkv_state,
                                        ssm_state=ssm_state, taps=taps,
                                        quantize_cb=cb)
    return holder["lp_q"], y, new_state


def _finalize_report(report: "QuantReport", pending: List[tuple],
                     metrics=NULL_METRICS):
    """Materialize every accumulated on-device error scalar with a single
    batched transfer — the pipeline walk itself never blocks on the host.
    Per-leaf metrics (solve seconds, final errors) are observed here, on
    the already-host values — never mid-walk."""
    if not pending:
        return report
    errs = jnp.stack([jnp.stack([jnp.asarray(eb, jnp.float32),
                                 jnp.asarray(ea, jnp.float32)])
                      for (_, _, eb, ea, _, _) in pending])
    vals = jax.device_get(errs)  # comq: allow(host-sync) one batched pull at report finalize
    h_err = metrics.histogram("quant.leaf_err_after")
    h_disp = metrics.histogram("quant.leaf_dispatch_seconds")
    h_wall = metrics.histogram("quant.leaf_wall_seconds")
    for (li, name, _, _, secs, wall), (eb, ea) in zip(pending, vals):
        report.layers.append(LayerReport(li, name, float(eb), float(ea),
                                         secs, wall))
        h_err.observe(float(ea))
        h_disp.observe(secs)
        h_wall.observe(wall)
    return report


# ---------------------------------------------------------------------------
# the sequential pipeline
# ---------------------------------------------------------------------------

def _tree_slice(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _tree_set(tree, i, sub):
    return jax.tree_util.tree_map(lambda a, s: a.at[i].set(s), tree, sub)


@functools.lru_cache(maxsize=16)
def _legacy_layer_fn(cfg, plan):
    """Jitted two-forward-schedule layer evaluator, cached across
    quantize_model calls (cfg/plan are frozen dataclasses)."""
    return jax.jit(lambda lp, x, st: _layer_with_taps(lp, x, st, cfg, plan))


def quantize_model(params, cfg, plan, tokens: Array, spec,
                   method: str = "comq",
                   vision_embeds: Optional[Array] = None,
                   quantize_unembed: bool = False,
                   propagation: str = "staged",
                   mesh=None, *,
                   guards: bool = True,
                   journal=None,
                   resume: bool = False,
                   injector=None,
                   progress_cb: Optional[Callable[[int], None]] = None,
                   tracer=None,
                   metrics=None):
    """Quantize all projection weights of an LM. `tokens`: (B, T) calib batch.

    `spec` is either a global QuantSpec (every leaf gets it — bit-identical
    to the historical path) or a `core.policy.QuantPolicy` whose pattern
    rules / first-last overrides / budget-allocated assignments resolve a
    *per-leaf* spec (only the bit width varies; granularity/order/λ/sweeps
    are policy-wide). Fused shared-tap solves require identical resolved
    specs across the group; mixed-bit groups solve per leaf.

    propagation="staged" (default) runs exactly one layer forward per layer
    (leaves quantized mid-forward in tap order, downstream taps exact
    w.r.t. quantized upstream); "legacy" keeps the two-forward schedule
    for A/B. mesh (optional, with a "data" axis) shards the calibration
    batch data-parallel: each Gram block reduces with a single psum
    (repro.dist; DESIGN.md §4.2). A nontrivial "model" axis additionally
    shards every column-shardable leaf solve (per-channel comq_blocked /
    rtn — see _col_shardable; the gate depends only on policy-wide fields,
    so it is decided once and each leaf's sharded solve runs under its own
    resolved spec) over the mesh columns, bit-identical to the replicated
    solve with zero solve-time collectives (DESIGN.md §4.3); other methods
    keep replicated solves. With a multi-device "data" axis the MoE
    routing capacity is rounded up to it (BuildPlan.moe_capacity_multiple)
    so expert taps always take the Gram-psum path.

    Robustness plumbing (DESIGN.md §8), all optional:

    * guards=True runs the numeric-guard policy (core/guards): NaN/Inf
      sentinels at tap collection and per Gram/weight, dead-column
      counting, escalating damping and the solver fallback chain on
      failed solves. A healthy run takes the exact unguarded compute
      path (bit-identical); every intervention lands in
      QuantReport.guard_events and the leaf's LayerReport.guard.
    * journal (a directory or a ft.QuantJournal) makes the run
      crash-safe: every solved leaf is durably spilled (atomic packed
      file) and journaled; resume=True re-applies journaled leaves
      through the same quantize_cb instead of re-solving, producing
      bit-identical codes/scales to an uninterrupted run. A resume
      against a journal whose run digest (arch/policy/method/calib/mesh)
      differs raises ft.ResumeMismatch.
    * injector (ft.FaultInjector) arms the pipeline fault points
      (gram_accumulate / leaf_solve / ckpt_write / kill / nan_tap);
      progress_cb(layer) fires after each durably-journaled layer (the
      supervisor's progress signal, e.g. ft.Heartbeat.beat).
    * tracer (obs.Tracer) records layer / leaf_solve spans — with a
      tracer each tap group's span blocks on its solved codes so
      LayerReport.wall_seconds is true wall time; without one the walk
      stays sync-free. metrics (obs.MetricsRegistry) accumulates
      quant.* counters/histograms and, under a mesh, the
      dist.bytes_all_reduced counter. Both default to disabled null
      singletons with zero cost (DESIGN.md §10).

    Returns (qparams, QuantReport). qparams has QTensor leaves (each
    carrying its resolved bit width); use `dequantize_tree` (or the
    quantized serving path) to run it.
    """
    from repro.data import (check_calib_coverage, validate_calib_features,
                            validate_calib_tokens)
    from repro.models.model import embed_tokens
    if propagation not in ("staged", "legacy"):
        raise ValueError(f"unknown propagation {propagation!r}")
    policy = as_policy(spec)
    n_layers = cfg.n_layers

    def resolve(layer_idx: int, name: str) -> QuantSpec:
        return policy.resolve(name, layer_idx, n_layers)

    tok_host = np.asarray(jax.device_get(tokens))
    validate_calib_tokens(tok_host, vocab_size=cfg.vocab_size)
    if cfg.family == "vlm" and vision_embeds is not None:
        validate_calib_features(vision_embeds)
    check_calib_coverage(int(tok_host.shape[0]) * int(tok_host.shape[1]),
                         _calib_leaf_dims(cfg))

    # journal setup + resume decision — the run digest hashes the
    # *unsharded* calibration bytes, so replicated and resharded runs of
    # the same calibration agree on identity up to the mesh term
    qj: Optional[QuantJournal] = None
    own_journal = False
    solved: Dict[Tuple[int, str], Dict] = {}
    if journal is not None:
        own_journal = not isinstance(journal, QuantJournal)
        qj = QuantJournal(journal) if own_journal else journal
        digest = _run_digest(cfg, policy, method, propagation, tok_host,
                             quantize_unembed, mesh)
        st = QuantJournal.replay(qj.dir)
        if resume and st.run is not None:
            if int(st.run["run"]) != digest:
                if own_journal:
                    qj.close()
                raise ResumeMismatch(
                    f"journal {qj.dir} was written by run digest "
                    f"{st.run['run']}, current run digest is {digest} "
                    "(arch/policy/method/calibration/mesh changed) — "
                    "refusing to mix journaled leaves into a different run")
            solved = dict(st.leaves)
            qj.record_resume(len(solved))
        else:
            qj.record_run_start(digest, arch=cfg.name, method=method,
                                propagation=propagation,
                                n_layers=cfg.n_layers)

    gctx = GuardContext(enabled=guards)
    ctx = _RunCtx(method, gctx=gctx, journal=qj, solved=solved,
                  injector=injector, progress_cb=progress_cb,
                  tracer=tracer, metrics=metrics)

    t_start = time.time()
    report = QuantReport()
    pending: List[tuple] = []
    gram_fn, batched_fn = _gram_fns(mesh)
    # dist bytes-all-reduced accounting: install the counter hook for the
    # run's duration (shape-derived host ints, no device sync)
    dist_obs_prev = None
    dist_obs_set = False
    if mesh is not None and ctx.metrics.enabled:
        from repro.dist import calibrate as _dcal
        _c_bytes = ctx.metrics.counter("dist.bytes_all_reduced")
        dist_obs_prev = _dcal.set_allreduce_observer(_c_bytes.inc)
        dist_obs_set = True
    solve_sh = None
    if mesh is not None:
        from repro.dist import model_size, shard_batch, sharded_solve
        tokens = shard_batch(mesh, tokens)
        ndata = int(mesh.shape.get("data", 1))
        if ndata > 1 and cfg.moe is not None:
            # align routed-expert capacity so (E, C, d) taps divide the
            # data axis and never fall off the Gram-psum path
            plan = plan.replace(moe_capacity_multiple=ndata)
        if model_size(mesh) > 1 and _col_shardable(policy.base, method):
            solve_sh = functools.partial(sharded_solve, mesh, method=method)

    try:
        x = embed_tokens(params, cfg, plan, tokens)
        qparams = jax.tree_util.tree_map(lambda a: a, params)  # shallow copy
        tapmap = taps_for(cfg)

        if cfg.family == "vlm":
            qparams = _quantize_vlm(params, cfg, plan, x, resolve, method,
                                    vision_embeds, pending, propagation,
                                    gram_fn, batched_fn, solve_sh=solve_sh,
                                    ctx=ctx)
        else:
            init_states = None
            if cfg.attn_free:
                from repro.models.rwkv import init_rwkv_state
                init_states = init_rwkv_state(x.shape[0], cfg)
            elif cfg.parallel_ssm_heads:
                from repro.models.ssm import init_ssm_state
                init_states = init_ssm_state(x.shape[0], cfg)

            state = init_states
            if propagation == "legacy":
                layer_full_j = _legacy_layer_fn(cfg, plan)
                for l in range(cfg.n_layers):
                    with ctx.tracer.span("layer", layer=l,
                                         schedule="legacy"):
                        lp = _tree_slice(params["layers"], l)
                        _, taps, _ = layer_full_j(lp, x, state)
                        lp_q = _quantize_layer_leaves(
                            lp, taps, tapmap, resolve, method, pending, l,
                            gram_fn, batched_fn, solve_sh=solve_sh, ctx=ctx)
                        # propagate through the *quantized* layer
                        lp_deq = dequantize_tree(lp_q)
                        x, _, state = layer_full_j(lp_deq, x, state)
                        qparams = _store_layer(qparams, l, lp_q)
                    ctx.layer_done(l)
            else:
                for l in range(cfg.n_layers):
                    with ctx.tracer.span("layer", layer=l,
                                         schedule="staged"):
                        lp_q, x, state = _quantize_layer_staged(
                            _tree_slice(params["layers"], l), x, state,
                            cfg, plan, tapmap, resolve, method,
                            pending, l, gram_fn, batched_fn,
                            solve_sh=solve_sh, ctx=ctx)
                        qparams = _store_layer(qparams, l, lp_q)
                    ctx.layer_done(l)

            if quantize_unembed and "unembed" in params:
                names, specs = ["unembed"], [resolve(-1, "unembed")]
                cached = ctx.lookup(-1, names, specs)
                if cached is not None:
                    qt, rec = cached[0]
                    pending.append((-1, "unembed", rec["err_before"],
                                    rec["err_after"], 0.0, 0.0))
                else:
                    ctx.fault("gram_accumulate")
                    xn = ctx.sanitize_tap(
                        ctx.poison_tap(apply_norm(params["final_norm"], x,
                                                  cfg)), -1, names)
                    ctx.fault("leaf_solve")
                    h = gram_fn(xn)
                    results = _timed_solve(
                        ctx, -1, "unembed_in", names,
                        lambda: _solve_group([params["unembed"]], h, specs,
                                             method, solve_sh=solve_sh,
                                             gctx=ctx.gctx, layer=-1,
                                             names=names))
                    qt, eb, ea, secs, wall = ctx.commit(-1, names, specs,
                                                        results)[0]
                    pending.append((-1, "unembed", eb, ea, secs, wall))
                qparams["unembed"] = qt
        if qj is not None:
            qj.record_run_done()
    finally:
        if own_journal and qj is not None:
            qj.close()
        if dist_obs_set:
            _dcal.set_allreduce_observer(dist_obs_prev)

    _finalize_report(report, pending, metrics=ctx.metrics)
    report.wall_seconds = time.time() - t_start
    report.guard_events = list(gctx.events)
    report.resumed_leaves = ctx.resumed
    ctx.metrics.counter("quant.guard_events").inc(len(report.guard_events))
    ctx.metrics.counter("quant.resumed_leaves").inc(ctx.resumed)
    gmap = gctx.by_leaf()
    if gmap:
        for lr in report.layers:
            lr.guard = gmap.get((lr.layer, lr.name), "")
    return qparams, report


def _set_nested(lp, mod, leaf, value):
    lp = dict(lp)
    lp[mod] = dict(lp[mod])
    lp[mod][leaf] = value
    return lp


def _store_layer(qparams, l, lp_q):
    """Store per-layer QTensors under a side table (stacked storage would
    force all layers to share scales)."""
    qparams = dict(qparams)
    table = dict(qparams.get("__qlayers__", {}))
    table[str(l)] = lp_q
    qparams["__qlayers__"] = table
    return qparams


def _layer_with_taps(lp, x, state, cfg, plan):
    taps: Dict[str, Array] = {}
    rwkv_state = state if cfg.attn_free else None
    ssm_state = state if cfg.parallel_ssm_heads else None
    y, _, _, new_state = tfm.layer_full(lp, x, cfg, plan, False,
                                        rwkv_state=rwkv_state,
                                        ssm_state=ssm_state, taps=taps)
    return y, taps, new_state


def _quantize_vlm(params, cfg, plan, x, resolve, method, vision_embeds,
                  pending, propagation, gram_fn, batched_fn, solve_sh=None,
                  ctx: Optional[_RunCtx] = None):
    from repro.models.model import _vlm_group_counts
    if ctx is None:
        ctx = _RunCtx(method)
    g, spg = _vlm_group_counts(cfg)
    cd = x.dtype
    ve = jnp.einsum("bnv,vd->bnd", vision_embeds.astype(cd),
                    params["vision_proj"].astype(cd))
    qparams = dict(params)
    table = {}
    staged = propagation == "staged"
    for gi in range(g):
        for si in range(spg):
            lp = _tree_slice(_tree_slice(params["groups"]["self"], gi), si)
            lidx = gi * (spg + 1) + si
            if staged:
                lp_q, x, _ = _quantize_layer_staged(
                    lp, x, None, cfg, plan, DENSE_TAPS, resolve, method,
                    pending, lidx, gram_fn, batched_fn, solve_sh=solve_sh,
                    ctx=ctx)
            else:
                taps: Dict[str, Array] = {}
                y, _, _, _ = tfm.layer_full(lp, x, cfg, plan, False,
                                            taps=taps)
                lp_q = _quantize_layer_leaves(lp, taps, DENSE_TAPS, resolve,
                                              method, pending, lidx,
                                              gram_fn, batched_fn,
                                              solve_sh=solve_sh, ctx=ctx)
                x, _, _, _ = tfm.layer_full(dequantize_tree(lp_q), x, cfg,
                                            plan, False)
            table[f"self_{gi}_{si}"] = lp_q
            ctx.layer_done(lidx)
        cp = _tree_slice(params["groups"]["cross"], gi)
        vkv = tfm.vision_kv_for_layer(cp, ve)
        lidx = gi * (spg + 1) + spg
        if staged:
            taps, holder, cb = _staged_ctx(cp, CROSS_TAPS, resolve, method,
                                           pending, lidx, gram_fn,
                                           batched_fn, prefix="cross.",
                                           solve_sh=solve_sh, ctx=ctx)
            x = tfm.cross_layer_full(cp, x, cfg, plan, vkv, taps=taps,
                                     quantize_cb=cb)
            cp_q = holder["lp_q"]
        else:
            taps = {}
            _ = tfm.cross_layer_full(cp, x, cfg, plan, vkv, taps=taps)
            cp_q = _quantize_layer_leaves(cp, taps, CROSS_TAPS, resolve,
                                          method, pending, lidx, gram_fn,
                                          batched_fn, prefix="cross.",
                                          solve_sh=solve_sh, ctx=ctx)
            x = tfm.cross_layer_full(dequantize_tree(cp_q), x, cfg, plan,
                                     vkv)
        table[f"cross_{gi}"] = cp_q
        ctx.layer_done(lidx)
    qparams["__qlayers__"] = table
    return qparams


# ---------------------------------------------------------------------------
# materialize a runnable dequantized model
# ---------------------------------------------------------------------------

def materialize(qparams, cfg) -> Any:
    """Fold the __qlayers__ side table back into stacked dense params."""
    params = {k: v for k, v in qparams.items() if k != "__qlayers__"}
    table = qparams.get("__qlayers__", {})
    if not table:
        return params
    if cfg.family == "vlm":
        from repro.models.model import _vlm_group_counts
        g, spg = _vlm_group_counts(cfg)
        if "groups" in params:
            self_p = params["groups"]["self"]
            cross_p = params["groups"]["cross"]
            for gi in range(g):
                for si in range(spg):
                    deq = dequantize_tree(table[f"self_{gi}_{si}"])
                    self_p = jax.tree_util.tree_map(
                        lambda a, s: a.at[gi, si].set(s.astype(a.dtype)),
                        self_p, deq)
                deq = dequantize_tree(table[f"cross_{gi}"])
                cross_p = jax.tree_util.tree_map(
                    lambda a, s: a.at[gi].set(s.astype(a.dtype)),
                    cross_p, deq)
        else:
            # stripped checkpoint (ckpt.strip_for_serving): rebuild the
            # (G, spg, ...) / (G, ...) stacks from the table
            self_rows = [
                jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs),
                    *[dequantize_tree(table[f"self_{gi}_{si}"])
                      for si in range(spg)])
                for gi in range(g)]
            self_p = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                            *self_rows)
            cross_p = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[dequantize_tree(table[f"cross_{gi}"]) for gi in range(g)])
        params = dict(params)
        params["groups"] = {"self": self_p, "cross": cross_p}
        return params
    if "layers" in params:
        layers = params["layers"]
        for key, lp_q in table.items():
            l = int(key)
            deq = dequantize_tree(lp_q)
            layers = jax.tree_util.tree_map(
                lambda a, s: a.at[l].set(s.astype(a.dtype)), layers, deq)
    else:
        # stripped checkpoint (ckpt.strip_for_serving): rebuild the stack
        # from the table (it carries every per-layer leaf, dense included)
        per = [dequantize_tree(table[k]) for k in sorted(table, key=int)]
        layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
    params = dict(params)
    params["layers"] = layers
    if is_qtensor(params.get("unembed", None)):
        params["unembed"] = dequant_qtensor(params["unembed"])
    return params
