"""COMQ — coordinate-wise minimization of ‖X W_q − X W‖² (paper §3).

This module is the *paper-faithful X-space solver*: it carries the residual
U = X(W − W_q) in sample space and performs the vectorized row updates of
eq. (6) (per-layer, Alg. 1) / eq. (9) (per-channel, Alg. 2), including the
float initialization Q⁰ = W/δ⁰ ("becomes feasible after the 1st iteration")
and the closed-form δ-updates eq. (7)/(10).

Greedy order (§3.3) is exact and *per-column*: coordinates are visited in
descending ‖w_i x_i‖ = ‖x_i‖·|w_i| order, realized with per-step gathers of
X columns so all output columns still update in lockstep. Cyclic order is
the index order. See core/comq_hessian.py for the H-space/blocked solvers
used at scale (bit-identical, tested).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import (EPS, QuantSpec, dequantize, init_per_channel,
                                  init_per_layer)

Array = jax.Array


@dataclass
class QuantResult:
    q: Array            # (m, n) int32 bit-codes in [z_lo, z_hi]
    delta: Array        # scalar (per-layer) or (n,) (per-channel)
    z_lo: Array
    z_hi: Array
    errors: Array       # (sweeps+1,) ‖X(W − W_q)‖ trajectory

    @property
    def w_q(self) -> Array:
        return self.q.astype(jnp.float32) * self.delta


# ---------------------------------------------------------------------------
# update orders
# ---------------------------------------------------------------------------

def make_orders(order: str, x_col_norms: Array, w: Array) -> Array:
    """Returns (m, n) int32: orders[t, j] = coordinate visited at step t in
    column j. Greedy = descending ‖x_i‖·|w_ij| (paper §3.3)."""
    m, n = w.shape
    if order == "cyclic":
        return jnp.broadcast_to(jnp.arange(m)[:, None], (m, n))
    if order == "greedy":
        keys = x_col_norms[:, None] * jnp.abs(w)          # (m, n)
        return jnp.argsort(-keys, axis=0).astype(jnp.int32)
    if order == "greedy_shared":
        keys = x_col_norms * jnp.linalg.norm(w, axis=1)   # (m,) row norms
        shared = jnp.argsort(-keys).astype(jnp.int32)
        return jnp.broadcast_to(shared[:, None], (m, n))
    raise ValueError(f"unknown order {order!r}")


# ---------------------------------------------------------------------------
# the coordinate-descent sweep (shared by per-layer / per-channel)
# ---------------------------------------------------------------------------

def _sweep(x: Array, u: Array, qf: Array, delta: Array, z_lo, z_hi,
           orders: Array, xsq: Array):
    """One full pass over all m coordinates (rows), vectorized over columns.

    u: (N, n) residual X(W − δ·Q); qf: (m, n) codes (float during sweep 1).
    delta/z_lo/z_hi: scalar or (n,). Returns updated (u, qf)."""
    m, n = qf.shape
    cols = jnp.arange(n)

    def step(t, carry):
        u, qf = carry
        idx = orders[t]                                   # (n,)
        xg = x[:, idx]                                    # (N, n) gather
        qg = qf[idx, cols]                                # (n,)
        xsq_g = xsq[idx]                                  # (n,)
        denom = delta * xsq_g
        # ⟨x_i, s_i⟩ / (δ‖x_i‖²) = ⟨x_i, u_j⟩/(δ‖x_i‖²) + q_old
        ratio = jnp.sum(xg * u, axis=0) / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg),
                         z_lo.astype(jnp.float32), z_hi.astype(jnp.float32))
        q_new = jnp.where(xsq_g > EPS, q_new,
                          jnp.clip(jnp.round(qg), z_lo.astype(jnp.float32),
                                   z_hi.astype(jnp.float32)))
        du = (q_new - qg) * delta                         # (n,)
        u = u - xg * du[None, :]
        qf = qf.at[idx, cols].set(q_new)
        return u, qf

    return jax.lax.fori_loop(0, m, step, (u, qf))


def _delta_update_per_layer(x: Array, w: Array, qf: Array) -> Array:
    xq = x @ qf
    num = jnp.vdot(xq, x @ w)
    den = jnp.vdot(xq, xq)
    return jnp.where(den > EPS, num / den, 1.0)           # eq. (7)


def _delta_update_per_channel(x: Array, w: Array, qf: Array) -> Array:
    xq = x @ qf                                           # (N, n)
    xw = x @ w
    num = jnp.sum(xq * xw, axis=0)
    den = jnp.sum(xq * xq, axis=0)
    return jnp.where(den > EPS, num / den, 1.0)           # eq. (10)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def _comq_x_core(x: Array, w: Array, *, spec: QuantSpec):
    m, n = w.shape
    if spec.granularity == "per_layer":
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)

    xsq = jnp.sum(x * x, axis=0)                          # ‖x_i‖² (m,)
    orders = make_orders(spec.order, jnp.sqrt(xsq), w)

    qf = w / delta                                        # float Q⁰ = W/δ⁰
    xw = x @ w
    errs = [jnp.linalg.norm(xw - x @ (qf * delta))]

    for _ in range(spec.sweeps):
        u = xw - x @ (qf * delta)                         # U₀ = X(W − δQ)
        u, qf = _sweep(x, u, qf, delta, z_lo, z_hi, orders, xsq)
        if spec.granularity == "per_layer":
            delta = _delta_update_per_layer(x, w, qf)
        else:
            delta = _delta_update_per_channel(x, w, qf)
        errs.append(jnp.linalg.norm(xw - x @ (qf * delta)))

    q = jnp.clip(jnp.round(qf), z_lo, z_hi).astype(jnp.int32)
    return q, delta, z_lo, z_hi, jnp.stack(errs)


_comq_x_jit = jax.jit(_comq_x_core, static_argnames=("spec",))


def comq_quantize(x: Array, w: Array, spec: QuantSpec) -> QuantResult:
    """Quantize one linear layer's weight w: (m, n) given features x: (N, m).

    Follows Alg. 1 (per-layer) / Alg. 2 (per-channel) with K = spec.sweeps.
    The multi-sweep solve runs as one jitted program per (shape, spec).
    """
    q, delta, z_lo, z_hi, errs = _comq_x_jit(
        x.astype(jnp.float32), w.astype(jnp.float32), spec=spec)
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi, errors=errs)
