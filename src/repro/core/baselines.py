"""Baselines the paper compares against (§4.2): RTN and a GPTQ/OBQ-style
Hessian solver. Both share COMQ's grid initialization so comparisons
isolate the *solver*, as in the paper's tables.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.comq import QuantResult
from repro.core.comq_hessian import _h_error, gram
from repro.core.guards import damped_inverse
from repro.core.quantizer import (EPS, QuantSpec, init_per_channel,
                                  init_per_layer, quantize_rtn)

Array = jax.Array


def rtn_quantize(w: Array, spec: QuantSpec,
                 h: Optional[Array] = None) -> QuantResult:
    """Round-to-nearest onto the COMQ grid (no data)."""
    w = w.astype(jnp.float32)
    if spec.granularity == "per_layer":
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)
    q = quantize_rtn(w, delta, z_lo, z_hi)
    err = (_h_error(h, w, q.astype(jnp.float32) * delta)
           if h is not None else jnp.float32(0.0))
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi,
                       errors=jnp.stack([err]))


def gptq_quantize(h: Array, w: Array, spec: QuantSpec,
                  damping: float = 0.01) -> QuantResult:
    """GPTQ/OBQ baseline (Frantar & Alistarh): sequential rounding over the
    input dimension with OBS error propagation through H⁻¹ (Cholesky form).

    Unlike COMQ this needs the Hessian inverse and uses a *fixed* grid (no
    δ-updates) — the paper's Tab. 4/9 comparison point.
    """
    h = h.astype(jnp.float32)
    w = w.astype(jnp.float32)
    m, n = w.shape
    if spec.granularity == "per_layer":
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)

    # revive dead features, then invert under the shared escalating
    # damping (core/guards.damped_inverse — same helper the COMQ guard
    # chain uses): the first attempt is the historical fixed
    # `damping · mean(diag)` and only an ill-conditioned H escalates,
    # so well-posed solves are unchanged. H-space errors keep the
    # first-attempt damped H so reported errors match the pre-guard ones.
    diag = jnp.diag(h)
    dead = diag <= EPS
    h = h + jnp.diag(jnp.where(dead, 1.0, 0.0))
    hinv, _ = damped_inverse(h, start=damping, diag_mean=jnp.mean(diag))
    h = h + jnp.eye(m) * damping * jnp.mean(diag)

    w0 = w

    def step(i, carry):
        w, q = carry
        wi = w[i]                                          # (n,)
        qi = jnp.clip(jnp.round(wi / delta),
                      z_lo.astype(jnp.float32), z_hi.astype(jnp.float32))
        err = (wi - qi * delta) / hinv[i, i]
        # propagate to not-yet-quantized rows (> i); rows <= i are frozen
        rows = jnp.arange(m)
        mask = (rows > i).astype(jnp.float32)[:, None]
        w = w - mask * hinv[:, i][:, None] * err[None, :]
        q = q.at[i].set(qi)
        return w, q

    _, qf = jax.lax.fori_loop(0, m, step, (w, jnp.zeros_like(w)))
    q = qf.astype(jnp.int32)
    err = _h_error(h, w0, qf * delta)
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi,
                       errors=jnp.stack([err]))
