"""COMQ core: the paper's contribution as a composable JAX module."""
from repro.core.baselines import gptq_quantize, rtn_quantize  # noqa: F401
from repro.core.comq import QuantResult, comq_quantize, make_orders  # noqa: F401
from repro.core.comq_hessian import (comq_quantize_blocked,  # noqa: F401
                                     comq_quantize_h, gram)
from repro.core.apply import serving_params  # noqa: F401
from repro.core.guards import (GuardContext, GuardEvent,  # noqa: F401
                               damp_hessian, damped_inverse, guarded_solve)
from repro.core.pipeline import (QuantReport, dequantize_tree,  # noqa: F401
                                 materialize, quantize_model)
from repro.core.policy import (QuantPolicy, allocate_bits,  # noqa: F401
                               as_policy, measure_bit_curves, parse_policy,
                               policy_from_budget)
from repro.core.quantizer import QuantSpec  # noqa: F401
