"""COMQ in Gram/Hessian space — the at-scale solvers (DESIGN.md §3).

Every COMQ quantity is a function of H = XᵀX (m×m) and W only:

    ⟨x_i, s_ij⟩            = (H·R)_ij + (W_q)_ij · H_ii ,  R = W − W_q
    ‖x_i‖²                 = H_ii
    δ-update numerators     ⟨Xq_j, Xw_j⟩ = q_jᵀ H w_j
    greedy keys            ‖x_i‖·|w_ij| = √H_ii · |w_ij|

so the solve never touches the N×m calibration features after a single
accumulation pass. Two implementations:

* `comq_quantize_h`   — row-at-a-time, supports exact per-column greedy
  order (gather-based), bit-identical to the X-space solver.
* `comq_quantize_blocked` — panel/blocked updates with a *trailing-update*
  schedule (DESIGN.md §3.3): the product P = H·R is maintained across the
  whole solve and each solved panel contributes one rank-B dense matmul
  `P -= H[:, blk] @ ΔW_blk` (MXU work) — no per-panel residual
  materialization, no per-sweep H·R refresh. With HW = H·W precomputed
  once, the δ-updates and error evaluations are elementwise reads of the
  maintained P, eliminating their per-sweep (m, m)·(m, n) matmuls too.
  The intra-panel sequential sweep touches only H[blk,blk] + the Q panel
  (VMEM-resident in the Pallas kernel `kernels/comq_panel.py`). Shared-order
  only — the panel structure requires all columns to visit rows in the same
  order. Exactly equals the row-at-a-time solver under the same shared
  order (tested). `schedule="refresh"` keeps the legacy per-panel-refresh
  schedule for A/B benchmarking (benchmarks/runtime_compare.py).

Both solvers run as a single jitted program per (shape, spec) — the multi-
sweep driver is `jax.jit`-compiled with the permuted/padded operands donated
on accelerator backends, so per-leaf solves in the whole-model pipeline pay
one dispatch instead of eager op-by-op dispatch.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comq import QuantResult, make_orders
from repro.core.quantizer import (EPS, QuantSpec, init_per_channel,
                                  init_per_layer)

Array = jax.Array


def gram(x: Array) -> Array:
    x = x.astype(jnp.float32)
    return x.T @ x


def _h_error(h: Array, w: Array, wq: Array) -> Array:
    """‖X(W − W_q)‖ from H: sqrt(tr(RᵀHR))."""
    r = w - wq
    val = jnp.sum(r * (h @ r))
    return jnp.sqrt(jnp.maximum(val, 0.0))


def _delta_update_h(h: Array, w: Array, qf: Array, per_layer: bool) -> Array:
    hq = h @ qf
    if per_layer:
        num = jnp.sum(qf * (h @ w))
        den = jnp.sum(qf * hq)
        return jnp.where(den > EPS, num / den, 1.0)
    num = jnp.sum(qf * (h @ w), axis=0)
    den = jnp.sum(qf * hq, axis=0)
    return jnp.where(den > EPS, num / den, 1.0)


# ---------------------------------------------------------------------------
# row-at-a-time H-space sweep (exact per-column greedy supported)
# ---------------------------------------------------------------------------

def _sweep_h(h: Array, p: Array, qf: Array, delta: Array, z_lo, z_hi,
             orders: Array, hdiag: Array):
    """p: (m, n) maintained product H·R with R = W − δ·Q."""
    m, n = qf.shape
    cols = jnp.arange(n)

    def step(t, carry):
        p, qf = carry
        idx = orders[t]                                   # (n,)
        qg = qf[idx, cols]
        hg = hdiag[idx]
        denom = delta * hg
        ratio = p[idx, cols] / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg),
                         z_lo.astype(jnp.float32), z_hi.astype(jnp.float32))
        q_new = jnp.where(hg > EPS, q_new,
                          jnp.clip(jnp.round(qg), z_lo.astype(jnp.float32),
                                   z_hi.astype(jnp.float32)))
        du = (q_new - qg) * delta                         # ΔW_q row entries
        p = p - h[:, idx] * du[None, :]                   # rank-1 per column
        qf = qf.at[idx, cols].set(q_new)
        return p, qf

    return jax.lax.fori_loop(0, m, step, (p, qf))


def _comq_h_core(h: Array, w: Array, *, spec: QuantSpec):
    m, n = w.shape
    per_layer = spec.granularity == "per_layer"
    if per_layer:
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)

    hdiag = jnp.diag(h)
    orders = make_orders(spec.order, jnp.sqrt(hdiag), w)
    qf = w / delta
    errs = [_h_error(h, w, qf * delta)]

    for _ in range(spec.sweeps):
        p = h @ (w - qf * delta)                          # H·R
        p, qf = _sweep_h(h, p, qf, delta, z_lo, z_hi, orders, hdiag)
        delta = _delta_update_h(h, w, qf, per_layer)
        errs.append(_h_error(h, w, qf * delta))

    q = jnp.clip(jnp.round(qf), z_lo, z_hi).astype(jnp.int32)
    return q, delta, z_lo, z_hi, jnp.stack(errs)


_comq_h_jit = partial(jax.jit, static_argnames=("spec",))(_comq_h_core)


def comq_quantize_h(h: Array, w: Array, spec: QuantSpec,
                    x_for_error: Optional[Array] = None) -> QuantResult:
    """H-space COMQ. `h` = XᵀX. Bit-identical to comq.comq_quantize.

    The whole multi-sweep solve runs as one jitted program (cached per
    shape and spec), so repeated per-leaf solves pay a single dispatch."""
    q, delta, z_lo, z_hi, errs = _comq_h_jit(
        h.astype(jnp.float32), w.astype(jnp.float32), spec=spec)
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi, errors=errs)


# ---------------------------------------------------------------------------
# blocked / panel solver (the TPU-shaped schedule; shared order only)
# ---------------------------------------------------------------------------

def shared_order(h: Array, w: Array, spec: QuantSpec) -> Array:
    """The (m,) shared visit order the blocked solver would derive for
    (h, w). Exposed so the column-sharded solve can compute it once on the
    replicated full weight and pass it through `perm=` — the order is the
    only column-coupled solver quantity (DESIGN.md §4.3)."""
    order_name = {"greedy": "greedy_shared"}.get(spec.order, spec.order)
    return make_orders(order_name, jnp.sqrt(jnp.diag(h)),
                       w.astype(jnp.float32))[:, 0]

def panel_sweep_ref(h_bb: Array, s0: Array, qf_b: Array, delta: Array,
                    z_lo, z_hi, hdiag_b: Array):
    """Reference intra-panel sweep (the Pallas kernel's oracle)."""
    qf_b, _ = panel_sweep_dq_ref(h_bb, s0, qf_b, delta, z_lo, z_hi, hdiag_b)
    return qf_b


def panel_sweep_dq_ref(h_bb: Array, s0: Array, qf_b: Array, delta: Array,
                       z_lo, z_hi, hdiag_b: Array):
    """Reference intra-panel sweep emitting the scaled code delta (the
    Pallas kernel's oracle, kernels/comq_panel.py::comq_panel_dq_pallas).

    h_bb: (B, B) block of H; s0: (B, n) = (H·R)[blk] before the panel;
    qf_b: (B, n) panel codes. Returns (qf_b', ΔW) with ΔW = (qf_b' − qf_b)·δ
    so the caller's trailing update is a single dense matmul.

    The sweep is *lazy*: instead of eagerly rank-1-updating all B rows of S
    after every step (B·n writes per step), it accumulates the scaled deltas
    ΔW and materializes each step's row as one (1×B)·(B×n) matvec
    s_t = s0[t] − h_bb[t, :]·ΔW — same FLOPs, a fraction of the memory
    traffic (n writes per step), and ΔW falls out for free."""
    B = qf_b.shape[0]

    def step(t, carry):
        qf_b, du = carry
        qg = qf_b[t]
        hg = hdiag_b[t]
        st = s0[t] - h_bb[t, :] @ du          # rows ≥ t of du are still 0
        denom = delta * hg
        ratio = st / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg),
                         z_lo.astype(jnp.float32), z_hi.astype(jnp.float32))
        q_new = jnp.where(hg > EPS, q_new,
                          jnp.clip(jnp.round(qg), z_lo.astype(jnp.float32),
                                   z_hi.astype(jnp.float32)))
        du = du.at[t].set((q_new - qg) * delta)
        qf_b = qf_b.at[t].set(q_new)
        return qf_b, du

    return jax.lax.fori_loop(0, B, step, (qf_b, jnp.zeros_like(qf_b)))


def _panel_and_dq(panel_fn, h_bb, s0, qf_b, delta, z_lo, z_hi, hd_b):
    """Normalize panel_fn output to (qf_b', ΔW): fused kernels return the
    scaled delta directly; legacy single-output panel_fns get it computed
    here (one extra elementwise pass over the panel)."""
    out = panel_fn(h_bb, s0, qf_b, delta, z_lo, z_hi, hd_b)
    if isinstance(out, tuple):
        return out
    return out, (out - qf_b) * delta


def _blocked_core(hp: Array, wp: Array, hdiag: Array, delta, z_lo, z_hi, *,
                  spec: QuantSpec, m: int, block: int, panel_fn, schedule: str):
    """Jitted multi-sweep blocked solve over permuted/padded operands.

    trailing (default): P = H·R is maintained exactly across sweeps — each
    panel solve is followed by one rank-B dense matmul P -= H[:, blk] @ ΔW.
    Between sweeps, H·Q is recovered elementwise from (HW − P)/δ so the
    δ-update and the error trajectory cost no matmuls at all.

    refresh: the legacy schedule — every panel recomputes the full residual
    product s0 = H[blk, :]·(W − δQ), and δ-updates/errors each pay another
    (m, m)·(m, n) matmul per sweep. Kept for A/B benchmarking.
    """
    per_layer = spec.granularity == "per_layer"
    m_pad, n = wp.shape
    B = block
    n_blocks = m_pad // B
    qf = wp / delta

    if schedule == "trailing":
        hw = hp @ wp                                       # H·W, once
        p = hp @ (wp - qf * delta)                         # P⁰ = H·R⁰

        def h_err(p, qf, delta):
            # ‖XR‖ = sqrt(tr(RᵀHR)) = sqrt(Σ R⊙P); padded rows of H are
            # zero, so P's padded rows vanish and the sum is exact.
            r = wp - qf * delta
            return jnp.sqrt(jnp.maximum(jnp.sum(r * p), 0.0))

        errs = [h_err(p, qf, delta)]
        for _ in range(spec.sweeps):
            def body(b, carry):
                p, qf = carry
                s0 = jax.lax.dynamic_slice(p, (b * B, 0), (B, n))
                h_cols = jax.lax.dynamic_slice(hp, (0, b * B), (m_pad, B))
                h_bb = jax.lax.dynamic_slice(h_cols, (b * B, 0), (B, B))
                qf_b = jax.lax.dynamic_slice(qf, (b * B, 0), (B, n))
                hd_b = jax.lax.dynamic_slice(hdiag, (b * B,), (B,))
                qf_b, dq = _panel_and_dq(panel_fn, h_bb, s0, qf_b, delta,
                                         z_lo, z_hi, hd_b)
                p = p - h_cols @ dq                        # rank-B trailing
                qf = jax.lax.dynamic_update_slice(qf, qf_b, (b * B, 0))
                return p, qf

            p, qf = jax.lax.fori_loop(0, n_blocks, body, (p, qf))
            # δ-update from the maintained P: H·Q = (HW − P)/δ, elementwise
            safe = jnp.where(jnp.abs(delta) > EPS, delta, 1.0)
            hq = (hw - p) / safe
            if per_layer:
                num = jnp.sum(qf * hw)
                den = jnp.sum(qf * hq)
            else:
                num = jnp.sum(qf * hw, axis=0)
                den = jnp.sum(qf * hq, axis=0)
            delta = jnp.where(den > EPS, num / den, 1.0)
            p = hw - delta * hq                            # rescale P to δ'
            errs.append(h_err(p, qf, delta))
    elif schedule == "refresh":
        errs = [_h_error(hp[:m, :m], wp[:m], (qf * delta)[:m])]
        for _ in range(spec.sweeps):
            def body(b, qf):
                r = wp - qf * delta
                h_rows = jax.lax.dynamic_slice(hp, (b * B, 0), (B, m_pad))
                s0 = h_rows @ r                            # (B, n) MXU
                h_bb = jax.lax.dynamic_slice(h_rows, (0, b * B), (B, B))
                qf_b = jax.lax.dynamic_slice(qf, (b * B, 0), (B, n))
                hd_b = jax.lax.dynamic_slice(hdiag, (b * B,), (B,))
                qf_b, _ = _panel_and_dq(panel_fn, h_bb, s0, qf_b, delta,
                                        z_lo, z_hi, hd_b)
                return jax.lax.dynamic_update_slice(qf, qf_b, (b * B, 0))

            qf = jax.lax.fori_loop(0, n_blocks, body, qf)
            delta = _delta_update_h(hp[:m, :m], wp[:m], qf[:m], per_layer)
            errs.append(_h_error(hp[:m, :m], wp[:m], (qf * delta)[:m]))
    else:
        raise ValueError(f"unknown schedule {schedule!r}")

    # return the full padded float codes: the caller rounds/clips/slices
    # outside the jit, and the (m_pad, n) output is what lets the donated
    # wp buffer alias in place (int32 q in here could alias nothing — the
    # donation audit in repro.analysis caught exactly that)
    return qf, delta, jnp.stack(errs)


_BLOCK_STATICS = ("spec", "m", "block", "panel_fn", "schedule")
_blocked_jit = partial(jax.jit, static_argnames=_BLOCK_STATICS)(_blocked_core)
# donate the operands that genuinely alias an output: wp -> the returned
# (m_pad, n) float codes, delta -> the updated delta. hp/hdiag alias nothing
# (donating them is silently dropped by JAX — audited in analysis/registry);
# the audit contract for this entry point is donated={1, 3}
_blocked_jit_donate = partial(jax.jit, static_argnames=_BLOCK_STATICS,
                              donate_argnums=(1, 3))(_blocked_core)


def comq_quantize_blocked(h: Array, w: Array, spec: QuantSpec,
                          block: int = 256, panel_fn=None,
                          schedule: str = "trailing",
                          perm: Optional[Array] = None) -> QuantResult:
    """Blocked COMQ: cyclic or shared-greedy order. `panel_fn` defaults to
    the pure-jnp fused panel sweep; the launcher swaps in the Pallas kernel
    (kernels/comq_panel.py::panel_fn_dq_interpret or the compiled variant).

    `schedule` picks the cross-panel update strategy ("trailing" maintains
    P = H·R with rank-B updates; "refresh" recomputes it per panel — see
    DESIGN.md §3.3 for the FLOP accounting). Both produce identical codes.

    `perm` optionally supplies the shared (m,) visit order. The shared
    greedy order is the only solver quantity coupled across *columns* of W;
    precomputing it from the full weight makes every remaining operand
    column-offset-invariant, which is what lets the column-sharded solve
    (repro.dist.sharded_solve, DESIGN.md §4.3) run each shard on its column
    slice bit-identically to the replicated solve.
    """
    h = h.astype(jnp.float32)
    w = w.astype(jnp.float32)
    m, n = w.shape
    per_layer = spec.granularity == "per_layer"
    if per_layer:
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)

    hdiag0 = jnp.diag(h)
    if perm is None:
        order_name = {"greedy": "greedy_shared"}.get(spec.order, spec.order)
        perm = make_orders(order_name, jnp.sqrt(hdiag0), w)[:, 0]  # (m,)
    inv_perm = jnp.argsort(perm)
    hp = h[perm][:, perm]
    wp = w[perm]
    hdiag = jnp.diag(hp)
    panel_fn = panel_fn or panel_sweep_dq_ref

    # pad rows to a multiple of the panel size (H rows padded with zeros:
    # zero-diagonal rows keep their code — no effect on real rows)
    B = min(block, m)
    m_pad = ((m + B - 1) // B) * B
    if m_pad != m:
        hp = jnp.pad(hp, ((0, m_pad - m), (0, m_pad - m)))
        wp = jnp.pad(wp, ((0, m_pad - m), (0, 0)))
        hdiag = jnp.pad(hdiag, (0, m_pad - m))

    qf, delta, errs = _blocked_jit_donate(
        hp, wp, hdiag, delta, z_lo, z_hi, spec=spec, m=m, block=B,
        panel_fn=panel_fn, schedule=schedule)
    q = jnp.clip(jnp.round(qf[:m]), z_lo, z_hi).astype(jnp.int32)
    q = q[inv_perm]
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi, errors=errs)
