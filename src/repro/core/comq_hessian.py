"""COMQ in Gram/Hessian space — the at-scale solvers (DESIGN.md §3).

Every COMQ quantity is a function of H = XᵀX (m×m) and W only:

    ⟨x_i, s_ij⟩            = (H·R)_ij + (W_q)_ij · H_ii ,  R = W − W_q
    ‖x_i‖²                 = H_ii
    δ-update numerators     ⟨Xq_j, Xw_j⟩ = q_jᵀ H w_j
    greedy keys            ‖x_i‖·|w_ij| = √H_ii · |w_ij|

so the solve never touches the N×m calibration features after a single
accumulation pass. Two implementations:

* `comq_quantize_h`   — row-at-a-time, supports exact per-column greedy
  order (gather-based), bit-identical to the X-space solver.
* `comq_quantize_blocked` — panel/blocked updates: cross-panel residual
  refresh is one dense (B×m)·(m×n) matmul (MXU work); the intra-panel
  sequential sweep touches only H[blk,blk] + the Q panel (VMEM-resident in
  the Pallas kernel `kernels/comq_panel.py`). Shared-order only — the panel
  structure requires all columns to visit rows in the same order. Exactly
  equals the row-at-a-time solver under the same shared order (tested).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comq import QuantResult, make_orders
from repro.core.quantizer import (EPS, QuantSpec, init_per_channel,
                                  init_per_layer)

Array = jax.Array


def gram(x: Array) -> Array:
    x = x.astype(jnp.float32)
    return x.T @ x


def _h_error(h: Array, w: Array, wq: Array) -> Array:
    """‖X(W − W_q)‖ from H: sqrt(tr(RᵀHR))."""
    r = w - wq
    val = jnp.sum(r * (h @ r))
    return jnp.sqrt(jnp.maximum(val, 0.0))


def _delta_update_h(h: Array, w: Array, qf: Array, per_layer: bool) -> Array:
    hq = h @ qf
    if per_layer:
        num = jnp.sum(qf * (h @ w))
        den = jnp.sum(qf * hq)
        return jnp.where(den > EPS, num / den, 1.0)
    num = jnp.sum(qf * (h @ w), axis=0)
    den = jnp.sum(qf * hq, axis=0)
    return jnp.where(den > EPS, num / den, 1.0)


# ---------------------------------------------------------------------------
# row-at-a-time H-space sweep (exact per-column greedy supported)
# ---------------------------------------------------------------------------

def _sweep_h(h: Array, p: Array, qf: Array, delta: Array, z_lo, z_hi,
             orders: Array, hdiag: Array):
    """p: (m, n) maintained product H·R with R = W − δ·Q."""
    m, n = qf.shape
    cols = jnp.arange(n)

    def step(t, carry):
        p, qf = carry
        idx = orders[t]                                   # (n,)
        qg = qf[idx, cols]
        hg = hdiag[idx]
        denom = delta * hg
        ratio = p[idx, cols] / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg),
                         z_lo.astype(jnp.float32), z_hi.astype(jnp.float32))
        q_new = jnp.where(hg > EPS, q_new,
                          jnp.clip(jnp.round(qg), z_lo.astype(jnp.float32),
                                   z_hi.astype(jnp.float32)))
        du = (q_new - qg) * delta                         # ΔW_q row entries
        p = p - h[:, idx] * du[None, :]                   # rank-1 per column
        qf = qf.at[idx, cols].set(q_new)
        return p, qf

    return jax.lax.fori_loop(0, m, step, (p, qf))


def comq_quantize_h(h: Array, w: Array, spec: QuantSpec,
                    x_for_error: Optional[Array] = None) -> QuantResult:
    """H-space COMQ. `h` = XᵀX. Bit-identical to comq.comq_quantize."""
    h = h.astype(jnp.float32)
    w = w.astype(jnp.float32)
    m, n = w.shape
    per_layer = spec.granularity == "per_layer"
    if per_layer:
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)

    hdiag = jnp.diag(h)
    orders = make_orders(spec.order, jnp.sqrt(hdiag), w)
    qf = w / delta
    errs = [_h_error(h, w, qf * delta)]

    for _ in range(spec.sweeps):
        p = h @ (w - qf * delta)                          # H·R
        p, qf = _sweep_h(h, p, qf, delta, z_lo, z_hi, orders, hdiag)
        delta = _delta_update_h(h, w, qf, per_layer)
        errs.append(_h_error(h, w, qf * delta))

    q = jnp.clip(jnp.round(qf), z_lo, z_hi).astype(jnp.int32)
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi,
                       errors=jnp.stack(errs))


# ---------------------------------------------------------------------------
# blocked / panel solver (the TPU-shaped schedule; shared order only)
# ---------------------------------------------------------------------------

def panel_sweep_ref(h_bb: Array, s0: Array, qf_b: Array, delta: Array,
                    z_lo, z_hi, hdiag_b: Array):
    """Reference intra-panel sweep (the Pallas kernel's oracle).

    h_bb: (B, B) block of H; s0: (B, n) = (H·R)[blk] before the panel;
    qf_b: (B, n) panel codes. Returns updated qf_b."""
    B = qf_b.shape[0]

    def step(t, carry):
        s, qf_b = carry
        qg = qf_b[t]
        hg = hdiag_b[t]
        denom = delta * hg
        ratio = s[t] / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg),
                         z_lo.astype(jnp.float32), z_hi.astype(jnp.float32))
        q_new = jnp.where(hg > EPS, q_new,
                          jnp.clip(jnp.round(qg), z_lo.astype(jnp.float32),
                                   z_hi.astype(jnp.float32)))
        du = (q_new - qg) * delta
        s = s - h_bb[:, t][:, None] * du[None, :]
        qf_b = qf_b.at[t].set(q_new)
        return s, qf_b

    _, qf_b = jax.lax.fori_loop(0, B, step, (s0, qf_b))
    return qf_b


def comq_quantize_blocked(h: Array, w: Array, spec: QuantSpec,
                          block: int = 256,
                          panel_fn=None) -> QuantResult:
    """Blocked COMQ: cyclic or shared-greedy order. `panel_fn` defaults to
    the pure-jnp panel sweep; the launcher swaps in the Pallas kernel."""
    h = h.astype(jnp.float32)
    w = w.astype(jnp.float32)
    m, n = w.shape
    per_layer = spec.granularity == "per_layer"
    if per_layer:
        delta, z_lo, z_hi = init_per_layer(w, spec.bits)
    else:
        delta, z_lo, z_hi = init_per_channel(w, spec.bits, spec.lam)

    order_name = {"greedy": "greedy_shared"}.get(spec.order, spec.order)
    hdiag0 = jnp.diag(h)
    perm = make_orders(order_name, jnp.sqrt(hdiag0), w)[:, 0]   # shared (m,)
    inv_perm = jnp.argsort(perm)
    hp = h[perm][:, perm]
    wp = w[perm]
    hdiag = jnp.diag(hp)
    panel_fn = panel_fn or panel_sweep_ref

    # pad rows to a multiple of the panel size (H rows padded with zeros:
    # zero-diagonal rows keep their code — no effect on real rows)
    B = min(block, m)
    m_pad = ((m + B - 1) // B) * B
    if m_pad != m:
        hp = jnp.pad(hp, ((0, m_pad - m), (0, m_pad - m)))
        wp = jnp.pad(wp, ((0, m_pad - m), (0, 0)))
        hdiag = jnp.pad(hdiag, (0, m_pad - m))
    n_blocks = m_pad // B

    qf = wp / delta
    errs = [_h_error(hp[:m, :m], wp[:m], (qf * delta)[:m])]

    for _ in range(spec.sweeps):
        def body(b, qf):
            r = wp - qf * delta
            h_rows = jax.lax.dynamic_slice(hp, (b * B, 0), (B, m_pad))
            s0 = h_rows @ r                                    # (B, n) MXU
            h_bb = jax.lax.dynamic_slice(h_rows, (0, b * B), (B, B))
            qf_b = jax.lax.dynamic_slice(qf, (b * B, 0), (B, n))
            hd_b = jax.lax.dynamic_slice(hdiag, (b * B,), (B,))
            qf_b = panel_fn(h_bb, s0, qf_b, delta, z_lo, z_hi, hd_b)
            return jax.lax.dynamic_update_slice(qf, qf_b, (b * B, 0))
        qf = jax.lax.fori_loop(0, n_blocks, body, qf)
        delta = _delta_update_h(hp[:m, :m], wp[:m], qf[:m], per_layer)
        errs.append(_h_error(hp[:m, :m], wp[:m], (qf * delta)[:m]))

    q = jnp.clip(jnp.round(qf[:m]), z_lo, z_hi).astype(jnp.int32)
    q = q[inv_perm]
    return QuantResult(q=q, delta=delta, z_lo=z_lo, z_hi=z_hi,
                       errors=jnp.stack(errs))
