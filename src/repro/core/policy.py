"""Per-leaf mixed-precision policies + the budgeted backprop-free allocator.

COMQ's decomposition W_q = δ·Q is per-layer by construction, but until this
module the whole stack hard-coded ONE global `QuantSpec` for every leaf.
A `QuantPolicy` resolves a (layer, leaf-name) pair to its own spec — the
pattern rules express the mixes the paper's sensitivity spread motivates
(first/last layers and down-projections at 8 bits, bulk attention at 4/2),
and `policy_from_budget` derives an *exact per-leaf* assignment from a
bits-per-param budget with a greedy knapsack over the layerwise H-space
reconstruction errors (Hubara et al., "Improving Post Training Neural
Quantization: Layer-wise Calibration and Integer Programming" — the same
layerwise quantities COMQ computes anyway, so the allocator stays
backprop-free).

Resolution order (DESIGN.md §6):

1. pattern ``rules`` — first match wins; matched against the
   layer-qualified name ``"{layer}.{name}"`` first, then the bare leaf
   name ``"attn.wq"`` / ``"mlp.w_down"`` / ``"unembed"`` (fnmatch
   wildcards allowed, e.g. ``("*.w_down", 8)``);
2. ``first_layer_bits`` / ``last_layer_bits`` overrides (layer 0 /
   layer n_layers-1);
3. ``base.bits``.

Only the *bit width* varies per leaf: granularity/order/λ/sweeps are
policy-wide, which is what keeps the fusion and column-sharding gates
(`pipeline._fusable` / `pipeline._col_shardable`) decidable per leaf and a
uniform policy bit-identical to the old global-spec path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from fnmatch import fnmatchcase
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.quantizer import QuantSpec, codes_per_byte

#: bit widths the allocator may assign (all have a packed storage form —
#: see quantizer.codes_per_byte: 2 → 0.25 B, 3/4 → 0.5 B, 8 → 1 B/param)
DEFAULT_BIT_CHOICES = (2, 3, 4, 8)


@dataclass(frozen=True)
class QuantPolicy:
    """A resolved-per-leaf quantization policy.

    ``rules`` are ``(pattern, bits)`` pairs; ``kv_bits`` carries the KV-
    cache precision the deployment should use (0 = keep the plan's cache
    dtype, 8 = int8 dense-cache quantization via BuildPlan.cache_quant) —
    it does not affect weight solves."""
    base: QuantSpec = QuantSpec()
    rules: Tuple[Tuple[str, int], ...] = ()
    first_layer_bits: Optional[int] = None
    last_layer_bits: Optional[int] = None
    kv_bits: int = 0

    def resolve(self, name: str, layer: int, n_layers: int) -> QuantSpec:
        """The spec for leaf `name` ("attn.wq", "cross.mlp.w_down",
        "unembed", ...) of layer `layer` (-1 for non-layer leaves)."""
        qualified = f"{layer}.{name}"
        for pattern, bits in self.rules:
            if fnmatchcase(qualified, pattern) or fnmatchcase(name, pattern):
                return dataclasses.replace(self.base, bits=int(bits))
        if self.first_layer_bits is not None and layer == 0:
            return dataclasses.replace(self.base,
                                       bits=int(self.first_layer_bits))
        if self.last_layer_bits is not None and layer == n_layers - 1:
            return dataclasses.replace(self.base,
                                       bits=int(self.last_layer_bits))
        return self.base

    def is_uniform(self) -> bool:
        return (not self.rules and self.first_layer_bits is None
                and self.last_layer_bits is None)


def as_policy(spec_or_policy) -> QuantPolicy:
    """Wrap a plain QuantSpec into the (uniform) policy it denotes."""
    if isinstance(spec_or_policy, QuantPolicy):
        return spec_or_policy
    if isinstance(spec_or_policy, QuantSpec):
        return QuantPolicy(base=spec_or_policy)
    raise TypeError(
        f"expected QuantSpec or QuantPolicy, got {type(spec_or_policy)}")


def parse_policy(text: str, base: QuantSpec) -> QuantPolicy:
    """Parse the launcher's ``--policy`` string: comma-separated
    ``pattern=bits`` rules plus the shorthands ``first=b`` / ``last=b`` /
    ``kv=b`` (e.g. ``"*.w_down=8,first=8,last=8,kv=8"``)."""
    rules: List[Tuple[str, int]] = []
    first = last = None
    kv = 0
    for item in filter(None, (s.strip() for s in text.split(","))):
        key, _, val = item.partition("=")
        if not val:
            raise ValueError(f"policy rule {item!r} is not 'pattern=bits'")
        bits = int(val)
        if key == "first":
            first = bits
        elif key == "last":
            last = bits
        elif key == "kv":
            kv = bits
        else:
            rules.append((key, bits))
    return QuantPolicy(base=base, rules=tuple(rules), first_layer_bits=first,
                       last_layer_bits=last, kv_bits=kv)


def policy_to_dict(policy: QuantPolicy) -> dict:
    """JSON/checkpoint-safe metadata form (ckpt extra / --save-quantized)."""
    return {
        "base": dataclasses.asdict(policy.base),
        "rules": [[p, int(b)] for p, b in policy.rules],
        "first_layer_bits": policy.first_layer_bits,
        "last_layer_bits": policy.last_layer_bits,
        "kv_bits": policy.kv_bits,
    }


def policy_from_dict(d: dict) -> QuantPolicy:
    return QuantPolicy(
        base=QuantSpec(**d["base"]),
        rules=tuple((p, int(b)) for p, b in d.get("rules", ())),
        first_layer_bits=d.get("first_layer_bits"),
        last_layer_bits=d.get("last_layer_bits"),
        kv_bits=d.get("kv_bits", 0),
    )


# ---------------------------------------------------------------------------
# budgeted bit allocation (greedy knapsack on layerwise H-space errors)
# ---------------------------------------------------------------------------

def allocate_bits(curves: Dict[str, Dict[int, float]],
                  sizes: Dict[str, int],
                  budget_bits_per_param: float,
                  choices: Sequence[int] = DEFAULT_BIT_CHOICES
                  ) -> Dict[str, int]:
    """Greedy budgeted allocation: every leaf starts at min(choices); the
    upgrade with the best error-reduction per extra bit·param is applied
    until the next one would exceed the budget.

    `curves[leaf][bits]` is the leaf's reconstruction error at that width
    (any monotone proxy works — we use the H-space ‖X(W − W_q)‖ of the
    COMQ grid init, see measure_bit_curves). Curves are clipped monotone
    non-increasing in bits first; the convexified upgrade sequence is
    computed once (budget-independent) and applied as a strict prefix —
    so a larger budget's allocation is a superset of a smaller one's and
    total error is non-increasing in the budget (tested). The assignment
    never exceeds the budget, and a budget of b bits/param with b in
    `choices` is satisfied exactly when the curves make the uniform-b
    point reachable (e.g. budget ≥ max(choices) ⇒ everything at max).
    """
    choices = sorted(set(int(c) for c in choices))
    if not choices:
        raise ValueError("allocate_bits needs at least one bit choice")
    leaves = sorted(curves)
    if set(leaves) != set(sizes):
        raise ValueError("curves and sizes must cover the same leaves")

    # monotone envelope: err at b = min err over widths <= b in the curve
    mono: Dict[str, Dict[int, float]] = {}
    for leaf in leaves:
        best = float("inf")
        mono[leaf] = {}
        for b in choices:
            if b not in curves[leaf]:
                raise ValueError(f"curve for {leaf!r} missing bits={b}")
            best = min(best, float(curves[leaf][b]))
            mono[leaf][b] = best

    alloc = {leaf: choices[0] for leaf in leaves}
    total_params = sum(sizes.values())
    budget_bits = budget_bits_per_param * total_params
    spent = float(choices[0]) * total_params
    if spent > budget_bits + 1e-9:
        raise ValueError(
            f"budget {budget_bits_per_param} bits/param is below the "
            f"smallest choice {choices[0]}")

    # Per-leaf upgrade steps, convexified: whenever a later step has a
    # strictly better gain/cost ratio than its predecessor, the two merge
    # into one atomic step — so each leaf's step ratios are non-increasing
    # and the globally sorted sequence visits every leaf's steps in order.
    ups = []
    for leaf in leaves:
        steps = []
        for lo, hi in zip(choices, choices[1:]):
            steps.append([(hi - lo) * sizes[leaf],
                          mono[leaf][lo] - mono[leaf][hi], hi])
            while (len(steps) >= 2 and steps[-1][1] * steps[-2][0]
                   > steps[-2][1] * steps[-1][0]):
                c2, g2, h2 = steps.pop()
                c1, g1, _ = steps.pop()
                steps.append([c1 + c2, g1 + g2, h2])
        for cost, gain, hi in steps:
            ups.append((-(gain / cost), leaf, hi, cost))
    # ratio descending; ties broken by (leaf, bits) — deterministic and,
    # crucially, budget-independent
    ups.sort(key=lambda t: (t[0], t[1], t[2]))

    # strict prefix application: the first step that does not fit ends the
    # allocation. A larger budget therefore applies a superset of a
    # smaller budget's steps — that nesting is what makes total error
    # non-increasing in the budget.
    for _, leaf, hi, cost in ups:
        if spent + cost > budget_bits + 1e-9:
            break
        alloc[leaf] = hi
        spent += cost
    return alloc


def alloc_bits_per_param(alloc: Dict[str, int], sizes: Dict[str, int]
                         ) -> float:
    total = sum(sizes.values())
    return sum(alloc[l] * sizes[l] for l in alloc) / max(total, 1)


def alloc_bytes_per_param(alloc: Dict[str, int], sizes: Dict[str, int]
                          ) -> float:
    """Packed storage cost of an allocation (codes only, excludes the
    per-channel scale/zero-point overhead — DESIGN.md §6 table)."""
    total = sum(sizes.values())
    return sum(sizes[l] / codes_per_byte(alloc[l])
               for l in alloc) / max(total, 1)


# ---------------------------------------------------------------------------
# curve measurement: one float forward per layer, zero backprop
# ---------------------------------------------------------------------------

def measure_bit_curves(params, cfg, plan, tokens, base: QuantSpec,
                       choices: Sequence[int] = DEFAULT_BIT_CHOICES,
                       curve_method: str = "rtn",
                       include_unembed: bool = False):
    """Per-leaf error-vs-bits curves from the taps of a single float-model
    walk (the legacy two-forward machinery minus the second forward).

    curve_method="rtn" (default) prices each width with the H-space error
    of the COMQ grid init — solver-free, one H·R matmul per (leaf, width).
    curve_method="comq_blocked" runs the maintained-P blocked solve per
    width instead (the solver's error trajectory is free once the solve
    runs; ~len(choices)× the quantization cost, for allocation studies).

    Returns (curves, sizes): {name: {bits: err}}, {name: n_params} with
    names layer-qualified ("3.attn.wq", "unembed").
    """
    import jax
    import jax.numpy as jnp

    from repro.core import calibrate, pipeline
    from repro.core.baselines import rtn_quantize
    from repro.core.comq_hessian import comq_quantize_blocked
    from repro.models.common import apply_norm
    from repro.models.model import embed_tokens

    if cfg.family == "vlm":
        raise NotImplementedError(
            "bit-curve measurement covers homogeneous stacks; resolve VLM "
            "policies with explicit rules instead")

    def leaf_errs(h, w2d):
        out = {}
        for b in choices:
            spec_b = dataclasses.replace(base, bits=int(b))
            if curve_method == "comq_blocked":
                r = comq_quantize_blocked(h, w2d, spec_b)
            else:
                r = rtn_quantize(w2d, spec_b, h=h)
            out[int(b)] = r.errors[-1]
        return out

    curves: Dict[str, Dict[int, float]] = {}
    sizes: Dict[str, int] = {}
    pending: List[Tuple[str, Dict[int, object]]] = []
    tapmap = pipeline.taps_for(cfg)
    x = embed_tokens(params, cfg, plan, tokens)

    init_states = None
    if cfg.attn_free:
        from repro.models.rwkv import init_rwkv_state
        init_states = init_rwkv_state(x.shape[0], cfg)
    elif cfg.parallel_ssm_heads:
        from repro.models.ssm import init_ssm_state
        init_states = init_ssm_state(x.shape[0], cfg)

    state = init_states
    layer_fn = pipeline._legacy_layer_fn(cfg, plan)
    for l in range(cfg.n_layers):
        lp = pipeline._tree_slice(params["layers"], l)
        x, taps, state = layer_fn(lp, x, state)
        cache = calibrate.TapGramCache()
        for tapname, entries in pipeline._tap_groups(lp, tapmap).items():
            if tapname.startswith("expert"):
                hs = cache.batched(tapname, taps[tapname])
                for mod, leaf in entries:
                    w = lp[mod][leaf].astype(jnp.float32)   # (E, d, f)
                    name = f"{l}.{mod}.{leaf}"
                    sizes[name] = int(w.size)
                    # one vmapped pricing pass covers every width; sum of
                    # per-expert error norms matches the pipeline's
                    # per-leaf MoE reporting
                    per_e = jax.vmap(leaf_errs)(hs, w)      # {b: (E,)}
                    pending.append((name, {int(b): jnp.sum(v)
                                           for b, v in per_e.items()}))
            else:
                h = cache.gram(tapname, taps[tapname])
                for mod, leaf in entries:
                    w2d = pipeline._w2d(lp[mod][leaf], h.shape[0]).astype(
                        jnp.float32)
                    name = f"{l}.{mod}.{leaf}"
                    sizes[name] = int(w2d.size)
                    pending.append((name, leaf_errs(h, w2d)))

    if include_unembed and "unembed" in params:
        xn = apply_norm(params["final_norm"], x, cfg)
        h = calibrate.gram_from_tap(xn)
        w2d = params["unembed"].astype(jnp.float32)
        sizes["unembed"] = int(w2d.size)
        pending.append(("unembed", leaf_errs(h, w2d)))

    # one batched transfer for all the device scalars
    flat = jnp.stack([jnp.asarray(v, jnp.float32)
                      for _, d in pending for v in d.values()])
    vals = jax.device_get(flat)
    i = 0
    for name, d in pending:
        curves[name] = {}
        for b in d:
            curves[name][int(b)] = float(vals[i])
            i += 1
    return curves, sizes


def policy_from_budget(params, cfg, plan, tokens, base: QuantSpec,
                       budget_bits_per_param: float,
                       choices: Sequence[int] = DEFAULT_BIT_CHOICES,
                       curve_method: str = "rtn",
                       kv_bits: int = 0):
    """Measure curves, allocate under the budget, and emit a QuantPolicy
    whose rules pin every leaf exactly (base.bits = the modal choice so
    the rule list stays short). Returns (policy, alloc, sizes)."""
    curves, sizes = measure_bit_curves(params, cfg, plan, tokens, base,
                                       choices=choices,
                                       curve_method=curve_method)
    alloc = allocate_bits(curves, sizes, budget_bits_per_param,
                          choices=choices)
    counts: Dict[int, int] = {}
    for b in alloc.values():
        counts[b] = counts.get(b, 0) + 1
    modal = max(counts, key=lambda b: (counts[b], -b))
    rules = tuple((name, b) for name, b in sorted(alloc.items())
                  if b != modal)
    policy = QuantPolicy(base=dataclasses.replace(base, bits=modal),
                         rules=rules, kv_bits=kv_bits)
    return policy, alloc, sizes
