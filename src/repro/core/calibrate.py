"""Calibration: streaming accumulation of the Gram matrix H = XᵀX.

At scale the features X are data-parallel across the mesh; `jnp` reductions
over the sharded sample axis lower to one all-reduce of the (m, m) Gram
block per layer — the only communication COMQ needs (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class GramAccumulator:
    """Streaming H = Σ XᵀX over calibration batches (f32)."""

    def __init__(self, dim: int):
        self.h = jnp.zeros((dim, dim), jnp.float32)
        self.count = 0

    def update(self, x: Array) -> "GramAccumulator":
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        self.h = self.h + x2.T @ x2
        self.count += x2.shape[0]
        return self

    def value(self) -> Array:
        return self.h


def gram_from_tap(tap: Array) -> Array:
    """(B, T, d) or (E, C, d) activation tap -> (d, d) Gram matrix.
    For stacked-expert taps, call with tap[e]."""
    x2 = tap.reshape(-1, tap.shape[-1]).astype(jnp.float32)
    return x2.T @ x2


def batched_gram(tap: Array) -> Array:
    """(E, C, d) -> (E, d, d): per-expert Gram matrices in one einsum."""
    t = tap.astype(jnp.float32)
    return jnp.einsum("ecd,ecf->edf", t, t)


class TapGramCache:
    """One Gram per activation tap: weight leaves sharing a tap (wq/wk/wv on
    attn_in, w_gate/w_up on mlp_in or expert_in) reuse the same H instead of
    re-accumulating it per leaf — for the dense transformer family this cuts
    Gram matmuls per layer from 7 (one per leaf) to 4 (one per tap).

    Scope one instance per layer: taps are recomputed from the quantized
    stream every layer, so cached Grams must not outlive them.

    `gram_fn`/`batched_fn` override how a Gram is computed (e.g. the
    data-parallel shard_map + psum path in repro.dist.calibrate)."""

    def __init__(self, gram_fn: Optional[Callable] = None,
                 batched_fn: Optional[Callable] = None):
        self._grams: Dict[str, Array] = {}
        self.computed = 0      # instrumentation: # of Gram matmuls issued
        self._gram_fn = gram_fn
        self._batched_fn = batched_fn

    def gram(self, name: str, tap: Array) -> Array:
        if name not in self._grams:
            fn = self._gram_fn if self._gram_fn is not None else gram_from_tap
            self._grams[name] = fn(tap)
            self.computed += 1
        return self._grams[name]

    def batched(self, name: str, tap: Array) -> Array:
        if name not in self._grams:
            fn = (self._batched_fn if self._batched_fn is not None
                  else batched_gram)
            self._grams[name] = fn(tap)
            self.computed += 1
        return self._grams[name]
