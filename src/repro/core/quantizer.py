"""Uniform quantization grids, scale/zero-point initialization, packing.

The paper's setting (§3): b-bit *asymmetric uniform* quantization with
bit-code set S = {z, z+1, ..., z + 2^b - 1} and decomposition W_q = δ·Q.

* per-layer  (Alg. 1): one shared δ; init δ⁰ = mean_j ‖w_j‖∞ / 2^{b-1},
  z = -2^{b-1} (symmetric code range around zero).
* per-channel (Alg. 2): δ_j = λ·(max w_j - min w_j)/(2^b - 1), λ ≤ 1
  (Tab. 10 ablation), z_j = round(min w_j / δ_j).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
EPS = 1e-12


@dataclass(frozen=True)
class QuantSpec:
    bits: int = 4
    granularity: str = "per_channel"      # per_channel | per_layer
    lam: float = 1.0                      # λ init shrink (per-channel)
    sweeps: int = 3                       # K in the paper (Tab. 7: 3-4 best)
    order: str = "greedy"                 # greedy | cyclic

    @property
    def n_levels(self) -> int:
        return 2 ** self.bits


def init_per_layer(w: Array, bits: int) -> Tuple[Array, Array, Array]:
    """Returns (delta0 scalar, z_lo scalar, z_hi scalar)."""
    col_inf = jnp.max(jnp.abs(w), axis=0)             # ‖w_j‖∞ per column
    delta0 = jnp.mean(col_inf) / (2.0 ** (bits - 1))
    delta0 = jnp.maximum(delta0, EPS)
    z = -(2 ** (bits - 1))
    return delta0, jnp.int32(z), jnp.int32(z + 2 ** bits - 1)


def init_per_channel(w: Array, bits: int, lam: float
                     ) -> Tuple[Array, Array, Array]:
    """Returns (delta0 (n,), z_lo (n,), z_hi (n,)) for w: (m, n)."""
    wmax = jnp.max(w, axis=0)
    wmin = jnp.min(w, axis=0)
    delta0 = lam * (wmax - wmin) / (2.0 ** bits - 1.0)
    delta0 = jnp.maximum(delta0, EPS)
    z_lo = jnp.round(wmin / delta0).astype(jnp.int32)
    return delta0, z_lo, z_lo + 2 ** bits - 1


def quantize_rtn(w: Array, delta: Array, z_lo: Array, z_hi: Array) -> Array:
    """Round-to-nearest onto the grid (baseline + COMQ initialization)."""
    q = jnp.round(w / delta)
    return jnp.clip(q, z_lo, z_hi).astype(jnp.int32)


def dequantize(q: Array, delta: Array) -> Array:
    return q.astype(jnp.float32) * delta


# ---------------------------------------------------------------------------
# storage: offset-binary codes (codes - z_lo in [0, 2^b-1]) packed for HBM
# ---------------------------------------------------------------------------

def to_unsigned(q: Array, z_lo: Array) -> Array:
    return (q - z_lo).astype(jnp.uint8)


def from_unsigned(u: Array, z_lo: Array) -> Array:
    return u.astype(jnp.int32) + z_lo


def pack_int4(u: Array) -> Array:
    """Pack uint4 codes (last dim even) into uint8 pairs: low nibble first."""
    assert u.shape[-1] % 2 == 0, "pack_int4 needs even last dim"
    lo = u[..., 0::2].astype(jnp.uint8)
    hi = u[..., 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(b: Array) -> Array:
    lo = b & jnp.uint8(0x0F)
    hi = (b >> 4) & jnp.uint8(0x0F)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*b.shape[:-1], b.shape[-1] * 2)


def pack_int2(u: Array) -> Array:
    """Pack uint2 codes (last dim % 4 == 0) four per byte, lowest bits
    first — the 0.25 B/param storage of a 2-bit policy leaf."""
    assert u.shape[-1] % 4 == 0, "pack_int2 needs last dim % 4 == 0"
    parts = [u[..., i::4].astype(jnp.uint8) << (2 * i) for i in range(4)]
    return parts[0] | parts[1] | parts[2] | parts[3]


def unpack_int2(b: Array) -> Array:
    parts = [(b >> (2 * i)) & jnp.uint8(0x03) for i in range(4)]
    out = jnp.stack(parts, axis=-1)
    return out.reshape(*b.shape[:-1], b.shape[-1] * 4)


def codes_per_byte(bits: int) -> int:
    """Storage density for offset-binary codes of a given bit width:
    2-bit codes pack four per byte, 3/4-bit codes share the nibble
    packing (3-bit codes fit a nibble), 5..8-bit codes pass through as
    one uint8 each (the explicit int8 pass-through)."""
    if bits <= 2:
        return 4
    if bits <= 4:
        return 2
    return 1


def pack_codes(u: Array, bits: int):
    """Pack offset-binary uint8 codes to the densest byte layout their bit
    width allows. Returns (packed, cpb) where cpb is the achieved
    codes-per-byte — 1 when the last dim doesn't align to the pack width
    (callers store the codes unpacked rather than padding)."""
    cpb = codes_per_byte(bits)
    if cpb == 1 or u.shape[-1] % cpb:
        return u.astype(jnp.uint8), 1
    if cpb == 4:
        return pack_int2(u), 4
    return pack_int4(u), 2


def unpack_codes(b: Array, cpb: int) -> Array:
    """Inverse of pack_codes for a known codes-per-byte."""
    if cpb == 4:
        return unpack_int2(b)
    if cpb == 2:
        return unpack_int4(b)
    return b


def reconstruction_error(x: Array, w: Array, w_q: Array) -> Array:
    """‖X W_q − X W‖_F — the paper's layer-wise objective (Fig. 3 metric)."""
    return jnp.linalg.norm(x @ (w_q - w))
