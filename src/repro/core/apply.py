"""Quantized parameter containers for the serving path.

`QT` is a registered pytree node whose codes/scale/zero are array leaves
and whose logical shape/bits are *static* aux data — so a params tree with
QT leaves jits/shards/scans like any other, while the dequantization
happens inside the compiled step (per layer, inside the scan body): HBM
streams int4/int8 codes, not bf16 weights. This is what turns COMQ's 4-bit
codes into a 4× reduction of the decode memory-roofline term (§Perf).
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.pipeline import is_qtensor, qtensor_bits
from repro.core.quantizer import pack_codes, unpack_codes

Array = jax.Array


def _default_cpb(bits: int) -> int:
    """Historical storage rule for QTs built before mixed precision:
    4-bit codes arrived nibble-packed, everything else one-per-byte."""
    return 2 if bits == 4 else 1


class QT:
    """Quantized tensor: codes (uint8, packed `cpb` codes per byte),
    per-channel scale + zero-point; static logical shape + bit width.

    `bits` is the *logical* width of the codes; `cpb` the achieved storage
    density (quantizer.codes_per_byte — producers fall back to cpb=1 when
    the last dim doesn't align to the pack width), so a mixed 2/3/4/8-bit
    tree is self-describing without inspecting code values."""

    def __init__(self, codes, scale, z_lo, shape: Tuple[int, ...],
                 bits: int, cpb: Optional[int] = None):
        self.codes = codes
        self.scale = scale
        self.z_lo = z_lo
        self.shape = tuple(shape)
        self.bits = int(bits)
        self.cpb = _default_cpb(self.bits) if cpb is None else int(cpb)

    def dequant(self, dtype=jnp.bfloat16) -> Array:
        u = unpack_codes(self.codes, self.cpb)
        s, z = self.scale, self.z_lo
        if u.ndim == s.ndim + 1:   # per-channel scale over the last dim
            s = s[..., None, :]
            z = z[..., None, :]
        q = u.astype(jnp.float32) + z.astype(jnp.float32)
        w = q * s
        # COMQ checkpoints store codes 2D-flattened (tap_dim, cols); restore
        # the logical trailing shape. Scan slicing drops leading stack dims,
        # so match the suffix of `self.shape` with the current element count.
        if w.shape != tuple(self.shape):
            target = _suffix_shape(self.shape, w.size)
            if target is not None and w.shape != target:
                w = w.reshape(target)
        return w.astype(dtype)


def _qt_flatten(qt: QT):
    return (qt.codes, qt.scale, qt.z_lo), (qt.shape, qt.bits, qt.cpb)


def _qt_unflatten(aux, children):
    return QT(*children, shape=aux[0], bits=aux[1], cpb=aux[2])


jax.tree_util.register_pytree_node(QT, _qt_flatten, _qt_unflatten)


def is_qt(x) -> bool:
    return isinstance(x, QT)


class SegmentedLayers:
    """A stacked-layer tree split into contiguous per-bit-width scan
    groups: segment s is a homogeneous stacked subtree covering
    `sizes[s]` consecutive layers, so mixed-bit serving trees keep every
    segment's QT codes packed at their own width while the model runs one
    `lax.scan` per segment (models/model.py::scan_layers). Registered as
    a pytree node — it jits/donates/shards like the plain stacked tree."""

    def __init__(self, segments: Tuple[Any, ...], sizes: Tuple[int, ...]):
        assert len(segments) == len(sizes) and len(segments) > 0
        self.segments = tuple(segments)
        self.sizes = tuple(int(s) for s in sizes)

    @property
    def n_layers(self) -> int:
        return sum(self.sizes)


jax.tree_util.register_pytree_node(
    SegmentedLayers,
    lambda s: (s.segments, s.sizes),
    lambda sizes, segments: SegmentedLayers(tuple(segments), sizes))


def is_segmented(x) -> bool:
    return isinstance(x, SegmentedLayers)


def _suffix_shape(shape, size):
    """Shortest suffix of `shape` whose element count equals `size`.

    Shortest (not longest) so that scan-sliced codes resolve to the
    logical per-layer shape even when a leading stack dim is 1: a
    (1, d, H, hd) QT sliced inside the scan must dequantize to
    (d, H, hd), not rebroadcast the stack dim."""
    for i in range(len(shape), -1, -1):
        p = 1
        for s in shape[i:]:
            p *= s
        if p == size:
            return tuple(shape[i:])
    return None


def qt_out_dims(qt: QT):
    """Logical trailing dims of a 2D-codes QT's output axis (e.g. the
    (H, hd) of a wq whose codes are stored (d, H·hd)).

    The output suffix must be preceded by dims multiplying to the codes'
    input dim K (with any leading stack dims before those) — that
    constraint disambiguates unit axes: a (L, d, 1, hd) MQA wk resolves
    to (1, hd), not (hd,), while a (1, d, H, hd) single-layer stack still
    resolves to (H, hd). Longest valid suffix wins."""
    import math
    n = qt.codes.shape[-1] * qt.cpb
    k = qt.codes.shape[0]
    shp = qt.shape
    for i in range(len(shp)):               # longest suffix first
        if math.prod(shp[i:]) != n:
            continue
        if any(math.prod(shp[j:i]) == k for j in range(i)):
            return tuple(shp[i:])
    return (n,)


def qt_fusable(x) -> bool:
    """True when a QT leaf can feed the fused quant_matmul path directly:
    2D codes (tap_dim, cols) with one per-column scale — the layout COMQ
    checkpoints store. fake_quantize_params trees (logical-rank codes,
    per-row-per-channel scales) fall back to dequant-then-einsum."""
    return is_qt(x) and x.codes.ndim == 2 and x.scale.ndim == 1


def qt_linear(qt: QT, x2d: Array, out_dtype=None) -> Array:
    """x2d: (M, K) · QT codes (K, N) through the dequant-fused GEMM —
    backend-dispatched (Pallas on TPU, factored-jnp oracle on CPU), so
    decode streams int4/int8 codes from HBM instead of bf16 weights."""
    from repro.kernels import ops
    y = ops.quant_matmul(x2d.astype(jnp.float32), qt.codes, qt.scale,
                         qt.z_lo.astype(jnp.float32), bits=qt.bits,
                         cpb=qt.cpb, out_dtype=jnp.float32)
    return y.astype(out_dtype if out_dtype is not None else x2d.dtype)


# leaves whose apply sites (qkv_project / out_project / apply_mlp) know how
# to consume a fused-layout QT directly
FUSED_QT_LEAVES = frozenset(
    {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"})


def dequantize_qt_tree(tree, dtype=jnp.bfloat16, keep_fused: bool = False):
    """Replace QT leaves with dense weights (called inside scan bodies).

    keep_fused=True leaves QT leaves in place when (a) the projection code
    consuming them is QT-aware (FUSED_QT_LEAVES) and (b) the layout feeds
    quant_matmul (qt_fusable) — the packed-QT decode path."""
    if not keep_fused:
        return jax.tree_util.tree_map(
            lambda x: x.dequant(dtype) if is_qt(x) else x, tree,
            is_leaf=is_qt)

    def walk(node, name=""):
        if is_qt(node):
            if name in FUSED_QT_LEAVES and qt_fusable(node):
                return node
            return node.dequant(dtype)
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        return node

    return walk(tree)


def fake_quantize_params(params, cfg, plan, bits: int = 4,
                         quantize_embed: bool = True):
    """Wrap every projection weight in a QT with RTN codes — the *layout*
    transform used by the serving dry-run (real deployments load COMQ codes
    from a quantized checkpoint; the compiled step is identical)."""
    from repro.core.quantizer import init_per_channel, quantize_rtn

    def to_qt(w):
        shape = w.shape
        lead = shape[:-2] if w.ndim > 2 else ()
        w2 = w.reshape(-1, *shape[-2:]) if lead else w[None]
        # per-channel over the last dim, batched over leading dims
        def one(wl):
            m = wl.reshape(-1, wl.shape[-1])
            delta, z_lo, z_hi = init_per_channel(m.astype(jnp.float32),
                                                 bits, 1.0)
            q = quantize_rtn(m.astype(jnp.float32), delta, z_lo, z_hi)
            u = (q - z_lo).astype(jnp.uint8)
            return u, delta, z_lo
        us, deltas, zs = jax.vmap(one)(w2)
        us, cpb = pack_codes(us, bits)
        if not lead:
            us, deltas, zs = us[0], deltas[0], zs[0]
        else:
            us = us.reshape(*lead, *us.shape[1:])
            deltas = deltas.reshape(*lead, *deltas.shape[1:])
            zs = zs.reshape(*lead, *zs.shape[1:])
        return QT(us, deltas, zs, shape, bits, cpb=cpb)

    quantizable = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "w_r", "w_k", "w_v", "w_g", "w_o", "w_in", "w_out",
                   "w_xproj", "unembed"}
    if quantize_embed:
        quantizable = quantizable | {"embed"}

    def walk(node, name=""):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        if name in quantizable and hasattr(node, "ndim") and node.ndim >= 2:
            return to_qt(node)
        return node

    return walk(params)


def _qt_from_qtensors(ts, pack: bool = True, stacked: bool = True) -> QT:
    """Stack per-layer pipeline QTensors (offset-binary uint8 codes, f32
    per-column scales, int32 zero-points) into one scan-able QT leaf.

    The pack width comes from the QTensors' recorded `bits` — never from
    inspecting code values (the old `max(codes) < 16` probe forced a host
    sync per leaf and silently nibble-packed 8-bit solves whose codes
    happened to stay small). All stacked QTensors carry the same bits by
    construction: serving_params groups mixed-bit tables into homogeneous
    segments before stacking."""
    bits = qtensor_bits(ts[0])
    assert all(qtensor_bits(t) == bits for t in ts), \
        "cannot stack QTensors of different bit widths into one QT"
    if stacked:
        codes = jnp.stack([t["codes"] for t in ts])
        scale = jnp.stack([t["scale"] for t in ts])
        z_lo = jnp.stack([t["z_lo"] for t in ts])
        shape = (len(ts), *ts[0]["shape"])
    else:
        codes = ts[0]["codes"]
        scale = ts[0]["scale"]
        z_lo = ts[0]["z_lo"]
        shape = tuple(ts[0]["shape"])
    if pack:
        codes, cpb = pack_codes(codes, bits)
    else:
        cpb = 1
    return QT(codes, scale, z_lo, shape, bits, cpb=cpb)


def _bit_signature(lp) -> Tuple:
    """Sorted (path, bits) tuple over a layer's QTensor leaves — layers
    stack into one scan group iff their signatures match."""
    out = []

    def walk(node, path):
        if is_qtensor(node):
            out.append((path, qtensor_bits(node)))
            return
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], f"{path}/{k}")

    walk(lp, "")
    return tuple(sorted(out))


def serving_params(qparams, cfg, *, pack: bool = True):
    """Fold a quantize_model output (__qlayers__ QTensor side table) into a
    stacked params tree with QT leaves — the *packed* serving form. Unlike
    `materialize` no dense weights are ever built: prefill/decode dequantize
    (or quant_matmul-fuse) per layer inside the compiled scan, so HBM holds
    packed codes end-to-end.

    Uniform-policy tables stack into the single-scan tree they always did.
    A mixed-bit table (per-leaf policy) is bucketed into *per-bit-width
    scan groups*: maximal contiguous runs of layers with the same bit
    signature become one homogeneous stacked segment each (SegmentedLayers)
    — every segment keeps its own pack density, and the model runs one
    scan per segment (models/model.py::scan_layers) so mixed 2/3/4/8-bit
    trees serve packed with no materialize anywhere."""
    params = {k: v for k, v in qparams.items() if k != "__qlayers__"}
    table = qparams.get("__qlayers__", {})
    for k, v in list(params.items()):
        if is_qtensor(v):
            params[k] = _qt_from_qtensors([v], pack=pack, stacked=False)
    if not table:
        return params
    if cfg.family == "vlm":
        raise NotImplementedError(
            "packed-QT serving covers homogeneous stacks; materialize() "
            "the VLM group table instead")
    per_layer = [table[k] for k in sorted(table, key=int)]

    def walk(stacked, slices):
        if is_qtensor(slices[0]):
            return _qt_from_qtensors(slices, pack=pack)
        if isinstance(slices[0], dict):
            return {k: walk(None if stacked is None else stacked[k],
                            [s[k] for s in slices])
                    for k in slices[0]}
        if stacked is not None:
            return stacked   # dense leaf: keep the original stacked array
        # stripped checkpoint (ckpt.strip_for_serving): restack the dense
        # leaves from the table's per-layer slices
        return jnp.stack(slices)

    sigs = [_bit_signature(lp) for lp in per_layer]
    if all(s == sigs[0] for s in sigs):
        params["layers"] = walk(params.get("layers"), per_layer)
        return params

    # mixed-bit: maximal contiguous same-signature runs -> scan segments
    runs: List[Tuple[int, int]] = []
    lo = 0
    for i in range(1, len(sigs) + 1):
        if i == len(sigs) or sigs[i] != sigs[lo]:
            runs.append((lo, i))
            lo = i
    stacked_all = params.get("layers")
    segs = []
    for lo, hi in runs:
        seg_stacked = (None if stacked_all is None else
                       jax.tree_util.tree_map(lambda a: a[lo:hi],
                                              stacked_all))
        segs.append(walk(seg_stacked, per_layer[lo:hi]))
    params["layers"] = SegmentedLayers(tuple(segs),
                                       tuple(hi - lo for lo, hi in runs))
    return params


def qt_param_specs(qparams, dense_specs):
    """Shardings for a QT-bearing tree from the dense param specs: codes
    inherit the dense spec (same rank, packed last dim divides the same
    way); scale/zero drop the last-dim axis (tiny)."""
    from jax.sharding import PartitionSpec as P

    flat_q, treedef = jax.tree_util.tree_flatten(qparams, is_leaf=is_qt)
    flat_s = jax.tree_util.tree_leaves(dense_specs,
                                       is_leaf=lambda x: isinstance(x, P))
    out = []
    i = 0
    for leaf in flat_q:
        spec = flat_s[i]
        i += 1
        if is_qt(leaf):
            codes_rank = leaf.codes.ndim
            # logical shape may have more dims than 2D-flattened codes
            cs = _fit_spec(spec, codes_rank)
            ss = _fit_spec(spec, leaf.scale.ndim, drop_last=True)
            zs = _fit_spec(spec, leaf.z_lo.ndim, drop_last=True)
            out.append(QT(cs, ss, zs, leaf.shape, leaf.bits, cpb=leaf.cpb))
        else:
            out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def _fit_spec(spec, rank, drop_last=False):
    from jax.sharding import PartitionSpec as P
    entries = list(spec)
    if len(entries) > rank:
        # collapse trailing entries (flattened dims): keep the first ones
        entries = entries[:rank - 1] + [entries[-1]]
    while len(entries) < rank:
        entries.append(None)
    if drop_last and entries:
        entries[-1] = None
    return P(*entries)
