"""Quantized parameter containers for the serving path.

`QT` is a registered pytree node whose codes/scale/zero are array leaves
and whose logical shape/bits are *static* aux data — so a params tree with
QT leaves jits/shards/scans like any other, while the dequantization
happens inside the compiled step (per layer, inside the scan body): HBM
streams int4/int8 codes, not bf16 weights. This is what turns COMQ's 4-bit
codes into a 4× reduction of the decode memory-roofline term (§Perf).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pipeline import is_qtensor
from repro.core.quantizer import pack_int4, unpack_int4

Array = jax.Array


class QT:
    """Quantized tensor: codes (uint8, possibly int4-packed), per-channel
    scale + zero-point; static logical shape."""

    def __init__(self, codes, scale, z_lo, shape: Tuple[int, ...],
                 bits: int):
        self.codes = codes
        self.scale = scale
        self.z_lo = z_lo
        self.shape = tuple(shape)
        self.bits = int(bits)

    def dequant(self, dtype=jnp.bfloat16) -> Array:
        u = self.codes
        if self.bits == 4:
            u = unpack_int4(u)
        s, z = self.scale, self.z_lo
        if u.ndim == s.ndim + 1:   # per-channel scale over the last dim
            s = s[..., None, :]
            z = z[..., None, :]
        q = u.astype(jnp.float32) + z.astype(jnp.float32)
        w = q * s
        # codes keep the logical rank (scan slicing drops leading dims, so
        # `self.shape` is metadata only — u.shape IS the current shape)
        return w.astype(dtype)


def _qt_flatten(qt: QT):
    return (qt.codes, qt.scale, qt.z_lo), (qt.shape, qt.bits)


def _qt_unflatten(aux, children):
    return QT(*children, shape=aux[0], bits=aux[1])


jax.tree_util.register_pytree_node(QT, _qt_flatten, _qt_unflatten)


def is_qt(x) -> bool:
    return isinstance(x, QT)


def dequantize_qt_tree(tree, dtype=jnp.bfloat16):
    """Replace QT leaves with dense weights (called inside scan bodies)."""
    return jax.tree_util.tree_map(
        lambda x: x.dequant(dtype) if is_qt(x) else x, tree,
        is_leaf=is_qt)


def fake_quantize_params(params, cfg, plan, bits: int = 4,
                         quantize_embed: bool = True):
    """Wrap every projection weight in a QT with RTN codes — the *layout*
    transform used by the serving dry-run (real deployments load COMQ codes
    from a quantized checkpoint; the compiled step is identical)."""
    from repro.core.quantizer import init_per_channel, quantize_rtn

    def to_qt(w):
        shape = w.shape
        lead = shape[:-2] if w.ndim > 2 else ()
        w2 = w.reshape(-1, *shape[-2:]) if lead else w[None]
        # per-channel over the last dim, batched over leading dims
        def one(wl):
            m = wl.reshape(-1, wl.shape[-1])
            delta, z_lo, z_hi = init_per_channel(m.astype(jnp.float32),
                                                 bits, 1.0)
            q = quantize_rtn(m.astype(jnp.float32), delta, z_lo, z_hi)
            u = (q - z_lo).astype(jnp.uint8)
            return u, delta, z_lo
        us, deltas, zs = jax.vmap(one)(w2)
        if bits == 4:
            us = pack_int4(us)
        if not lead:
            us, deltas, zs = us[0], deltas[0], zs[0]
        else:
            us = us.reshape(*lead, *us.shape[1:])
            deltas = deltas.reshape(*lead, *deltas.shape[1:])
            zs = zs.reshape(*lead, *zs.shape[1:])
        return QT(us, deltas, zs, shape, bits)

    quantizable = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "w_r", "w_k", "w_v", "w_g", "w_o", "w_in", "w_out",
                   "w_xproj", "unembed"}
    if quantize_embed:
        quantizable = quantizable | {"embed"}

    def walk(node, name=""):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        if name in quantizable and hasattr(node, "ndim") and node.ndim >= 2:
            return to_qt(node)
        return node

    return walk(params)


def qt_param_specs(qparams, dense_specs):
    """Shardings for a QT-bearing tree from the dense param specs: codes
    inherit the dense spec (same rank, packed last dim divides the same
    way); scale/zero drop the last-dim axis (tiny)."""
    from jax.sharding import PartitionSpec as P

    flat_q, treedef = jax.tree_util.tree_flatten(qparams, is_leaf=is_qt)
    flat_s = jax.tree_util.tree_leaves(dense_specs,
                                       is_leaf=lambda x: isinstance(x, P))
    out = []
    i = 0
    for leaf in flat_q:
        spec = flat_s[i]
        i += 1
        if is_qt(leaf):
            codes_rank = leaf.codes.ndim
            # logical shape may have more dims than 2D-flattened codes
            cs = _fit_spec(spec, codes_rank)
            ss = _fit_spec(spec, leaf.scale.ndim, drop_last=True)
            zs = _fit_spec(spec, leaf.z_lo.ndim, drop_last=True)
            out.append(QT(cs, ss, zs, leaf.shape, leaf.bits))
        else:
            out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def _fit_spec(spec, rank, drop_last=False):
    from jax.sharding import PartitionSpec as P
    entries = list(spec)
    if len(entries) > rank:
        # collapse trailing entries (flattened dims): keep the first ones
        entries = entries[:rank - 1] + [entries[-1]]
    while len(entries) < rank:
        entries.append(None)
    if drop_last and entries:
        entries[-1] = None
    return P(*entries)
