"""Numerical guards for the quantization pipeline (DESIGN.md §8.2).

COMQ is hyperparameter-free — "only dot products and rounding" — so
robustness to degenerate calibration has to come from the pipeline, not
from tuning. This module is the single implementation both solvers share:

* **Sentinels** — `sanitize_array` / `gram_health` count non-finite
  entries (and zero Gram diagonals = dead input columns) with one small
  host transfer, and zero out NaN/Inf *only when any were actually
  found*, so the healthy path stays bit-identical to the unguarded one.
* **Escalating diagonal damping** — `damp_hessian(h, mult)` adds
  `mult · mean(diag H) · I`; `DAMP_MULTS` is the escalation schedule a
  failed solve walks (an undamped attempt always runs first).
  `damped_inverse` is the jit/vmap-safe variant the GPTQ baseline uses:
  a `lax.while_loop` that re-inverts under 10× stronger damping until
  H⁻¹ is finite.
* **Fallback chain** — `guarded_solve` retries a failed solve through
  `solver_chain(method)` (comq_blocked: trailing → refresh → RTN;
  comq/gptq: → RTN), escalating damping within each stage, and finally
  data-free RTN, which is finite by construction. Every escalation and
  fallback is recorded as a `GuardEvent` on the `GuardContext` (surfaced
  in `QuantReport.guard_events` / `LayerReport.guard`) and warned loudly
  — degradation is never silent.

Dead columns need no special-casing here: every solver already routes a
zero Gram diagonal to plain rounding per column (the `hg > EPS` where-
clauses in comq/comq_hessian), which is exactly the RTN-per-dead-column
rule; the guards just *count and report* them.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import EPS, QuantSpec

Array = jax.Array

# escalation schedule, as multiples of mean(diag H); an undamped attempt
# always runs first so healthy solves stay bit-identical to the
# unguarded pipeline
DAMP_MULTS = (1e-4, 1e-2, 1e-1, 1.0)

# a solve whose final H-space error exceeds this multiple of its initial
# (grid/RTN) error has diverged, even if finite — escalate
EXPLODE_FACTOR = 10.0


@dataclass
class GuardEvent:
    """One guard intervention, keyed to the leaf it protected."""
    layer: int
    name: str
    kind: str            # nonfinite_tap | nonfinite_gram | nonfinite_weight
    #                    | dead_columns | damping_escalated | fallback
    #                    | sharded_solve_nonfinite
    detail: Dict[str, Any] = field(default_factory=dict)


class GuardContext:
    """Collects GuardEvents across one quantize_model walk. A disabled
    context makes every guard hook a no-op (and bit-exact)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[GuardEvent] = []

    def record(self, layer: int, name: str, kind: str, warn: bool = True,
               **detail) -> GuardEvent:
        ev = GuardEvent(int(layer), str(name), kind, dict(detail))
        self.events.append(ev)
        if warn:
            warnings.warn(
                f"quantization guard [{kind}] layer {layer} leaf {name}: "
                f"{detail}", stacklevel=3)
        return ev

    def by_leaf(self) -> Dict[Tuple[int, str], str]:
        """(layer, name) -> comma-joined distinct event kinds, for the
        per-leaf LayerReport.guard annotation."""
        out: Dict[Tuple[int, str], List[str]] = {}
        for e in self.events:
            kinds = out.setdefault((e.layer, e.name), [])
            if e.kind not in kinds:
                kinds.append(e.kind)
        return {k: ",".join(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# sentinels
# ---------------------------------------------------------------------------

def nonfinite_count(x: Array) -> int:
    """Host int: number of NaN/Inf entries (one small transfer)."""
    # comq: allow(host-sync) sentinel: one small intentional transfer
    return int(jax.device_get(jnp.sum(~jnp.isfinite(x))))

def sanitize_array(x: Array) -> Tuple[Array, int]:
    """(x with NaN/Inf zeroed, how many there were). The replacement runs
    only when the count is nonzero, so clean inputs pass through
    untouched — bit-identity of the healthy path is structural."""
    n_bad = nonfinite_count(x)
    if n_bad:
        x = jnp.where(jnp.isfinite(x), x, jnp.zeros((), x.dtype))
    return x, n_bad


def gram_health(h: Array, w2ds: Sequence[Array] = ()) -> Tuple[int, int,
                                                               List[int]]:
    """(nonfinite entries of H, dead diagonal columns of H, nonfinite
    entries per weight) in ONE batched device transfer — the per-group
    sentinel the pipeline runs before each solve."""
    diag = jnp.diagonal(h, axis1=-2, axis2=-1)
    vals = [jnp.sum(~jnp.isfinite(h)), jnp.sum(diag <= EPS)]
    vals += [jnp.sum(~jnp.isfinite(w)) for w in w2ds]
    # comq: allow(host-sync) sentinel: one batched health transfer per Gram
    out = jax.device_get(jnp.stack([jnp.asarray(v, jnp.int32)
                                    for v in vals]))
    return int(out[0]), int(out[1]), [int(v) for v in out[2:]]


# ---------------------------------------------------------------------------
# escalating diagonal damping
# ---------------------------------------------------------------------------

def damp_hessian(h: Array, mult, diag_mean=None) -> Array:
    """H + mult · mean(diag H) · I. Works batched ((..., m, m) with a
    (...,)-shaped diag_mean) so the vmapped per-expert path can reuse it;
    the mean is floored at EPS so an all-zero H still moves."""
    m = h.shape[-1]
    if diag_mean is None:
        diag_mean = jnp.mean(jnp.diagonal(h, axis1=-2, axis2=-1), axis=-1)
    lam = jnp.asarray(mult * jnp.maximum(
        jnp.asarray(diag_mean, jnp.float32), EPS))
    return h + jnp.eye(m, dtype=h.dtype) * lam[..., None, None]


def damped_inverse(h: Array, start: float = 0.01, diag_mean=None,
                   max_tries: int = 4) -> Tuple[Array, Array]:
    """(H + λI)⁻¹ with λ escalated ×10 per retry until the inverse is
    finite; pure-JAX (lax.while_loop) so it is jit/vmap-safe — the GPTQ
    baseline calls it from inside jitted/vmapped solves. Returns
    (hinv, final multiplier); after max_tries a still-bad inverse is
    NaN→0-scrubbed and left for the caller's fallback chain (the
    post-solve result check catches the exploded error)."""
    m = h.shape[-1]
    if diag_mean is None:
        diag_mean = jnp.mean(jnp.diag(h))
    base = jnp.maximum(jnp.asarray(diag_mean, jnp.float32), EPS)
    eye = jnp.eye(m, dtype=h.dtype)

    def inv_at(mult):
        return jnp.linalg.inv(h + eye * (mult * base))

    def cond(carry):
        hinv, mult, tries = carry
        return (~jnp.all(jnp.isfinite(hinv))) & (tries < max_tries)

    def body(carry):
        _, mult, tries = carry
        mult = mult * 10.0
        return inv_at(mult), mult, tries + 1

    hinv, mult, _ = jax.lax.while_loop(
        cond, body, (inv_at(jnp.float32(start)), jnp.float32(start),
                     jnp.int32(0)))
    hinv = jnp.where(jnp.isfinite(hinv), hinv, 0.0)
    return hinv, mult


# ---------------------------------------------------------------------------
# guarded solve: damping escalation + structured fallback chain
# ---------------------------------------------------------------------------

def solver_chain(method: str) -> Tuple[Tuple[str, Optional[str]], ...]:
    """(method, schedule) stages to try in order. comq_blocked falls back
    to the per-panel-refresh schedule (different FP accumulation path can
    survive conditioning the trailing update cannot) before RTN; the
    row/sequential solvers go straight to RTN."""
    if method == "comq_blocked":
        return (("comq_blocked", "trailing"), ("comq_blocked", "refresh"),
                ("rtn", None))
    if method in ("comq", "gptq"):
        return ((method, None), ("rtn", None))
    return (("rtn", None),)


def result_ok(r, ref_err=None) -> bool:
    """Host bool: scales and errors finite and — when `ref_err` (the
    data-free RTN error on the same grid, the natural do-no-harm
    reference) is given — the final H-space error did not explode past
    EXPLODE_FACTOR × it. The solvers' own errors[0] is NOT a usable
    reference: comq/comq_blocked log the float-Q⁰ error there (≈ 0)."""
    delta = jnp.asarray(r.delta, jnp.float32)
    errs = jnp.asarray(r.errors, jnp.float32)
    ok = jnp.all(jnp.isfinite(delta)) & jnp.all(jnp.isfinite(errs))
    if ref_err is not None:
        base = jnp.maximum(jnp.asarray(ref_err, jnp.float32),
                           jnp.float32(1e-6))
        ok = ok & (errs[-1] <= EXPLODE_FACTOR * base)
    return bool(jax.device_get(ok))  # comq: allow(host-sync) one scalar verdict per solve


def guarded_solve(h: Array, w2d: Array, spec: QuantSpec, method: str, *,
                  block: int = 256, gctx: Optional[GuardContext] = None,
                  layer: int = -1, names: Sequence[str] = ("?",),
                  solve_fn=None, presanitized: bool = False):
    """pipeline.solve with the full guard policy: sanitize inputs, try
    the method undamped (bit-identical when healthy), then escalate
    damping through DAMP_MULTS, then walk solver_chain, and as a last
    resort quantize data-free RTN. Records one GuardEvent per protected
    leaf name for everything it had to do."""
    if solve_fn is None:
        from repro.core.pipeline import solve as solve_fn
    if gctx is None or not gctx.enabled:
        return solve_fn(h, w2d, spec, method, block=block)

    if not presanitized:
        h, n_bad = sanitize_array(h)
        if n_bad:
            for nm in names:
                gctx.record(layer, nm, "nonfinite_gram", count=n_bad)
        w2d, n_badw = sanitize_array(w2d)
        if n_badw:
            for nm in names:
                gctx.record(layer, nm, "nonfinite_weight", count=n_badw)
        # comq: allow(host-sync) sentinel: one scalar per guarded solve
        n_dead = int(jax.device_get(jnp.sum(jnp.diag(h) <= EPS)))
        if n_dead:
            for nm in names:
                gctx.record(layer, nm, "dead_columns", warn=False,
                            count=n_dead)

    from repro.core.baselines import rtn_quantize   # lazy: baselines imports us
    # the do-no-harm explosion reference: the data-free RTN error on the
    # same (sanitized) H — a solve that lands >10× above plain rounding
    # has diverged even if every value is finite
    ref_err = rtn_quantize(w2d, spec, h=h).errors[-1]
    diag_mean = jnp.mean(jnp.diag(h))
    for stage, (meth, schedule) in enumerate(solver_chain(method)):
        tag = meth if schedule in (None, "trailing") else f"{meth}:{schedule}"
        for mult in (0.0,) + DAMP_MULTS:
            hd = h if mult == 0.0 else damp_hessian(h, mult, diag_mean)
            r = solve_fn(hd, w2d, spec, meth, block=block, schedule=schedule)
            if result_ok(r, ref_err):
                if mult:
                    for nm in names:
                        gctx.record(layer, nm, "damping_escalated",
                                    mult=mult, solver=tag)
                if stage:
                    for nm in names:
                        gctx.record(layer, nm, "fallback", solver=tag)
                return r
    r = rtn_quantize(w2d, spec)     # data-free: finite by construction
    for nm in names:
        gctx.record(layer, nm, "fallback", solver="rtn_no_h")
    return r
