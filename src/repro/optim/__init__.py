from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,  # noqa: F401
                               clip_by_global_norm, global_norm)
from repro.optim.schedule import constant, warmup_cosine  # noqa: F401
