"""AdamW in pure JAX with optional 8-bit (blockwise-quantized) moments.

The 8-bit moment state (per-block absmax scales, block=256) cuts optimizer
memory from 8 to ~2.3 bytes/param — what lets the 400B llama4-maverick
config fit a single 256-chip pod (DESIGN.md §4). Quantization uses
stochastic-free deterministic rounding; the update math runs in f32 after
dequantization.

The signed first moment carries *error feedback*: the int8 rounding
residual (≤ scale/2 per element) is re-quantized to 2-bit codes on the
same block scale and stored packed 4-per-byte next to the int8 codes, and
decoding adds it back. The EMA recursion m ← β₁·decode(m) + (1−β₁)·g then
runs on a value within scale/6 of the exact f32 moment instead of scale/2,
so the quantization error no longer compounds as a β₁-geometric drift of
the whole trajectory (the compressed_psum EF principle, at 1/4 bit cost;
without it the int8 run walks off the f32 one — the former
test_int8_moments_track_f32 failure). The non-negative second moment keeps
the power-law codec: its error enters through a sqrt in the denominator
and is not integrated by an EMA of comparable decay, so it stays EF-free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"      # float32 | int8


# ---------------------------------------------------------------------------
# blockwise int8 moment codec
# ---------------------------------------------------------------------------

def _blocked(x: Array):
    d = x.shape[-1] if x.ndim else 1
    x = x.reshape(*x.shape, 1) if x.ndim == 0 else x
    pad = (-d) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return xp, xp.reshape(*xp.shape[:-1], -1, BLOCK)


def _pack2(c: Array) -> Array:
    """{0..3} codes (last dim % 4 == 0) packed 4-per-uint8, low pair first."""
    c4 = c.reshape(*c.shape[:-1], -1, 4)
    return (c4[..., 0] | (c4[..., 1] << 2) | (c4[..., 2] << 4)
            | (c4[..., 3] << 6)).astype(jnp.uint8)


def _unpack2(b: Array) -> Array:
    parts = jnp.stack([(b >> (2 * i)) & jnp.uint8(3) for i in range(4)],
                      axis=-1)
    return parts.reshape(*b.shape[:-1], b.shape[-1] * 4)


def _q8_encode(x: Array) -> Dict[str, Array]:
    """Blockwise (last-dim, 256) linear int8 for the signed first moment,
    with the rounding residual carried as 2-bit error-feedback codes
    ("ef", packed 4/byte on the same block scale; see module docstring).
    q/scale/ef keep the param's rank so its PartitionSpec applies to all."""
    xp, blocks = _blocked(x)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    resid = blocks - q * scale[..., None]          # |resid| ≤ scale/2
    step = scale[..., None] / 3.0
    eq = (jnp.clip(jnp.round(resid / step), -2, 1) + 2).astype(jnp.uint8)
    return {"q": q.reshape(xp.shape).astype(jnp.int8),
            "scale": scale.astype(jnp.float32),
            "ef": _pack2(eq.reshape(xp.shape))}


def _q8_decode(enc: Dict[str, Array], shape) -> Array:
    q = enc["q"]
    blocks = q.reshape(*q.shape[:-1], -1, BLOCK).astype(jnp.float32)
    x = blocks * enc["scale"][..., None]
    if "ef" in enc:                                # error-feedback add-back
        eq = _unpack2(enc["ef"]).astype(jnp.float32) - 2.0
        x = x + (eq.reshape(*q.shape[:-1], -1, BLOCK)
                 * (enc["scale"][..., None] / 3.0))
    x = x.reshape(q.shape)
    d = shape[-1] if len(shape) else 1
    return x[..., :d].reshape(shape)


def _q8_encode_pow(x: Array) -> Dict[str, Array]:
    """Power-law uint8 codec for the non-negative second moment: linear
    int8 rounds small v to exactly 0 and 1/√v̂ explodes; storing
    (v/absmax)^(1/4) keeps ~4 decades of relative resolution (the same
    reason bitsandbytes uses dynamic-exponent quantization)."""
    xp, blocks = _blocked(x)
    absmax = jnp.max(blocks, axis=-1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    frac = jnp.clip(blocks / scale[..., None], 0.0, 1.0)
    q = jnp.round(jnp.sqrt(jnp.sqrt(frac)) * 255.0)
    return {"q": q.reshape(xp.shape).astype(jnp.uint8),
            "scale": scale.astype(jnp.float32)}


def _q8_decode_pow(enc: Dict[str, Array], shape) -> Array:
    q = enc["q"]
    blocks = q.reshape(*q.shape[:-1], -1, BLOCK).astype(jnp.float32) / 255.0
    frac = jnp.square(jnp.square(blocks))
    x = (frac * enc["scale"][..., None]).reshape(q.shape)
    d = shape[-1] if len(shape) else 1
    return x[..., :d].reshape(shape)


def _moment_init(p: Array, dtype: str, signed: bool = True):
    z = jnp.zeros_like(p, jnp.float32)
    if dtype != "int8":
        return z
    return _q8_encode(z) if signed else _q8_encode_pow(z)


def _moment_read(m, dtype: str, shape, signed: bool = True) -> Array:
    if dtype != "int8":
        return m
    return _q8_decode(m, shape) if signed else _q8_decode_pow(m, shape)


def _moment_write(val: Array, dtype: str, signed: bool = True):
    if dtype != "int8":
        return val
    return _q8_encode(val) if signed else _q8_encode_pow(val)


# ---------------------------------------------------------------------------


def adamw_init(params: PyTree, cfg: AdamWConfig) -> Dict[str, Any]:
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(
            lambda p: _moment_init(p, cfg.moment_dtype, True), params),
        "v": jax.tree_util.tree_map(
            lambda p: _moment_init(p, cfg.moment_dtype, False), params),
    }


def adamw_update(grads: PyTree, state: Dict[str, Any], params: PyTree,
                 cfg: AdamWConfig, lr: Array) -> Tuple[PyTree, Dict[str, Any]]:
    """Returns (new_params, new_state). Master params stay f32."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    is_leaf = lambda x: isinstance(x, dict) and "q" in x and "scale" in x

    def upd(p, g, m_enc, v_enc, token):
        # `token` chains leaf updates sequentially: without it XLA keeps
        # every leaf's decoded-f32 moment temporaries live simultaneously
        # (~10 param-tree-sized buffers at 100B+ scale). The chain bounds
        # peak temp to one leaf; elementwise updates are HBM-bound anyway.
        # optimization_barrier prevents the dependency from being folded.
        g, _ = jax.lax.optimization_barrier((g.astype(jnp.float32), token))
        m = _moment_read(m_enc, cfg.moment_dtype, p.shape, True)
        v = _moment_read(v_enc, cfg.moment_dtype, p.shape, False)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        p_new = p - lr * delta
        new_token = jnp.min(delta)
        return (p_new.astype(p.dtype),
                _moment_write(m, cfg.moment_dtype, True),
                _moment_write(v, cfg.moment_dtype, False), new_token)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_leaf)[0]
    flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_leaf)[0]
    out = []
    token = jnp.float32(0.0)
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        res = upd(p, g, m, v, token)
        token = res[3]
        out.append(res)
    new_p = tdef.unflatten([o[0] for o in out])
    mdef = jax.tree_util.tree_structure(state["m"], is_leaf=is_leaf)
    new_m = jax.tree_util.tree_unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(mdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, Array]:
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * factor, tree), norm
