"""Serving launcher: continuous-batching generation from a (optionally
COMQ-quantized, optionally packed-on-disk) checkpoint or a fresh init.

    # quantize, save the packed checkpoint, serve packed (no materialize)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --quantize --bits 4 --save-quantized /tmp/q.pkl \
        --num-requests 4 --max-new 16 --mixed --stagger 2

    # later runs start straight from the packed checkpoint
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --load-quantized /tmp/q.pkl --num-requests 4 --max-new 16

    # fault-tolerant serving: journal every request, inject a kill, then
    # resume — the replayed streams are token-identical
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --journal /tmp/j --inject kill:5 --restarts 2

`--engine paged` (default) drives serve.Runtime — paged KV cache,
priority admission with preemption-by-page-reclaim (`--admission reserve`
keeps the legacy full-lifetime reservation for A/B), mixed prompt
lengths, staggered arrivals. `--engine static` keeps the equal-length
Engine baseline. `--materialize` dequantizes to a dense tree first;
without it quantized params are served as a packed QT-leaf tree.

`--journal DIR` appends every request lifecycle to a crash-replay journal
(fsync-gated); `--resume` rebuilds the queue from DIR instead of
synthesizing prompts; `--restarts N` wraps the drain in the
`ft.run_with_restarts` supervisor (progress = retired requests, so the
attempt budget resets whenever any request completes); `--inject SPEC`
seeds deterministic faults (e.g. "page_alloc:3+7,kill:5").
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import (load_packed_ckpt, pack_tree, save_packed_ckpt,
                        strip_for_serving, tree_bytes, unpack_tree)
from repro.configs import get_config, get_smoke_config
from repro.core import (QuantSpec, materialize, quantize_model,
                        serving_params)
from repro.ft import (FaultInjector, Heartbeat, Journal, SimulatedKill,
                      run_with_restarts)
from repro.models import BuildPlan, count_params, init_params
from repro.obs import MetricsRegistry, Tracer, next_trace_path
from repro.serve import (Engine, Runtime, ServeConfig, blocks_for,
                         recover_runtime)


def _quantize(params, cfg, plan, bits: int):
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    ve = None
    if cfg.family == "vlm":
        ve = jax.random.normal(
            key, (4, cfg.cross_attn.n_vision_tokens,
                  cfg.cross_attn.vision_dim), jnp.bfloat16)
    spec = QuantSpec(bits=bits, granularity="per_channel",
                     lam=0.9, sweeps=3, order="greedy")
    qparams, report = quantize_model(params, cfg, plan, calib, spec,
                                     vision_embeds=ve)
    print(f"quantized {len(report.layers)} projections; COMQ vs RTN "
          f"reconstruction improvement {report.total_improvement():.1%}")
    return qparams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--save-quantized", metavar="PATH", default=None,
                    help="pack_tree the quantized tree to PATH "
                         "(headered + crc32-checksummed single file)")
    ap.add_argument("--load-quantized", metavar="PATH", default=None,
                    help="serve from a packed quantized tree on disk "
                         "instead of re-quantizing (validated header)")
    ap.add_argument("--materialize", action="store_true",
                    help="dequantize to dense before serving (default: "
                         "serve the packed QT tree)")
    ap.add_argument("--engine", choices=("paged", "static"), default="paged")
    ap.add_argument("--num-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--mixed", action="store_true",
                    help="vary prompt lengths across requests")
    ap.add_argument("--stagger", type=int, default=0, metavar="N",
                    help="submit N requests up front, the rest one per "
                         "decode step (arrival-over-time)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    metavar="ID", help="stop-token id(s): generation ends "
                    "when one is sampled (repeatable; paged engine only)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="0 -> sized for num_requests at full length")
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 4, 8),
                    help="quantize the paged KV pool: 8/4-bit page codes "
                         "with per-(layer, page, kv_head) scales, "
                         "dequantized inside the attention kernel "
                         "(0 = bf16 pages; paged engine only)")
    ap.add_argument("--admission", choices=("preempt", "reserve"),
                    default="preempt",
                    help="preempt: incremental pages + preemption-by-page-"
                         "reclaim; reserve: legacy full-lifetime "
                         "reservation (A/B)")
    ap.add_argument("--priorities", default=None, metavar="CSV",
                    help="per-request priority classes (lower = more "
                         "urgent), e.g. '0,1,1,0'; cycled if shorter "
                         "than --num-requests")
    ap.add_argument("--journal", default=None, metavar="DIR",
                    help="append a crash-replay request journal to DIR "
                         "(paged engine only)")
    ap.add_argument("--resume", action="store_true",
                    help="rebuild the queue from --journal DIR and replay "
                         "in-flight requests instead of submitting new "
                         "ones")
    ap.add_argument("--restarts", type=int, default=0, metavar="N",
                    help="supervise the drain with ft.run_with_restarts: "
                         "recover from the journal up to N consecutive "
                         "no-progress crashes (requires --journal)")
    ap.add_argument("--inject", default=None, metavar="SPEC",
                    help="deterministic fault schedule, e.g. "
                         "'page_alloc:3+7,decode_step:5,kill:9'")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write a Chrome-trace JSON (host spans + per-"
                         "request lifecycle events) to DIR/serve.gN."
                         "trace.json; inspect with chrome://tracing, "
                         "Perfetto, or `python -m repro.obs.report DIR` "
                         "(paged engine only)")
    ap.add_argument("--metrics", default=None, metavar="DIR",
                    help="dump the metrics registry (TTFT/ITL histograms, "
                         "pool gauges, preemption counters) to "
                         "DIR/metrics.jsonl + DIR/metrics.prom "
                         "(paged engine only)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan = BuildPlan(remat=False)
    if args.kv_bits:
        if args.engine == "static":
            print("note: --kv-bits quantizes the paged pool; the static "
                  "engine's dense cache ignores it")
        else:
            plan = plan.replace(kv_bits=args.kv_bits)
    if args.engine == "paged" and (cfg.attn_free or cfg.parallel_ssm_heads
                                   or cfg.family == "vlm"):
        print(f"note: {cfg.family}/attention-free archs use the dense-"
              "cache static engine (paged runtime is attention-family "
              "only; see ROADMAP)")
        args.engine = "static"
    if (args.resume or args.restarts) and not args.journal:
        raise SystemExit("--resume/--restarts need --journal DIR")
    # bf16 deployment baseline: 2 bytes/param regardless of master dtype
    # (analytic count — no dense tree is allocated just to measure it)
    bf16_bytes = 2 * count_params(cfg, plan)

    params = None
    qparams = None
    if args.load_quantized:
        blob = load_packed_ckpt(args.load_quantized)
        saved_arch = blob.get("arch")
        if saved_arch is not None and saved_arch != cfg.name:
            raise SystemExit(
                f"--load-quantized checkpoint is for arch {saved_arch!r}, "
                f"not {cfg.name!r} (pass the matching --arch/--smoke)")
        packed = jax.tree_util.tree_map(
            lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x,
            blob["tree"])
        print(f"loaded packed tree: {tree_bytes(packed):,} bytes vs "
              f"{bf16_bytes:,} bf16 "
              f"({bf16_bytes / max(tree_bytes(packed), 1):.1f}x smaller)")
        qparams = unpack_tree(packed)
    elif args.quantize:
        params = init_params(jax.random.PRNGKey(0), cfg, plan)
        qparams = _quantize(params, cfg, plan, args.bits)

    if qparams is not None and args.save_quantized:
        packed = pack_tree(strip_for_serving(qparams))
        host = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a))
            if hasattr(a, "dtype") else a, packed)
        save_packed_ckpt(args.save_quantized, host, bits=args.bits,
                         arch=cfg.name)
        print(f"saved packed tree to {args.save_quantized}: "
              f"{tree_bytes(packed):,} bytes vs {bf16_bytes:,} bf16 "
              f"({bf16_bytes / tree_bytes(packed):.1f}x smaller)")

    packed_serve = False
    if qparams is not None:
        if args.materialize or args.engine == "static":
            params = materialize(qparams, cfg)
        else:
            params = serving_params(qparams, cfg)
            packed_serve = True
    elif params is None:
        params = init_params(jax.random.PRNGKey(0), cfg, plan)

    rs = np.random.RandomState(0)
    lens = [args.prompt_len] * args.num_requests
    if args.mixed:
        if args.engine == "static":
            print("note: --engine static only batches equal-length "
                  "prompts; ignoring --mixed")
        else:
            lens = [max(4, int(l)) for l in
                    rs.randint(args.prompt_len // 2, args.prompt_len + 1,
                               args.num_requests)]
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    priorities = [0] * args.num_requests
    if args.priorities:
        cycle = [int(p) for p in args.priorities.split(",")]
        priorities = [cycle[i % len(cycle)] for i in range(args.num_requests)]

    t0 = time.time()
    if args.engine == "static":
        engine = Engine(params, cfg, plan,
                        max_len=args.prompt_len + args.max_new)
        out = engine.generate_batch(
            np.stack(prompts),
            max_new_tokens=args.max_new, temperature=args.temperature)
        dt = time.time() - t0
        print(json.dumps({
            "arch": cfg.name, "engine": "static",
            "requests": args.num_requests, "new_tokens": int(out.size),
            "seconds": round(dt, 2),
            "tok_per_s": round(out.size / dt, 1),
            "sample": out[0, :8].tolist(),
        }))
        return

    bucket = 1 << max(args.prompt_len - 1, 1).bit_length()
    maxb = blocks_for(bucket + args.max_new, args.block_size)
    num_blocks = args.num_blocks or maxb * min(args.num_requests, 8)
    serve_cfg = ServeConfig(max_slots=min(args.num_requests, 8),
                            block_size=args.block_size,
                            num_blocks=num_blocks,
                            buckets=(bucket // 4, bucket // 2, bucket),
                            max_blocks_per_slot=maxb,
                            policy=args.admission)
    if plan.kv_bits:
        from repro.serve import paged_cache_bytes
        pool_b = paged_cache_bytes(cfg, plan, num_blocks, args.block_size)
        bf16_b = paged_cache_bytes(cfg, plan.replace(kv_bits=0),
                                   num_blocks, args.block_size)
        print(f"kv pages: int{plan.kv_bits} pool {pool_b:,} bytes vs "
              f"{bf16_b:,} bf16 ({bf16_b / pool_b:.2f}x smaller)")
    injector = FaultInjector.parse(args.inject) if args.inject else None
    # observability (DESIGN.md §10): absent flags keep the runtime on the
    # zero-cost null singletons (the static-engine branch returned above)
    tracer = Tracer(run=f"serve:{cfg.name}") if args.trace else None
    registry = (MetricsRegistry(run=f"serve:{cfg.name}")
                if args.metrics else None)
    hb = Heartbeat(args.journal, host_id=0) if args.journal else None
    kw = dict(max_new_tokens=args.max_new, temperature=args.temperature,
              top_k=args.top_k, top_p=args.top_p,
              stop_tokens=tuple(args.stop_token))

    box = {}           # box["rt"] is set as soon as a runtime exists, so a
                       # crash inside build() still lets the supervisor
                       # close that attempt's journal handle before retrying

    def build(resume: bool):
        if resume:
            rt, state = recover_runtime(params, cfg, plan, args.journal,
                                        serve_cfg, injector=injector,
                                        tracer=tracer, metrics=registry)
            box["rt"] = rt
            print(f"resume: {len(state.completed)} retired in journal, "
                  f"replaying {len(state.inflight)} in-flight")
            reqs = list(rt.scheduler.queue)
            if not args.resume:
                # restart of *this* launch: prompts map 1:1 to rids in
                # submission order, so any prompt past max_rid crashed
                # before its submit record was durable — re-submit it
                # rather than lose it
                for p, pr in zip(prompts[state.max_rid + 1:],
                                 priorities[state.max_rid + 1:]):
                    reqs.append(rt.submit(p, priority=pr, **kw))
            return rt, reqs
        journal = Journal(args.journal) if args.journal else None
        rt = Runtime(params, cfg, plan, serve_cfg, journal=journal,
                     injector=injector, tracer=tracer, metrics=registry)
        box["rt"] = rt
        n_up_front = args.stagger if args.stagger > 0 else len(prompts)
        reqs = [rt.submit(p, priority=pr, **kw)
                for p, pr in zip(prompts[:n_up_front],
                                 priorities[:n_up_front])]
        for p, pr in zip(prompts[n_up_front:], priorities[n_up_front:]):
            rt.step()
            reqs.append(rt.submit(p, priority=pr, **kw))
        return rt, reqs

    if args.restarts > 0:

        def attempt(_):
            prev = box.pop("rt", None)
            if prev is not None and prev.journal is not None:
                prev.journal.close()
            # a crash inside build() (e.g. during staggered submits) has
            # already journaled some requests, so decide resume from the
            # journal itself, not from whether build() ever returned
            resume = args.resume or bool(Journal.replay(args.journal).records)
            rt, reqs = build(resume)
            box["reqs"] = reqs
            if hb is not None:   # watchdog file inspectable mid-run
                hb.beat(rt.steps, metrics=rt.metrics_snapshot())
            out = rt.run()
            if hb is not None:
                hb.beat(rt.steps, metrics=rt.metrics_snapshot())
            return out

        def progress():
            return len(Journal.replay(args.journal).completed)

        metrics = run_with_restarts(
            attempt, progress, max_restarts=args.restarts,
            exceptions=(RuntimeError, SimulatedKill), backoff_s=0.0)
        rt, reqs = box["rt"], box["reqs"]
    else:
        rt, reqs = build(args.resume)
        metrics = rt.run()
        if hb is not None:
            hb.beat(rt.steps, metrics=rt.metrics_snapshot())

    if tracer is not None:
        tpath = next_trace_path(args.trace, "serve")
        tracer.save(tpath)
        print(f"trace: {tpath} ({len(tracer.events)} events)")
    if registry is not None:
        registry.dump_jsonl(os.path.join(args.metrics, "metrics.jsonl"))
        registry.dump_prometheus(os.path.join(args.metrics, "metrics.prom"))
        print(f"metrics: {args.metrics}/metrics.jsonl + metrics.prom")

    metrics.update({
        "arch": cfg.name, "engine": "paged",
        "admission": args.admission,
        "packed_qt": packed_serve,
        "prompt_lens": [int(r.prompt_len) for r in reqs],
        "ttft_s": [round(t, 4) for t in metrics["ttft_s"]],
        "sample": reqs[0].out_tokens[:8] if reqs else [],
    })
    if injector is not None:
        metrics["faults_fired"] = injector.fired
    metrics = {k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in metrics.items()}
    print(json.dumps(metrics))


if __name__ == "__main__":
    main()
