"""Serving launcher: batched generation from a (optionally COMQ-quantized)
checkpoint or a fresh init.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --quantize --bits 4 --num-requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import QuantSpec, materialize, quantize_model
from repro.models import BuildPlan, init_params
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--num-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan = BuildPlan(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, plan)

    if args.quantize:
        calib = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
        ve = None
        if cfg.family == "vlm":
            ve = jax.random.normal(
                key, (4, cfg.cross_attn.n_vision_tokens,
                      cfg.cross_attn.vision_dim), jnp.bfloat16)
        spec = QuantSpec(bits=args.bits, granularity="per_channel",
                         lam=0.9, sweeps=3, order="greedy")
        qparams, report = quantize_model(params, cfg, plan, calib, spec,
                                         vision_embeds=ve)
        params = materialize(qparams, cfg)
        print(f"quantized {len(report.layers)} projections; COMQ vs RTN "
              f"reconstruction improvement {report.total_improvement():.1%}")

    engine = Engine(params, cfg, plan, max_len=args.prompt_len + args.max_new)
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (args.num_requests, args.prompt_len))
    t0 = time.time()
    out = engine.generate_batch(prompts, max_new_tokens=args.max_new,
                                temperature=args.temperature)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "requests": args.num_requests,
        "new_tokens": int(out.size), "seconds": round(dt, 2),
        "tok_per_s": round(out.size / dt, 1),
        "sample": out[0, :8].tolist(),
    }))


if __name__ == "__main__":
    main()
