"""Serving launcher: continuous-batching generation from a (optionally
COMQ-quantized, optionally packed-on-disk) checkpoint or a fresh init.

    # quantize, save the packed checkpoint, serve packed (no materialize)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --quantize --bits 4 --save-quantized /tmp/q.pkl \
        --num-requests 4 --max-new 16 --mixed --stagger 2

    # later runs start straight from the packed checkpoint
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --load-quantized /tmp/q.pkl --num-requests 4 --max-new 16

`--engine paged` (default) drives serve.Runtime — paged KV cache, FCFS
scheduler, mixed prompt lengths, staggered arrivals. `--engine static`
keeps the equal-length Engine baseline. `--materialize` dequantizes to a
dense tree first (the pre-runtime behavior); without it quantized params
are served as a packed QT-leaf tree.
"""
from __future__ import annotations

import argparse
import json
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import (pack_tree, strip_for_serving, tree_bytes,
                        unpack_tree)
from repro.configs import get_config, get_smoke_config
from repro.core import (QuantSpec, materialize, quantize_model,
                        serving_params)
from repro.models import BuildPlan, count_params, init_params
from repro.serve import Engine, Runtime, ServeConfig, blocks_for


def _quantize(params, cfg, plan, bits: int):
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    ve = None
    if cfg.family == "vlm":
        ve = jax.random.normal(
            key, (4, cfg.cross_attn.n_vision_tokens,
                  cfg.cross_attn.vision_dim), jnp.bfloat16)
    spec = QuantSpec(bits=bits, granularity="per_channel",
                     lam=0.9, sweeps=3, order="greedy")
    qparams, report = quantize_model(params, cfg, plan, calib, spec,
                                     vision_embeds=ve)
    print(f"quantized {len(report.layers)} projections; COMQ vs RTN "
          f"reconstruction improvement {report.total_improvement():.1%}")
    return qparams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--save-quantized", metavar="PATH", default=None,
                    help="pack_tree the quantized tree to PATH (pickle)")
    ap.add_argument("--load-quantized", metavar="PATH", default=None,
                    help="serve from a packed quantized tree on disk "
                         "instead of re-quantizing")
    ap.add_argument("--materialize", action="store_true",
                    help="dequantize to dense before serving (default: "
                         "serve the packed QT tree)")
    ap.add_argument("--engine", choices=("paged", "static"), default="paged")
    ap.add_argument("--num-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--mixed", action="store_true",
                    help="vary prompt lengths across requests")
    ap.add_argument("--stagger", type=int, default=0, metavar="N",
                    help="submit N requests up front, the rest one per "
                         "decode step (arrival-over-time)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    metavar="ID", help="stop-token id(s): generation ends "
                    "when one is sampled (repeatable; paged engine only)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="0 -> sized for num_requests at full length")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan = BuildPlan(remat=False)
    if args.engine == "paged" and (cfg.attn_free or cfg.parallel_ssm_heads
                                   or cfg.family == "vlm"):
        print(f"note: {cfg.family}/attention-free archs use the dense-"
              "cache static engine (paged runtime is attention-family "
              "only; see ROADMAP)")
        args.engine = "static"
    # bf16 deployment baseline: 2 bytes/param regardless of master dtype
    # (analytic count — no dense tree is allocated just to measure it)
    bf16_bytes = 2 * count_params(cfg, plan)

    params = None
    qparams = None
    if args.load_quantized:
        with open(args.load_quantized, "rb") as f:
            blob = pickle.load(f)
        saved_arch = blob.get("arch")
        if saved_arch is not None and saved_arch != cfg.name:
            raise SystemExit(
                f"--load-quantized checkpoint is for arch {saved_arch!r}, "
                f"not {cfg.name!r} (pass the matching --arch/--smoke)")
        packed = jax.tree_util.tree_map(
            lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x,
            blob["tree"])
        print(f"loaded packed tree: {tree_bytes(packed):,} bytes vs "
              f"{bf16_bytes:,} bf16 "
              f"({bf16_bytes / max(tree_bytes(packed), 1):.1f}x smaller)")
        qparams = unpack_tree(packed)
    elif args.quantize:
        params = init_params(jax.random.PRNGKey(0), cfg, plan)
        qparams = _quantize(params, cfg, plan, args.bits)

    if qparams is not None and args.save_quantized:
        packed = pack_tree(strip_for_serving(qparams))
        host = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a))
            if hasattr(a, "dtype") else a, packed)
        with open(args.save_quantized, "wb") as f:
            pickle.dump({"tree": host, "bits": args.bits, "arch": cfg.name},
                        f)
        print(f"saved packed tree to {args.save_quantized}: "
              f"{tree_bytes(packed):,} bytes vs {bf16_bytes:,} bf16 "
              f"({bf16_bytes / tree_bytes(packed):.1f}x smaller)")

    packed_serve = False
    if qparams is not None:
        if args.materialize or args.engine == "static":
            params = materialize(qparams, cfg)
        else:
            params = serving_params(qparams, cfg)
            packed_serve = True
    elif params is None:
        params = init_params(jax.random.PRNGKey(0), cfg, plan)

    rs = np.random.RandomState(0)
    lens = [args.prompt_len] * args.num_requests
    if args.mixed:
        if args.engine == "static":
            print("note: --engine static only batches equal-length "
                  "prompts; ignoring --mixed")
        else:
            lens = [max(4, int(l)) for l in
                    rs.randint(args.prompt_len // 2, args.prompt_len + 1,
                               args.num_requests)]
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]

    t0 = time.time()
    if args.engine == "static":
        engine = Engine(params, cfg, plan,
                        max_len=args.prompt_len + args.max_new)
        out = engine.generate_batch(
            np.stack(prompts),
            max_new_tokens=args.max_new, temperature=args.temperature)
        dt = time.time() - t0
        print(json.dumps({
            "arch": cfg.name, "engine": "static",
            "requests": args.num_requests, "new_tokens": int(out.size),
            "seconds": round(dt, 2),
            "tok_per_s": round(out.size / dt, 1),
            "sample": out[0, :8].tolist(),
        }))
        return

    bucket = 1 << max(args.prompt_len - 1, 1).bit_length()
    maxb = blocks_for(bucket + args.max_new, args.block_size)
    num_blocks = args.num_blocks or maxb * min(args.num_requests, 8)
    rt = Runtime(params, cfg, plan,
                 ServeConfig(max_slots=min(args.num_requests, 8),
                             block_size=args.block_size,
                             num_blocks=num_blocks,
                             buckets=(bucket // 4, bucket // 2, bucket),
                             max_blocks_per_slot=maxb))
    kw = dict(max_new_tokens=args.max_new, temperature=args.temperature,
              top_k=args.top_k, top_p=args.top_p,
              stop_tokens=tuple(args.stop_token))
    n_up_front = args.stagger if args.stagger > 0 else len(prompts)
    reqs = [rt.submit(p, **kw) for p in prompts[:n_up_front]]
    for p in prompts[n_up_front:]:
        rt.step()
        reqs.append(rt.submit(p, **kw))
    metrics = rt.run()
    metrics.update({
        "arch": cfg.name, "engine": "paged",
        "packed_qt": packed_serve,
        "prompt_lens": lens,
        "ttft_s": [round(t, 4) for t in metrics["ttft_s"]],
        "sample": reqs[0].out_tokens[:8],
    })
    metrics = {k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in metrics.items()}
    print(json.dumps(metrics))


if __name__ == "__main__":
    main()
