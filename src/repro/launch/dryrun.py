import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh and record memory/cost/collective stats.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline via repro.roofline.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.dist.sharding import (batch_dim_spec, cache_specs,
                                 input_batch_specs, make_constrain, named,
                                 param_specs, dp_size, tp_size)
from repro.launch.mesh import make_production_mesh
from repro.models import BuildPlan
from repro.models.model import (decode_step, init_cache, init_params,
                                input_specs, prefill)
from repro.optim import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

BIG_ARCHES_INT8_OPT = {"llama4-maverick-400b-a17b", "mistral-large-123b",
                       "llama-3.2-vision-90b", "deepseek-67b"}


def default_microbatches(gb: int, dp: int, per_shard: int = 2) -> int:
    local = max(gb // dp, 1)
    return max(1, local // per_shard)


def build_plan(cfg, mesh, shape, overrides) -> BuildPlan:
    seq_shard = overrides.get("seq_shard")
    if seq_shard is None:
        seq_shard = (shape.kind == "train" and cfg.family != "encoder"
                     and shape.seq_len % tp_size(mesh) == 0)
    constrain = make_constrain(
        mesh, shape.global_batch, seq_shard=seq_shard,
        block_gather=overrides.get("block_gather", False),
        ffn_shard=overrides.get("ffn_shard", False))
    return BuildPlan(
        tp=tp_size(mesh),
        attn_block_size=overrides.get("attn_block_size", 512),
        moe_token_chunk=overrides.get("moe_token_chunk", 4096),
        remat=(shape.kind == "train"),
        cache_quant=bool(overrides.get("cache_quant", False)),
        constrain=constrain,
    )


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    overrides = overrides or {}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = build_plan(cfg, mesh, shape, overrides)
    t0 = time.time()

    params_shape = jax.eval_shape(
        lambda k: init_params(k, cfg, plan), jax.random.PRNGKey(0))
    if shape.kind != "train":
        # serving runs from a bf16 inference checkpoint (f32 master is a
        # training-only artifact)
        params_shape = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 else s, params_shape)
    pspecs = param_specs(params_shape, mesh)
    qbits = overrides.get("quantized_bits", 0)
    if qbits and shape.kind != "train":
        # COMQ-quantized serving: weights stream as int4/int8 codes and
        # dequantize per layer inside the scan body (core/apply.py)
        from repro.core.apply import fake_quantize_params, qt_param_specs
        dense_specs = pspecs
        params_shape = jax.eval_shape(
            lambda p: fake_quantize_params(p, cfg, plan, bits=qbits),
            params_shape)
        pspecs = qt_param_specs(params_shape, dense_specs)
    specs = input_specs(cfg, shape, plan)
    bspecs = input_batch_specs(
        {k: v for k, v in specs.items() if k != "cache"}, mesh,
        shape.global_batch)

    with mesh:
        if shape.kind == "train":
            moment_dtype = overrides.get(
                "moment_dtype",
                "int8" if arch in BIG_ARCHES_INT8_OPT else "float32")
            adamw_cfg = AdamWConfig(moment_dtype=moment_dtype)
            from repro.configs.base import RunConfig
            run_cfg = RunConfig(
                arch=arch, shape=shape_name,
                microbatches=overrides.get(
                    "microbatches",
                    default_microbatches(shape.global_batch, dp_size(mesh))))
            step_fn = make_train_step(cfg, plan, run_cfg, adamw_cfg)
            state_shape = jax.eval_shape(
                lambda ps: init_train_state(ps, adamw_cfg, run_cfg),
                params_shape)
            ospecs = _opt_specs(state_shape, pspecs)
            in_shardings = (named(mesh, ospecs), named(mesh, bspecs))
            out_shardings = (named(mesh, ospecs),
                             named(mesh, jax.tree_util.tree_map(
                                 lambda *_: P(), {"loss": 0, "grad_norm": 0,
                                                  "lr": 0, "step": 0})))
            lowered = jax.jit(step_fn, in_shardings=in_shardings,
                              out_shardings=out_shardings,
                              donate_argnums=(0,)).lower(
                state_shape, {k: specs[k] for k in bspecs})
        elif shape.kind == "prefill":
            b = batch_dim_spec(mesh, shape.global_batch)
            cache_shape = jax.eval_shape(
                lambda: init_cache(cfg, plan, shape.global_batch,
                                   shape.seq_len))
            cspecs = cache_specs(cache_shape, mesh, shape.global_batch)

            def prefill_fn(params, tokens, vision_embeds=None):
                return prefill(params, cfg, plan, tokens,
                               vision_embeds=vision_embeds)

            args = [params_shape, specs["tokens"]]
            in_sh = [named(mesh, pspecs), named(mesh, bspecs["tokens"])]
            if "vision_embeds" in specs:
                args.append(specs["vision_embeds"])
                in_sh.append(named(mesh, bspecs["vision_embeds"]))
            out_sh = (NamedSharding(mesh, P(b, "model")),
                      _prefill_cache_shardings(cfg, plan, shape, mesh))
            lowered = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                              out_shardings=out_sh).lower(*args)
        else:  # decode
            b = batch_dim_spec(mesh, shape.global_batch)
            cache_shape = specs["cache"]
            cspecs = cache_specs(cache_shape, mesh, shape.global_batch)

            def serve_step(params, cache, tokens, pos):
                return decode_step(params, cfg, plan, cache, tokens, pos)

            in_sh = (named(mesh, pspecs), named(mesh, cspecs),
                     named(mesh, bspecs["tokens"]),
                     NamedSharding(mesh, P()))
            out_sh = (NamedSharding(mesh, P(b, "model")),
                      named(mesh, cspecs))
            lowered = jax.jit(serve_step, in_shardings=in_sh,
                              out_shardings=out_sh,
                              donate_argnums=(1,)).lower(
                params_shape, cache_shape, specs["tokens"], specs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "overrides": overrides,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_total_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
                3),
        },
        "xla_cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed") if k in cost},
    }
    # roofline terms from the compiled HLO (trip-count aware)
    try:
        from repro.roofline.analysis import analyze_compiled
        result["hlo"] = analyze_compiled(compiled)
    except Exception as e:  # keep the dry-run result even if parsing fails
        result["hlo_error"] = f"{type(e).__name__}: {e}"
    return result


def _opt_specs(state_shape, pspecs):
    """Build shardings for the whole train state from the param specs.
    int8 moment dicts ({"q","scale"[,"ef"]}) inherit the param's spec;
    the first ("m") and second ("v") moments are specced separately —
    their codecs differ (only m carries the packed 2-bit EF residual)."""
    from jax.sharding import PartitionSpec as PS

    def moment_spec(ps, leaf):
        if isinstance(leaf, dict):
            # the blockwise scale (last dim /256) and packed EF residual
            # (last dim /4) both shrink the last dim: replicate it (small)
            # so divisibility never constrains specs
            small_spec = PS(*ps[:-1], None) if len(ps) else ps
            out = {"q": ps, "scale": small_spec}
            if "ef" in leaf:
                out["ef"] = small_spec
            return out
        return ps

    is_enc = lambda x: isinstance(x, dict) and {"q", "scale"} <= set(x)
    flat_p = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, PS))

    def tree_spec(moments):
        flat = jax.tree_util.tree_leaves(moments, is_leaf=is_enc)
        specs = [moment_spec(ps, lf) for ps, lf in zip(flat_p, flat)]
        tdef = jax.tree_util.tree_structure(moments, is_leaf=is_enc)
        return jax.tree_util.tree_unflatten(tdef, specs)

    out = {"params": pspecs,
           "opt": {"step": PS(),
                   "m": tree_spec(state_shape["opt"]["m"]),
                   "v": tree_spec(state_shape["opt"]["v"])}}
    if "grad_err" in state_shape:      # int8_ef carry: same tree as params
        out["grad_err"] = pspecs
    return out


def _prefill_cache_shardings(cfg, plan, shape, mesh):
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, plan, shape.global_batch, shape.seq_len))
    return named(mesh, cache_specs(cache_shape, mesh, shape.global_batch))


def run_cell(arch, shape_name, multi_pod, overrides=None, out_dir=OUT_DIR):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    try:
        res = lower_cell(arch, shape_name, multi_pod=multi_pod,
                         overrides=overrides)
        status = "ok"
    except Exception as e:
        res = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        status = "FAIL"
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if not (overrides or {}) else "__" + "_".join(
        f"{k}-{v}" for k, v in sorted(overrides.items()))
    with open(os.path.join(out_dir, tag + suffix + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    mem = res.get("memory", {}).get("per_device_total_gb", "-")
    print(f"[{status}] {tag} mem/dev={mem}GB "
          f"compile={res.get('compile_s', '-')}s", flush=True)
    if status == "FAIL":
        print(res["error"], flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    ap.add_argument("--override", action="append", default=[],
                    help="key=value (int/bool/str) plan overrides")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v in ("true", "false"):
            v = v == "true"
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        overrides[k] = v

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    if args.all:
        cells = []
        for arch in list_archs():
            cfg = get_config(arch)
            if cfg.family == "encoder":
                continue  # paper's own arch: separate smoke/bench path
            for s in shapes_for(cfg):
                cells.append((arch, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for mp in meshes:
        for arch, shape_name in cells:
            run_cell(arch, shape_name, mp, overrides or None, args.out_dir)


if __name__ == "__main__":
    main()
