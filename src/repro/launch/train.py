"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --batch 8 --seq 128

`--smoke` runs the reduced config on local devices (CPU-runnable); the full
configs are exercised via the dry-run (launch/dryrun.py). On a real cluster
this same entrypoint runs under `jax.distributed.initialize()` with the
production mesh.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.base import RunConfig
from repro.models import BuildPlan
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "int8"])
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan = BuildPlan(remat=args.remat)
    run_cfg = RunConfig(arch=args.arch, microbatches=args.microbatches,
                        learning_rate=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 10, 1),
                        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    trainer = Trainer(cfg, plan, run_cfg,
                      adamw_cfg=AdamWConfig(moment_dtype=args.moment_dtype))
    out = trainer.run_loop(total_steps=args.steps, seq_len=args.seq,
                           global_batch=args.batch)
    losses = [m["loss"] for m in out["metrics"]]
    print(json.dumps({
        "arch": cfg.name, "steps": out["final_step"],
        "first_loss": round(losses[0], 4), "last_loss": round(losses[-1], 4),
        "stragglers": len(trainer.watchdog.events),
    }))


if __name__ == "__main__":
    main()
