"""COMQ quantization launcher: calibrate → quantize → quantized checkpoint.

    PYTHONPATH=src python -m repro.launch.quantize --arch qwen2-7b --smoke \
        --bits 4 --order greedy --granularity per_channel --sweeps 3

At scale the per-channel solve runs with output columns sharded over the
full mesh (COMQ's solve needs zero communication — DESIGN.md §4); here the
same code path runs on local devices against the smoke configs.

Crash-safe runs (DESIGN.md §8): `--journal DIR` journals every solved
leaf durably (solve → spill → journal) and `--restarts N` supervises the
run with ft.run_with_restarts — on a crash (or an injected `--inject
kill:…` fault) the surviving journal resumes the walk, re-applying
journaled leaves bit-identically instead of re-solving them. The
journaled-leaf count is the supervisor's progress signal and a
ft.Heartbeat in the journal directory tracks liveness.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import (CheckpointManager, pack_tree, policy_extra,
                        save_packed_ckpt, tree_bytes)
from repro.configs import get_config, get_smoke_config
from repro.core import (QuantSpec, materialize, parse_policy,
                        policy_from_budget, quantize_model)
from repro.ft import (FaultInjector, Heartbeat, QuantJournal,
                      run_with_restarts)
from repro.models import BuildPlan, init_params, lm_loss
from repro.obs import MetricsRegistry, Tracer, next_trace_path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--granularity", default="per_channel",
                    choices=["per_channel", "per_layer"])
    ap.add_argument("--order", default="greedy",
                    choices=["greedy", "cyclic", "greedy_shared"])
    ap.add_argument("--sweeps", type=int, default=3)
    ap.add_argument("--lam", type=float, default=0.9)
    ap.add_argument("--method", default="comq",
                    choices=["comq", "comq_blocked", "rtn", "gptq"])
    ap.add_argument("--calib-batch", type=int, default=8)
    ap.add_argument("--calib-seq", type=int, default=128)
    ap.add_argument("--propagation", default="staged",
                    choices=["staged", "legacy"],
                    help="staged = one forward per layer (default); "
                         "legacy = two-forward A/B schedule")
    ap.add_argument("--shard-data", action="store_true",
                    help="shard the calibration batch over the mesh data "
                         "axis (repro.dist: one Gram psum per tap)")
    ap.add_argument("--shard-solve", type=int, default=0, metavar="TP",
                    help="shard solve columns over a model axis of this "
                         "size (0 = off; with --shard-data the remaining "
                         "devices form the data axis). Zero-communication, "
                         "bit-identical for per-channel comq_blocked/rtn "
                         "(DESIGN.md §4.3); other methods keep replicated "
                         "solves.")
    ap.add_argument("--policy", default=None, metavar="RULES",
                    help="per-leaf mixed-precision rules, e.g. "
                         "'*.w_down=8,first=8,last=8,kv=8' — patterns "
                         "match '{layer}.{leaf}' then the bare leaf name "
                         "(core/policy.py; --bits stays the base width)")
    ap.add_argument("--bits-budget", type=float, default=0.0, metavar="BPP",
                    help="allocate per-leaf bit widths (2/3/4/8) under "
                         "this bits-per-param budget with the greedy "
                         "backprop-free knapsack on layerwise H-space "
                         "errors (overrides --policy rules)")
    ap.add_argument("--out-dir", default="/tmp/repro_quant")
    ap.add_argument("--journal", default=None, metavar="DIR",
                    help="journal directory: durably record every solved "
                         "leaf so a crashed run can --resume bit-"
                         "identically (ft.QuantJournal, DESIGN.md §8)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --journal (also implied when the "
                         "journal already has leaves under --restarts)")
    ap.add_argument("--restarts", type=int, default=0, metavar="N",
                    help="supervise the run with ft.run_with_restarts: up "
                         "to N restarts without progress (journaled-leaf "
                         "count), resuming from --journal after each")
    ap.add_argument("--inject", default=None, metavar="SPEC",
                    help="deterministic fault injection, e.g. 'kill:2' or "
                         "'leaf_solve:3,ckpt_write:1' (ft.FaultInjector; "
                         "points: gram_accumulate, leaf_solve, ckpt_write, "
                         "kill, nan_tap)")
    ap.add_argument("--save-packed", default=None, metavar="PATH",
                    help="also save the packed tree as one atomic "
                         "checksummed file (byte-deterministic — the CI "
                         "fault-smoke compares these across runs)")
    ap.add_argument("--no-guards", action="store_true",
                    help="disable the numeric guards (core/guards); "
                         "healthy runs are bit-identical either way")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write a Chrome-trace JSON of the run (layer + "
                         "leaf_solve spans; open in chrome://tracing or "
                         "Perfetto, or summarize with `python -m "
                         "repro.obs.report DIR`)")
    ap.add_argument("--metrics", default=None, metavar="DIR",
                    help="dump the quant.* metrics registry (layers/"
                         "leaves counters, per-leaf error + seconds "
                         "histograms) as metrics.jsonl + metrics.prom")
    args = ap.parse_args()
    if args.restarts and not args.journal:
        raise SystemExit("--restarts needs --journal (resume source)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan = BuildPlan(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, plan)
    tokens = jax.random.randint(key, (args.calib_batch, args.calib_seq), 0,
                                cfg.vocab_size)
    ve = None
    if cfg.family == "vlm":
        ve = jax.random.normal(key, (args.calib_batch,
                                     cfg.cross_attn.n_vision_tokens,
                                     cfg.cross_attn.vision_dim), jnp.bfloat16)

    base = QuantSpec(bits=args.bits, granularity=args.granularity,
                     lam=args.lam, sweeps=args.sweeps, order=args.order)
    spec = base
    parsed = parse_policy(args.policy, base) if args.policy else None
    if args.bits_budget:
        # the budget allocation supersedes explicit bit rules, but the
        # kv rider still applies (it is orthogonal to weight widths)
        if parsed is not None and (parsed.rules
                                   or parsed.first_layer_bits is not None
                                   or parsed.last_layer_bits is not None):
            print("# note: --bits-budget supersedes the --policy bit "
                  "rules; only its kv= rider is kept")
        kv = parsed.kv_bits if parsed is not None else 0
        spec, alloc, sizes = policy_from_budget(params, cfg, plan, tokens,
                                                base, args.bits_budget,
                                                kv_bits=kv)
        hist = {}
        for b in alloc.values():
            hist[b] = hist.get(b, 0) + 1
        print(f"# bit allocation under {args.bits_budget} bits/param: "
              f"{dict(sorted(hist.items()))}")
    elif parsed is not None:
        spec = parsed
    if spec is not base and spec.kv_bits:
        if spec.kv_bits not in (4, 8):
            raise SystemExit(f"kv={spec.kv_bits} unsupported (0, 4 or 8)")
        if spec.kv_bits == 8:
            # dense eval cache quantizes per-entry at int8 (core/apply.py)
            plan = plan.replace(cache_quant=True)
        # the paged runtime consumes the same rider as page codes with
        # per-(layer, page, kv_head) scales (serve --kv-bits; 4-bit has no
        # dense-cache analogue, so eval there runs bf16 caches)
        plan = plan.replace(kv_bits=spec.kv_bits)
    mesh = None
    if args.shard_solve:
        from repro.dist import calib_mesh
        mesh = calib_mesh(model=args.shard_solve,
                          data=None if args.shard_data else 1)
        from repro.core import as_policy
        from repro.core.pipeline import _col_shardable
        if not _col_shardable(as_policy(spec).base, args.method):
            print(f"# note: method={args.method} granularity="
                  f"{args.granularity} is not column-shardable; solves "
                  "stay replicated (see DESIGN.md §4.3)")
    elif args.shard_data:
        from repro.dist import data_mesh
        mesh = data_mesh()
    injector = FaultInjector.parse(args.inject) if args.inject else None
    # observability (DESIGN.md §10): absent flags keep the pipeline on the
    # zero-cost null singletons
    tracer = Tracer(run=f"quantize:{cfg.name}") if args.trace else None
    registry = (MetricsRegistry(run=f"quantize:{cfg.name}")
                if args.metrics else None)
    hb = Heartbeat(args.journal, host_id=0) if args.journal else None
    progress_cb = None
    if hb is not None:
        # the heartbeat doubles as a liveness + health publisher: each
        # layer beat carries the current metrics snapshot when enabled
        def progress_cb(layer):
            hb.beat(layer, metrics=(registry.snapshot()
                                    if registry is not None else None))

    def run_once(resume: bool):
        return quantize_model(params, cfg, plan, tokens, spec,
                              method=args.method, vision_embeds=ve,
                              propagation=args.propagation, mesh=mesh,
                              guards=not args.no_guards,
                              journal=args.journal, resume=resume,
                              injector=injector, progress_cb=progress_cb,
                              tracer=tracer, metrics=registry)

    t0 = time.time()
    if args.journal:
        box = {}

        def attempt(_):
            # resume whenever the journal already holds leaves of this (or
            # an explicitly-resumed) run; assert journal↔spill integrity
            # before trusting any of them
            resume = args.resume or bool(
                QuantJournal.replay(args.journal).leaves)
            if resume:
                QuantJournal.check_integrity(args.journal)
            box["out"] = run_once(resume)

        def progress():
            return len(QuantJournal.replay(args.journal).leaves)

        run_with_restarts(attempt, progress, max_restarts=args.restarts,
                          exceptions=(RuntimeError,), backoff_s=0.0)
        qparams, report = box["out"]
    else:
        qparams, report = run_once(args.resume)
    dt = time.time() - t0

    # quantized checkpoint (each QTensor packed to its own bit width) +
    # the policy metadata that produced it (ckpt.restore_policy reads it);
    # CheckpointManager writes are atomic+fsynced (tmp → rename)
    packed = pack_tree(qparams["__qlayers__"])
    mgr = CheckpointManager(args.out_dir, keep=2)
    mgr.save(0, packed, extra=policy_extra(policy=spec, arch=cfg.name,
                                           bits=args.bits))
    if args.save_packed:
        # single-file form with deterministic bytes (npz embeds zip
        # timestamps; pickled host arrays do not) — what the CI fault
        # smoke byte-compares between faulted-resumed and clean runs
        host = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a))
            if isinstance(a, jax.Array) else a, packed)
        save_packed_ckpt(args.save_packed, host, arch=cfg.name,
                         bits=args.bits)

    # quality: eval loss fp vs quantized on a held-out batch
    ev = jax.random.randint(jax.random.PRNGKey(7),
                            (args.calib_batch, args.calib_seq), 0,
                            cfg.vocab_size)
    batch = {"tokens": ev, "labels": ev}
    if ve is not None:
        batch["vision_embeds"] = ve
    fp_loss = float(lm_loss(params, cfg, plan, batch)[0])
    q_loss = float(lm_loss(materialize(qparams, cfg), cfg, plan, batch)[0])

    if tracer is not None:
        tp = next_trace_path(args.trace, "quantize")
        tracer.save(tp)
        print(f"# trace: {tp} ({len(tracer.events)} events)")
    if registry is not None:
        registry.dump_jsonl(os.path.join(args.metrics, "metrics.jsonl"))
        registry.dump_prometheus(os.path.join(args.metrics, "metrics.prom"))
        print(f"# metrics: {args.metrics}/metrics.jsonl + metrics.prom")

    dense_bytes = sum(l.size * l.dtype.itemsize for l in
                      jax.tree_util.tree_leaves(params))
    from repro.core import QuantPolicy
    print(json.dumps({
        "arch": cfg.name, "method": args.method, "bits": args.bits,
        "mixed_policy": (isinstance(spec, QuantPolicy)
                         and not spec.is_uniform()),
        "bits_budget": args.bits_budget or None,
        "propagation": args.propagation,
        "data_shards": 1 if mesh is None else int(mesh.shape["data"]),
        "model_shards": 1 if mesh is None else int(mesh.shape.get("model",
                                                                  1)),
        "order": args.order, "granularity": args.granularity,
        "layers_quantized": len(report.layers),
        "comq_vs_rtn_error_improvement": round(report.total_improvement(), 4),
        "fp_loss": round(fp_loss, 4), "quant_loss": round(q_loss, 4),
        "seconds": round(dt, 1),
        "ckpt_bytes": tree_bytes(packed),
        "dense_bytes": dense_bytes,
        "compression": round(dense_bytes / max(tree_bytes(packed), 1), 1),
        "guard_events": len(report.guard_events),
        "resumed_leaves": report.resumed_leaves,
        "faults_fired": (len(injector.fired) if injector is not None
                         else 0),
    }))


if __name__ == "__main__":
    main()
