"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; multi-pod adds a leading pod axis (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) > n:
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"need {n} devices for mesh {shape}, have {len(devices)} — the "
        f"dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
        f"device_count=512 before importing jax")


def make_smoke_mesh():
    """Single-device mesh with the same axis names (CPU tests)."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
