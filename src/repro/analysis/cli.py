"""`python -m repro.analysis.cli` — the repo's static-analysis gate
(DESIGN.md §9.6, wired as the `analysis-gate` CI step).

Modes (combinable; `--gate` = all three):

* ``--lint``      — AST lint over the source tree (host-syncs in hot
  zones, wall-clock calls inside jitted functions, un-fsynced
  `os.replace` in the durable dirs);
* ``--contracts`` — lower every gated entry point in
  `analysis/registry.py` and check its collective census + donation
  aliasing against the declared contract;
* ``--retrace``   — a small mixed-length, staggered serve run under the
  runtime's retrace guards, asserting the decode step compiled exactly
  once and every guard stayed inside its budget.

Exit status is the number of violated checks (0 = clean), so CI can use
it directly. Findings print one per line; `--quiet` suppresses the
per-section OK chatter.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List


def _print(quiet: bool, msg: str) -> None:
    if not quiet:
        print(msg)


def run_lint(paths: List[str], quiet: bool) -> int:
    from repro.analysis.lint import lint_paths
    root = os.getcwd()
    findings = lint_paths(paths, root=root)
    for f in findings:
        print(f)
    _print(quiet, f"lint: {len(findings)} finding(s) over {paths}")
    return 1 if findings else 0


def run_contracts(quiet: bool) -> int:
    from repro.analysis.registry import run_gate
    bad = 0
    for res in run_gate():
        if res.skipped:
            _print(quiet, f"contract {res.name}: SKIP ({res.skipped})")
        elif res.ok:
            _print(quiet, f"contract {res.name}: OK")
        else:
            bad += 1
            for v in res.violations:
                print(f"contract {res.name}: {v}")
    return 1 if bad else 0


def run_retrace_smoke(quiet: bool) -> int:
    """Mixed-length, staggered serve run; the decode step must compile
    exactly once and every runtime guard must stay inside its budget."""
    import numpy as np

    from repro.analysis.retrace import (compile_count, guard_violations,
                                        reset_guards, retrace_report)
    from repro.configs import get_smoke_config
    from repro.models import BuildPlan, init_params
    from repro.serve import Runtime, ServeConfig
    import jax
    import jax.numpy as jnp

    reset_guards()
    cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg, plan)
    rt = Runtime(params, cfg, plan,
                 ServeConfig(max_slots=3, block_size=8, num_blocks=24,
                             buckets=(8, 16), max_blocks_per_slot=4))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (5, 11, 7, 13)]
    problems: List[str] = []
    try:
        # staggered arrivals: two up front, two injected mid-run
        for p in prompts[:2]:
            rt.submit(p, max_new_tokens=6)
        rt.step(); rt.step()
        rt.submit(prompts[2], max_new_tokens=5, temperature=0.7, seed=7)
        rt.step()
        rt.submit(prompts[3], max_new_tokens=4)
        rt.run()
    except Exception as e:   # strict mode raises mid-run on violation
        problems.append(f"serve run raised: {type(e).__name__}: {e}")
    n = compile_count("serve.decode_step")
    if n != 1:
        problems.append(f"decode step compiled {n} time(s), expected "
                        "exactly 1 across a mixed/staggered run")
    problems += guard_violations()
    for p in problems:
        print(f"retrace: {p}")
    if not problems:
        report = retrace_report()
        traced = {k: v["traces"] for k, v in report.items() if v["traces"]}
        _print(quiet, f"retrace: OK — compile counts {traced}")
    return 1 if problems else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.cli",
        description="compile-contract + lint gate")
    ap.add_argument("--gate", action="store_true",
                    help="run every check (lint + contracts + retrace)")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--contracts", action="store_true")
    ap.add_argument("--retrace", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("paths", nargs="*", default=None,
                    help="lint roots (default: src/repro)")
    args = ap.parse_args(argv)
    if args.gate:
        args.lint = args.contracts = args.retrace = True
    if not (args.lint or args.contracts or args.retrace):
        ap.error("pick at least one of --gate/--lint/--contracts/--retrace")

    failures = 0
    if args.lint:
        failures += run_lint(args.paths or ["src/repro"], args.quiet)
    if args.contracts:
        failures += run_contracts(args.quiet)
    if args.retrace:
        failures += run_retrace_smoke(args.quiet)
    _print(args.quiet,
           "analysis gate: " + ("CLEAN" if not failures
                                else f"{failures} section(s) FAILED"))
    return failures


if __name__ == "__main__":
    sys.exit(main())
