"""Declarative compile contracts over HLO (DESIGN.md §9.2).

A contract states what a compiled program is *allowed to do* on the wire
and in memory, independent of its numerics:

* ``collectives=0``                 — the module contains no collective
  instructions at all (the column-sharded solve's invariant);
* ``collectives={"all-reduce": 1}`` — exactly one all-reduce and zero
  collectives of any other family (the one-psum-per-tap Gram);
* ``donated={1}``                   — positional arg 1 was donated AND
  the compiled module actually aliases every one of its buffers to an
  output. JAX drops ``donate_argnums`` *silently* when a donated leaf's
  dtype/shape/sharding matches no output — the paged KV pool falling off
  the in-place path would double decode-step HBM traffic without failing
  any test, so the audit reads the ground truth: the module header's
  ``input_output_alias`` table.

Checks run on compiled HLO text (`compiled.as_text()`); `check_lowered`
is the convenience that lowers+compiles a jitted callable on example
args. Violations come back as strings (empty list = clean);
`assert_contract` raises `ContractViolation` with all of them.

The `@contract(...)` decorator only attaches metadata
(``__comq_contract__``) — checking happens where example shapes exist:
the registry of gated entry points (`analysis/registry.py`), the CLI
gate, and the tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.analysis.hlo import (COLLECTIVES, collective_census,
                                entry_param_count, parse_io_aliases)

CollectiveSpec = Union[int, Mapping[str, int], None]


class ContractViolation(AssertionError):
    """A compiled program broke its declared contract."""


@dataclass(frozen=True)
class Contract:
    """What a compiled entry point is allowed to do.

    collectives: None = unconstrained; an int N = total collective
      instruction count must equal N; a mapping = per-family exact
      counts, with every family *not* named required to be 0.
    donated: positional argnums whose every flattened leaf must be
      aliased input->output in the compiled module.
    """
    name: str = ""
    collectives: CollectiveSpec = None
    donated: Tuple[int, ...] = ()
    notes: str = ""


def contract(collectives: CollectiveSpec = None,
             donated: Sequence[int] = (), notes: str = ""):
    """Attach a Contract to a callable (jitted or not) as metadata."""
    def deco(fn):
        fn.__comq_contract__ = Contract(
            name=getattr(fn, "__name__", ""),
            collectives=(dict(collectives)
                         if isinstance(collectives, Mapping)
                         else collectives),
            donated=tuple(sorted(int(a) for a in donated)), notes=notes)
        return fn
    return deco


def contract_of(fn) -> Optional[Contract]:
    return getattr(fn, "__comq_contract__", None)


# ---------------------------------------------------------------------------
# collective-census pass
# ---------------------------------------------------------------------------

def check_collectives(text: str, spec: CollectiveSpec,
                      name: str = "") -> List[str]:
    """Violation strings for the census vs. a collectives spec."""
    if spec is None:
        return []
    census = collective_census(text)
    label = f"[{name}] " if name else ""
    found = {k: v.count for k, v in census.items()}
    if isinstance(spec, Mapping):
        out = []
        for fam in sorted(set(found) | set(spec)):
            want = int(spec.get(fam, 0))
            got = found.get(fam, 0)
            if got != want:
                by = census[fam].bytes if fam in census else 0.0
                out.append(f"{label}collective census: {fam} x{got} "
                           f"({by:.0f} shard bytes), contract wants "
                           f"x{want}")
        return out
    total = sum(found.values())
    if total != int(spec):
        detail = ", ".join(f"{k} x{v.count} ({v.bytes:.0f} B)"
                           for k, v in sorted(census.items())) or "none"
        return [f"{label}collective census: {total} collective "
                f"instruction(s) [{detail}], contract wants {int(spec)}"]
    return []


# ---------------------------------------------------------------------------
# donation audit
# ---------------------------------------------------------------------------

def _leaf_counts(example_args) -> List[int]:
    import jax
    return [len(jax.tree_util.tree_leaves(a)) for a in example_args]


def audit_donation(text: str, donated: Sequence[int],
                   example_args=None, name: str = "") -> List[str]:
    """Verify each donated positional arg produced input-output aliasing.

    With `example_args` (the positional args the program was lowered on;
    avals/ShapeDtypeStructs work) the audit maps every donated argnum to
    its flattened entry-parameter range and requires each parameter in
    the range to appear in the module's `input_output_alias` table —
    which is exactly what JAX fails to establish when a donated leaf's
    dtype or sharding matches no output. Without example args it can
    only require *some* aliasing to exist per the contract.
    """
    donated = sorted(int(a) for a in donated)
    if not donated:
        return []
    label = f"[{name}] " if name else ""
    aliased = set(parse_io_aliases(text))
    if example_args is None:
        if not aliased:
            return [f"{label}donation audit: contract donates args "
                    f"{donated} but the compiled module has no "
                    "input_output_alias entries at all (donation dropped)"]
        return []
    counts = _leaf_counts(example_args)
    for a in donated:
        if a >= len(counts):
            return [f"{label}donation audit: donated argnum {a} out of "
                    f"range for {len(counts)} example args"]
    n_params = entry_param_count(text)
    offsets = [0]
    for c in counts:
        offsets.append(offsets[-1] + c)
    out: List[str] = []
    if n_params is not None and n_params == offsets[-1]:
        # exact mapping: flattened args are the entry params, in order
        for a in donated:
            missing = [p for p in range(offsets[a], offsets[a + 1])
                       if p not in aliased]
            if missing:
                out.append(
                    f"{label}donation audit: arg {a} donated but "
                    f"{len(missing)}/{counts[a]} of its leaves are not "
                    f"aliased to any output (entry params "
                    f"{missing[:6]}{'...' if len(missing) > 6 else ''}) — "
                    "JAX drops donation silently on dtype/sharding "
                    "mismatch")
    else:
        # params don't line up 1:1 with flattened args (hoisted consts,
        # tokens): fall back to counting
        expected = sum(counts[a] for a in donated)
        if len(aliased) < expected:
            out.append(
                f"{label}donation audit: contract donates {expected} "
                f"leaves (args {donated}) but only {len(aliased)} entry "
                f"parameter(s) are aliased to outputs "
                f"(module has {n_params} params vs {offsets[-1]} example "
                "leaves — count-based audit)")
    return out


# ---------------------------------------------------------------------------
# combined checks
# ---------------------------------------------------------------------------

def check_hlo(text: str, *, collectives: CollectiveSpec = None,
              donated: Sequence[int] = (), example_args=None,
              name: str = "") -> List[str]:
    """Run every applicable pass; returns violation strings (empty=clean)."""
    return (check_collectives(text, collectives, name)
            + audit_donation(text, donated, example_args, name))


def check_compiled(compiled, con: Contract, example_args=None) -> List[str]:
    text = compiled.as_text() if hasattr(compiled, "as_text") else compiled
    return check_hlo(text, collectives=con.collectives, donated=con.donated,
                     example_args=example_args, name=con.name)


def check_lowered(fn, *args, con: Optional[Contract] = None) -> List[str]:
    """Lower+compile a jitted callable on example args and check its
    contract (the one passed, else the attached `@contract` metadata)."""
    con = con or contract_of(fn)
    if con is None:
        raise ValueError("no contract given and none attached to fn")
    compiled = fn.lower(*args).compile()
    return check_compiled(compiled, con, example_args=args)


def assert_contract(text_or_compiled, con: Contract,
                    example_args=None) -> None:
    viol = check_compiled(text_or_compiled, con, example_args)
    if viol:
        raise ContractViolation("\n".join(viol))
