"""Repo-specific AST lint (DESIGN.md §9.4).

Three rules, each encoding a discipline the repo's performance or
durability story depends on:

* ``host-sync``   — inside *hot zones* (the functions listed in
  `HOT_ZONES`: the serve decode/admission path, the engine decode loop,
  the per-leaf pipeline sentinels), flag calls that force a device→host
  sync: `jax.device_get(...)`, `.item()`, `block_until_ready(...)`,
  `np.asarray(...)`/`np.array(...)` of a non-literal, and `float(...)`/
  `int(...)` of a call expression. Streaming a sampled token to a
  callback is a sync by design — such sites carry a pragma; anything
  unannotated is a new stall on the hot path. The observability hooks
  (`obs/trace.py`, `obs/metrics.py`) are hot zones too: they run once
  per token/leaf from inside the decode/solve loops and must stay
  append-only host work.
* ``time-in-jit`` — `time.time()`/`perf_counter()`/`monotonic()` inside
  a function that is jitted (decorated with `jax.jit`/`partial(jax.jit)`
  or passed to `jax.jit(...)`/`guard_jit(...)`, including lambdas).
  Wall-clocking a traced function measures trace time once and then
  nothing, silently.
* ``fsync-before-replace`` — in `ft/` and `ckpt/`, every `os.replace`
  must be lexically preceded, in the same function, by an fsync-ish call
  (a name containing "fsync"). An un-fsynced rename is atomic but not
  durable: the journal's crash-safety ordering (DESIGN.md §7/§8) relies
  on contents being on disk before the rename publishes them.

Intentional sites are annotated ``# comq: allow(<rule>)`` on the same
line or the line above; the pragma names the rule it waives (comma-
separated for several). Findings are (path, line, rule, message) —
`lint_paths` walks a tree, `lint_source` lints a snippet (the tests'
fixture hook).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, List, Sequence, Set, Tuple

RULES = ("host-sync", "time-in-jit", "fsync-before-replace")

# relpath (under src/repro, "/"-separated) -> qualnames whose bodies are
# decode/solve hot loops: any host sync inside runs once per step/leaf
HOT_ZONES: Dict[str, Tuple[str, ...]] = {
    "serve/runtime.py": ("Runtime.step", "Runtime._admit_one",
                         "Runtime.run", "Runtime._emit",
                         "Runtime._clear_slot", "Runtime._retire"),
    "serve/engine.py": ("Engine.generate_batch",),
    "core/guards.py": ("nonfinite_count", "sanitize_array", "gram_health",
                       "result_ok", "guarded_solve"),
    "core/pipeline.py": ("_results_finite", "_RunCtx.commit",
                         "_finalize_report", "_timed_solve"),
    "dist/calibrate.py": ("sharded_gram", "sharded_batched_gram"),
    # observability hooks run once per token/leaf from inside the zones
    # above — they must stay append-only host work (DESIGN.md §10.3)
    "obs/trace.py": ("Tracer.span", "Tracer.instant",
                     "Tracer.request_event", "Tracer.token_event",
                     "Span.__exit__"),
    "obs/metrics.py": ("Counter.inc", "Gauge.set", "Gauge.add",
                       "Histogram.observe"),
}

# dirs (relative to the package root) under the durability rule
DURABLE_DIRS = ("ft", "ckpt")

_TIME_CALLS = {"time", "perf_counter", "monotonic"}
_JIT_ENTRY_NAMES = {"jit", "guard_jit"}

_PRAGMA_RE = re.compile(r"#\s*comq:\s*allow\(([^)]*)\)")


@dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _pragmas(src: str) -> Dict[int, Set[str]]:
    """line -> set of waived rules, from `# comq: allow(rule[, rule])`."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _dotted(f: ast.AST) -> str:
    """Dotted-ish name of an expression: 'jax.device_get', 'os.replace',
    'x.item', 'float', ... (tail attributes only; subscripts etc. -> '')."""
    parts: List[str] = []
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    elif parts:
        parts.append("<expr>")
    else:
        return ""
    return ".".join(reversed(parts))


def _call_name(node: ast.Call) -> str:
    return _dotted(node.func)


def _is_literalish(node: ast.AST) -> bool:
    return isinstance(node, (ast.Constant, ast.List, ast.Tuple, ast.Dict))


# ---------------------------------------------------------------------------
# rule: host-sync (hot zones)
# ---------------------------------------------------------------------------

def _host_sync_reason(call: ast.Call) -> str:
    name = _call_name(call)
    tail = name.rsplit(".", 1)[-1]
    if tail == "device_get":
        return "jax.device_get forces a blocking device->host transfer"
    if tail == "item":
        return ".item() forces a blocking scalar device->host sync"
    if tail == "block_until_ready":
        return ("block_until_ready stalls the host until the device "
                "queue drains")
    if (name in ("np.asarray", "np.array", "numpy.asarray", "numpy.array")
            and call.args and isinstance(call.args[0], ast.Call)):
        # np.asarray(<call result>): pulling a freshly computed device
        # value; a Name/Subscript arg is usually already host data
        return (f"{name}(...) of a device value blocks until the "
                "computation materializes on host")
    if (name in ("float", "int") and call.args
            and isinstance(call.args[0], ast.Call)
            and _call_name(call.args[0]) != "len"):
        return (f"{name}(<call>) pulls a device scalar to host "
                "synchronously")
    return ""


class _FuncIndexer(ast.NodeVisitor):
    """Collects every FunctionDef with its dotted qualname + parents."""

    def __init__(self):
        self.funcs: List[Tuple[str, ast.AST]] = []
        self._stack: List[str] = []

    def _visit_fn(self, node):
        self._stack.append(node.name)
        self.funcs.append((".".join(self._stack), node))
        self.generic_visit(node)
        self._stack.pop()

    def visit_FunctionDef(self, node):
        self._visit_fn(node)

    def visit_AsyncFunctionDef(self, node):
        self._visit_fn(node)

    def visit_ClassDef(self, node):
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()


def _lint_host_sync(tree: ast.AST, relpath: str) -> List[Tuple[int, str]]:
    zones = HOT_ZONES.get(relpath)
    if not zones:
        return []
    idx = _FuncIndexer()
    idx.visit(tree)
    out: List[Tuple[int, str]] = []
    for qualname, fn in idx.funcs:
        if qualname not in zones:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                reason = _host_sync_reason(node)
                if reason:
                    out.append((node.lineno,
                                f"host sync in hot zone {qualname}: "
                                f"{reason}"))
    return out


# ---------------------------------------------------------------------------
# rule: time-in-jit
# ---------------------------------------------------------------------------

def _jit_callee_names(tree: ast.AST) -> Set[str]:
    """Names of locally-defined functions passed to jit/guard_jit (or
    wrapped via partial(jax.jit, ...) decorators)."""
    jitted: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            tail = _call_name(node).rsplit(".", 1)[-1]
            if tail in _JIT_ENTRY_NAMES:
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        jitted.add(arg.id)
            # partial(jax.jit, ...)(f): the outer call's func is the
            # partial(...) call itself
            if isinstance(node.func, ast.Call):
                inner = node.func
                if (_call_name(inner).rsplit(".", 1)[-1] == "partial"
                        and inner.args
                        and _dotted(inner.args[0]).rsplit(".", 1)[-1]
                        in _JIT_ENTRY_NAMES):
                    for arg in node.args[:1]:
                        if isinstance(arg, ast.Name):
                            jitted.add(arg.id)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                names = []
                if isinstance(dec, ast.Call):
                    names.append(_call_name(dec))
                    names += [_dotted(a) for a in dec.args
                              if isinstance(a, (ast.Attribute, ast.Name))]
                elif isinstance(dec, (ast.Attribute, ast.Name)):
                    names.append(_dotted(dec))
                if any(n.rsplit(".", 1)[-1] in _JIT_ENTRY_NAMES
                       for n in names if n):
                    jitted.add(node.name)
    return jitted


def _jitted_bodies(tree: ast.AST) -> List[ast.AST]:
    """Function/lambda bodies that end up traced by jit."""
    jitted_names = _jit_callee_names(tree)
    bodies: List[ast.AST] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name in jitted_names:
                bodies.append(node)
        elif isinstance(node, ast.Call):
            tail = _call_name(node).rsplit(".", 1)[-1]
            if tail in _JIT_ENTRY_NAMES:
                bodies += [a for a in node.args[:1]
                           if isinstance(a, ast.Lambda)]
    return bodies


def _lint_time_in_jit(tree: ast.AST, relpath: str) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for body in _jitted_bodies(tree):
        for node in ast.walk(body):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                head, _, tail = name.rpartition(".")
                if head == "time" and tail in _TIME_CALLS:
                    out.append((node.lineno,
                                f"{name}() inside a jitted function runs "
                                "once at trace time and never again"))
    return out


# ---------------------------------------------------------------------------
# rule: fsync-before-replace (ft/ + ckpt/ durability)
# ---------------------------------------------------------------------------

def _lint_fsync_replace(tree: ast.AST, relpath: str) -> List[Tuple[int, str]]:
    top = relpath.split("/", 1)[0]
    if top not in DURABLE_DIRS:
        return []
    idx = _FuncIndexer()
    idx.visit(tree)
    out: List[Tuple[int, str]] = []
    for qualname, fn in idx.funcs:
        replaces = []
        fsync_lines = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name == "os.replace":
                    replaces.append(node.lineno)
                elif "fsync" in name.rsplit(".", 1)[-1].lower():
                    fsync_lines.append(node.lineno)
        for line in replaces:
            if not any(fl < line for fl in fsync_lines):
                out.append((line,
                            f"os.replace in {qualname} with no preceding "
                            "fsync in the same function — the rename is "
                            "atomic but the contents are not durable"))
    return out


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

_RULE_FNS = {
    "host-sync": _lint_host_sync,
    "time-in-jit": _lint_time_in_jit,
    "fsync-before-replace": _lint_fsync_replace,
}


def lint_source(src: str, relpath: str) -> List[LintFinding]:
    """Lint one file's source. `relpath` is the path under the package
    root ("/"-separated), which selects hot zones and durable dirs."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [LintFinding(relpath, e.lineno or 0, "parse-error", str(e))]
    pragmas = _pragmas(src)

    def waived(line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            if rule in pragmas.get(ln, ()):
                return True
        return False

    findings: List[LintFinding] = []
    for rule, fn in _RULE_FNS.items():
        for line, msg in fn(tree, relpath):
            if not waived(line, rule):
                findings.append(LintFinding(relpath, line, rule, msg))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def _package_relpath(path: str, root: str) -> str:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    # HOT_ZONES/DURABLE_DIRS are keyed under src/repro; strip the prefix
    for prefix in ("src/repro/", "repro/"):
        if rel.startswith(prefix):
            return rel[len(prefix):]
    return rel


def lint_paths(paths: Sequence[str], root: str = ".") -> List[LintFinding]:
    """Lint every .py file under `paths` (files or directories)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, _, names in os.walk(p):
                files += [os.path.join(dirpath, n) for n in names
                          if n.endswith(".py")]
        elif p.endswith(".py"):
            files.append(p)
    findings: List[LintFinding] = []
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        rel = _package_relpath(f, root)
        for finding in lint_source(src, rel):
            findings.append(LintFinding(
                os.path.relpath(f, root), finding.line, finding.rule,
                finding.message))
    return findings
