"""Runtime retrace guard: compile-count budgets on jitted entry points
(DESIGN.md §9.3).

`guard_jit(fun, name=..., max_traces=N)` is a drop-in for `jax.jit(fun)`
that counts *traces* — each jit cache miss re-enters the wrapped Python
callable exactly once, so counting entries counts compiles without any
private JAX API. Budgets:

* ``max_traces=N``        — hard ceiling on total compiles (the serve
  decode step declares 1; each per-bucket prefill program declares 1);
* ``per_signature=True``  — unlimited *distinct* (shape/dtype/static)
  signatures, but re-tracing a signature that was already compiled is a
  violation (solver sweeps: one compile per (shape, statics) signature —
  a second trace means a silently thrashing jit cache).

A violation warns in dev and raises `RetraceViolation` under pytest/CI
(`PYTEST_CURRENT_TEST` in the environment, or `COMQ_STRICT_RETRACE=1`;
`COMQ_STRICT_RETRACE=0` force-disables strictness). Every guard
registers under its name: `compile_count("serve.decode_step")` is how
the tests assert "exactly one decode-step compile across a mixed/
staggered run", and `retrace_report()` feeds the CLI gate.

Re-creating a guard under an existing name (a fresh Runtime, an
lru-cache rebuild) starts a fresh record — budgets are per live jitted
object, not per process.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

import jax


class RetraceViolation(RuntimeError):
    """A jitted entry point exceeded its declared compile budget."""


def strict_mode() -> bool:
    env = os.environ.get("COMQ_STRICT_RETRACE")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "")
    return "PYTEST_CURRENT_TEST" in os.environ


@dataclass
class GuardRecord:
    name: str
    max_traces: Optional[int] = None
    per_signature: bool = False
    traces: int = 0
    signatures: Set[Any] = field(default_factory=set)
    violations: List[str] = field(default_factory=list)

    def note_trace(self, sig) -> Optional[str]:
        """Record one trace; returns a violation message or None."""
        self.traces += 1
        msg = None
        if self.per_signature and sig in self.signatures:
            msg = (f"retrace guard [{self.name}]: re-traced an already-"
                   f"compiled signature (trace #{self.traces}) — the jit "
                   "cache is thrashing")
        self.signatures.add(sig)
        if (msg is None and self.max_traces is not None
                and self.traces > self.max_traces):
            msg = (f"retrace guard [{self.name}]: compile #{self.traces} "
                   f"exceeds the declared budget of {self.max_traces}")
        if msg is not None:
            self.violations.append(msg)
        return msg


_GUARDS: Dict[str, GuardRecord] = {}


def _signature_of(args, kwargs):
    def leaf_key(x):
        aval = getattr(x, "aval", None)
        if aval is not None:
            return (tuple(aval.shape), str(aval.dtype))
        shape = getattr(x, "shape", None)
        dtype = getattr(x, "dtype", None)
        if shape is not None and dtype is not None:
            return (tuple(shape), str(dtype))
        return repr(x)     # static operand: identity by repr
    leaves, treedef = jax.tree_util.tree_flatten((args, tuple(sorted(
        kwargs.items()))))
    return (treedef, tuple(leaf_key(leaf) for leaf in leaves))


def guard_jit(fun, *, name: str, max_traces: Optional[int] = None,
              per_signature: bool = False, **jit_kwargs):
    """`jax.jit` with a compile-count budget registered under `name`."""
    rec = GuardRecord(name, max_traces, per_signature)
    _GUARDS[name] = rec

    import functools

    @functools.wraps(fun)
    def traced(*args, **kwargs):
        msg = rec.note_trace(_signature_of(args, kwargs))
        if msg is not None:
            if strict_mode():
                raise RetraceViolation(msg)
            warnings.warn(msg, stacklevel=2)
        return fun(*args, **kwargs)

    jitted = jax.jit(traced, **jit_kwargs)
    jitted.__comq_retrace_guard__ = rec
    return jitted


def compile_count(name: str) -> int:
    """Traces recorded by the most recent guard registered under `name`."""
    rec = _GUARDS.get(name)
    return 0 if rec is None else rec.traces


def guard_violations(name: Optional[str] = None) -> List[str]:
    if name is not None:
        rec = _GUARDS.get(name)
        return list(rec.violations) if rec else []
    return [v for rec in _GUARDS.values() for v in rec.violations]


def retrace_report() -> Dict[str, Dict[str, Any]]:
    return {
        n: {"traces": r.traces, "max_traces": r.max_traces,
            "per_signature": r.per_signature,
            "distinct_signatures": len(r.signatures),
            "violations": list(r.violations)}
        for n, r in sorted(_GUARDS.items())
    }


def reset_guards(name: Optional[str] = None) -> None:
    """Drop guard records (all, or one name). Live jitted objects keep
    counting into their own (now unregistered) records."""
    if name is None:
        _GUARDS.clear()
    else:
        _GUARDS.pop(name, None)
