"""Compile-contract checking: static analysis over jaxprs/HLO and the
repo's own Python source (DESIGN.md §9).

The repo's hottest guarantees — zero collectives in the column-sharded
solve, one psum per tap in the data-parallel Gram, donated KV pages
updated in place, "recompile at most once per bucket" in the serve
scheduler — are cheap to break silently: a stray all-reduce, a dropped
donation or a quiet retrace erases COMQ's backprop-free efficiency
without failing a single numeric test. This package turns those
invariants into checkable contracts:

* `analysis.hlo`       — shared HLO-text parser (instructions, shapes,
                         computations; factored out of roofline),
                         collective census, input/output alias table;
* `analysis.contracts` — declarative per-function contracts
                         (`collectives=0`, `collectives={"all-reduce": 1}`,
                         `donated={0}`) checked against compiled HLO,
                         including the donation audit (JAX silently drops
                         donation on dtype/sharding mismatch);
* `analysis.retrace`   — runtime compile-count budgets around jitted
                         entry points (`guard_jit`), warnings in dev,
                         hard failures under pytest/CI;
* `analysis.lint`      — repo-specific AST lint (host syncs in hot
                         loops, `time.time()` inside jit, fsync-before-
                         `os.replace` durability) with
                         `# comq: allow(<rule>)` pragmas;
* `analysis.registry`  — the gated entry points and their declared
                         budgets;
* `analysis.cli`       — `python -m repro.analysis.cli --gate`, the CI
                         gate over all of the above.
"""
from repro.analysis.contracts import (Contract, ContractViolation,
                                      assert_contract, audit_donation,
                                      check_compiled, check_hlo,
                                      check_lowered, contract, contract_of)
from repro.analysis.hlo import (collective_census, parse_hlo,
                                parse_io_aliases, COLLECTIVES)
from repro.analysis.retrace import (RetraceViolation, compile_count,
                                    guard_jit, retrace_report, reset_guards)

__all__ = [
    "COLLECTIVES", "Contract", "ContractViolation", "RetraceViolation",
    "assert_contract", "audit_donation", "check_compiled", "check_hlo",
    "check_lowered", "collective_census", "compile_count", "contract",
    "contract_of", "guard_jit", "parse_hlo", "parse_io_aliases",
    "reset_guards", "retrace_report",
]
