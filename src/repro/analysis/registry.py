"""The registry of gated entry points (DESIGN.md §9.5).

Each entry names one jitted program the repo's performance story depends
on, builds a smoke-sized instance, lowers it on example operands, and
checks its `Contract` against the compiled HLO:

* ``serve.decode_step``    — zero collectives; the paged KV pool
  (positional arg 1) is donated *and actually aliased* — a dropped
  donation would double decode-step HBM traffic without failing a test;
* ``serve.decode_step_q8`` — the same contract on int8 KV pages
  (per-page scales dequantized inside the attention); its ``_tp``
  variant lowers the slot+page-sharded decode on a model-axis mesh and
  must *still* be collective-free with the sharded pool donated;
* ``serve.prefill``        — zero collectives (per-bucket program);
* ``serve.prefill_write``  — pool donated+aliased through the scatter;
* ``solver.comq_blocked``  — zero collectives; the permuted weights and
  the scale vector are donated+aliased through the multi-sweep driver;
* ``train.step``           — the train state is donated+aliased (params
  and optimizer moments update in place);
* ``dist.solve``           — the column-sharded solve issues *no*
  collectives between the Gram psum and the final codes (§4.3);
* ``dist.gram``            — exactly one all-reduce per tap (§4.2).

`run_gate()` executes every entry that fits the local device count
(`dist.*` need >= 2 devices and report as skipped otherwise) and returns
`GateResult`s; the CLI turns any violation into a non-zero exit.

Entries build fresh smoke models per run — a few seconds of CPU; the
gate is a CI step, not a hot path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.contracts import Contract, check_lowered


@dataclass
class GateResult:
    name: str
    violations: List[str] = field(default_factory=list)
    skipped: str = ""          # non-empty reason => entry did not run

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclass(frozen=True)
class Entry:
    name: str
    run: Callable[[], List[str]]     # -> violation strings
    min_devices: int = 1
    notes: str = ""


def _smoke_serve(kv_bits: int = 0, mesh=None):
    """One tiny float32 runtime shared by the serve entries of a run."""
    from repro.configs import get_smoke_config
    from repro.models import BuildPlan, init_params
    from repro.serve import Runtime, ServeConfig
    cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32,
                     kv_bits=kv_bits)
    params = init_params(jax.random.PRNGKey(0), cfg, plan)
    return Runtime(params, cfg, plan,
                   ServeConfig(max_slots=2, block_size=8, num_blocks=16,
                               buckets=(8, 16), max_blocks_per_slot=4),
                   mesh=mesh)


def _decode_violations(rt, name: str) -> List[str]:
    B = rt.serve_cfg.max_slots
    args = (rt.params, rt.pool, jnp.zeros((B, rt.maxb), jnp.int32),
            jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32))
    con = Contract(name=name, collectives=0, donated=(1,))
    return check_lowered(rt._decode, *args, con=con)


def _check_decode() -> List[str]:
    return _decode_violations(_smoke_serve(), "serve.decode_step")


def _check_decode_quant() -> List[str]:
    # int8 pages: the in-kernel dequant (per-page scales folded into the
    # attention) must not cost the decode step its alias or add traffic
    return _decode_violations(_smoke_serve(kv_bits=8),
                              "serve.decode_step_q8")


def _check_decode_quant_tp() -> List[str]:
    import numpy as np
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("model",))
    return _decode_violations(_smoke_serve(kv_bits=8, mesh=mesh),
                              "serve.decode_step_q8_tp")


def _check_prefill() -> List[str]:
    rt = _smoke_serve()
    bucket = rt.serve_cfg.buckets[0]
    fn = rt._prefill_fn(bucket)
    con = Contract(name="serve.prefill", collectives=0)
    return check_lowered(fn, rt.params, jnp.zeros((1, bucket), jnp.int32),
                         con=con)


def _check_prefill_write() -> List[str]:
    rt = _smoke_serve()
    bucket = rt.serve_cfg.buckets[0]
    _, cache = rt._prefill_fn(bucket)(rt.params,
                                      jnp.zeros((1, bucket), jnp.int32))
    kv = cache["kv"]
    fn = rt._write_fn(int(kv.k.shape[2]))
    args = (rt.pool, kv.k[:, 0], kv.v[:, 0], kv.pos[0, 0],
            jnp.int32(bucket), jnp.zeros((rt.maxb,), jnp.int32))
    con = Contract(name="serve.prefill_write", collectives=0, donated=(0,))
    return check_lowered(fn, *args, con=con)


def _check_solver_blocked() -> List[str]:
    import numpy as np
    from repro.core.comq_hessian import (_blocked_jit_donate,
                                         panel_sweep_dq_ref)
    from repro.core.quantizer import QuantSpec
    m, n = 32, 16
    rng = np.random.default_rng(0)
    hp = jnp.asarray(np.eye(m, dtype=np.float32) * 2.0)
    wp = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    args = (hp, wp, jnp.diagonal(hp), jnp.full((n,), 0.05, jnp.float32),
            jnp.float32(-8.0), jnp.float32(7.0))
    con = Contract(name="solver.comq_blocked", collectives=0,
                   donated=(1, 3))
    lowered = _blocked_jit_donate.lower(
        *args, spec=QuantSpec(bits=4), m=m, block=m,
        panel_fn=panel_sweep_dq_ref, schedule="trailing")
    from repro.analysis.contracts import check_compiled
    return check_compiled(lowered.compile(), con, example_args=args)


def _check_train_step() -> List[str]:
    from repro.configs import RunConfig, get_smoke_config
    from repro.models import BuildPlan, init_params
    from repro.optim import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="qwen2-7b", total_steps=10)
    adamw = AdamWConfig(weight_decay=run_cfg.weight_decay)
    step = jax.jit(make_train_step(cfg, plan, run_cfg, adamw),
                   donate_argnums=(0,))
    params = init_params(jax.random.PRNGKey(0), cfg, plan)
    state = init_train_state(params, adamw, run_cfg)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    con = Contract(name="train.step", donated=(0,))
    return check_lowered(step, state, batch, con=con)


def _check_dist_solve() -> List[str]:
    from repro.core.quantizer import QuantSpec
    from repro.dist.calibrate import _solve_fn, calib_mesh
    mesh = calib_mesh(model=jax.device_count())
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    fn = _solve_fn(mesh, spec, "comq_blocked", 32)
    m, n = 64, 96
    args = (jnp.eye(m), jnp.ones((m, n)), jnp.arange(m, dtype=jnp.int32))
    con = Contract(name="dist.solve", collectives=0)
    return check_lowered(fn, *args, con=con)


def _check_dist_gram() -> List[str]:
    from repro.dist.calibrate import _gram_fn, data_mesh
    mesh = data_mesh()
    fn = _gram_fn(mesh)
    nd = mesh.shape["data"]
    con = Contract(name="dist.gram", collectives={"all-reduce": 1},
                   notes="one psum per tap (DESIGN.md §4.2)")
    return check_lowered(fn, jnp.ones((4 * nd, 16)), con=con)


ENTRIES: Dict[str, Entry] = {e.name: e for e in (
    Entry("serve.decode_step", _check_decode,
          notes="pool donated+aliased, zero collectives"),
    Entry("serve.decode_step_q8", _check_decode_quant,
          notes="int8 pages + per-page scales: pool donated+aliased, "
                "zero collectives, dequant fused into attention"),
    Entry("serve.decode_step_q8_tp", _check_decode_quant_tp, min_devices=2,
          notes="slot+page-sharded quantized decode over a model-axis "
                "mesh: still zero collectives, pool donated"),
    Entry("serve.prefill", _check_prefill, notes="zero collectives"),
    Entry("serve.prefill_write", _check_prefill_write,
          notes="pool donated+aliased through the scatter"),
    Entry("solver.comq_blocked", _check_solver_blocked,
          notes="permuted W + scales donated+aliased, zero collectives"),
    Entry("train.step", _check_train_step,
          notes="train state donated+aliased"),
    Entry("dist.solve", _check_dist_solve, min_devices=2,
          notes="zero-communication column-sharded solve"),
    Entry("dist.gram", _check_dist_gram, min_devices=2,
          notes="exactly one all-reduce per Gram tap"),
)}


def run_gate(names: Optional[Sequence[str]] = None) -> List[GateResult]:
    """Run the named entries (default: all); skips entries the local
    device count cannot exercise rather than vacuously passing them."""
    results: List[GateResult] = []
    for name in (names or sorted(ENTRIES)):
        entry = ENTRIES[name]
        if jax.device_count() < entry.min_devices:
            results.append(GateResult(
                name, skipped=f"needs >= {entry.min_devices} devices "
                              f"(have {jax.device_count()})"))
            continue
        try:
            results.append(GateResult(name, violations=entry.run()))
        except Exception as e:            # a broken builder is a failure
            results.append(GateResult(
                name, violations=[f"[{name}] gate entry raised: "
                                  f"{type(e).__name__}: {e}"]))
    return results
