"""Shared HLO-text parser + whole-module passes (DESIGN.md §9.1).

This is the single HLO parser in the repo: `repro.roofline.analysis`
consumes it for the trip-count-aware cost model, and
`repro.analysis.contracts` consumes it for the collective census and the
donation audit. It walks the *optimized post-SPMD per-device* HLO text
(`compiled.as_text()`), producing per-computation instruction lists with
output shapes/bytes, operand names, called computations and
`known_trip_count` backend configs.

Whole-module passes on top of the parse:

* `collective_census`  — per-collective *instruction* counts and shard
  bytes (operand/output max, i.e. the per-device payload). Counts are
  static occurrences, not dynamic executions: an all-reduce inside a
  scanned body counts once — which is exactly the quantity the repo's
  contracts constrain ("one psum per tap" is one all-reduce instruction
  regardless of layer count).
* `parse_io_aliases`   — the `input_output_alias` table from the
  HloModule header: which entry parameters XLA actually aliased to
  outputs. JAX drops `donate_argnums` silently on dtype/sharding
  mismatch; the only ground truth that a donated buffer is reused in
  place is this table in the compiled module.
* `entry_param_count`  — entry parameter count from
  `entry_computation_layout`, used to map flattened pytree args onto
  parameter numbers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")


def _parse_shape(s: str) -> Tuple[int, int]:
    """'f32[256,128]{1,0}' -> (elements, bytes). Tuples: sum of parts."""
    total_el, total_by = 0, 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        el = 1
        if dims:
            for d in dims.split(","):
                el *= int(d)
        total_el += el
        total_by += el * _DTYPE_BYTES[dt]
    return total_el, total_by


@dataclass
class Instr:
    name: str
    op: str
    out_elements: int
    out_bytes: int
    operands: List[str]
    text: str
    called: List[str] = field(default_factory=list)
    trip_count: int = 1


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_CALL_SINGLE_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_CALL_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _split_shape_op(rhs: str):
    """rhs = '<shape> <op>(<args>)...' where shape may be a paren tuple."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape_s = rhs[: i + 1]
                    rest = rhs[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape_s, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    opm = re.match(r"([\w\-]+)\(", rest)
    if not opm:
        return None
    op = opm.group(1)
    args_region = rest[opm.end():]
    depth = 1
    for i, ch in enumerate(args_region):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args_region[:i]
                break
    else:
        args = args_region
    return shape_s, op, args


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    comment_re = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        stripped = comment_re.sub("", line).strip()
        if "=" not in stripped and stripped.endswith("{") and "->" in stripped:
            first = stripped.split()[0]
            is_entry = first == "ENTRY"
            name = (stripped.split()[1] if is_entry else first).lstrip("%")
            name = name.split("(")[0].strip()
            cur = Computation(name)
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if stripped == "}":
            continue
        if cur is None or "=" not in stripped:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        parts = _split_shape_op(rhs)
        if parts is None:
            continue
        shape_s, op, args = parts
        out_el, out_by = _parse_shape(shape_s)
        operands = _OPERAND_RE.findall(args)
        called = [c.lstrip("%") for c in _CALL_SINGLE_RE.findall(rhs)]
        bm = _CALL_BRANCH_RE.search(rhs)
        if bm:
            called += [c.strip().lstrip("%")
                       for c in bm.group(1).split(",") if c.strip()]
        trip = 1
        tm = _TRIP_RE.search(rhs)
        if tm:
            trip = int(tm.group(1))
        inst = Instr(name, op, out_el, out_by, operands, rhs, called, trip)
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


# ---------------------------------------------------------------------------
# collective census
# ---------------------------------------------------------------------------


@dataclass
class CollectiveStat:
    count: int = 0
    bytes: float = 0.0


def classify_collective(op: str) -> Optional[str]:
    """Map an HLO opcode to its collective family, or None.

    Async pairs count once: `all-reduce-start` is the collective,
    `all-reduce-done` is bookkeeping and is skipped.
    """
    if op.endswith("-done"):
        return None
    for c in COLLECTIVES:
        if op == c or op.startswith(c + "-"):
            return c
    return None


def collective_census(text: str) -> Dict[str, CollectiveStat]:
    """Per-collective static instruction counts + shard bytes over the
    whole module (every computation — fusion bodies, scan bodies and the
    entry alike), the quantity the `collectives=` contracts constrain."""
    comps, _ = parse_hlo(text)
    census: Dict[str, CollectiveStat] = {}
    for comp in comps.values():
        for inst in comp.instrs:
            fam = classify_collective(inst.op)
            if fam is None:
                continue
            in_bytes = sum(comp.by_name[o].out_bytes for o in inst.operands
                           if o in comp.by_name)
            stat = census.setdefault(fam, CollectiveStat())
            stat.count += 1
            stat.bytes += float(max(in_bytes, inst.out_bytes))
    return census


# ---------------------------------------------------------------------------
# input/output aliasing (donation ground truth)
# ---------------------------------------------------------------------------

_ALIAS_TABLE_RE = re.compile(r"input_output_alias=\{(.*?)\}[,\s]")
_ALIAS_ENTRY_RE = re.compile(
    r"\{[\d,\s]*\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(?:may|must)-alias\)")
_ENTRY_LAYOUT_RE = re.compile(r"entry_computation_layout=\{\((.*?)\)->")


def parse_io_aliases(text: str) -> List[int]:
    """Entry parameter numbers that XLA aliased to an output buffer.

    Parsed from the HloModule header's `input_output_alias` table — the
    compiled module's ground truth for donation. An empty list means no
    donated buffer survived lowering (or none was requested).
    """
    m = _ALIAS_TABLE_RE.search(text)
    if not m:
        return []
    inner = m.group(1)
    # the table nests one brace level: find its true extent by balance
    start = text.find("input_output_alias={") + len("input_output_alias=")
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                inner = text[start + 1:i]
                break
    return sorted(int(p) for p in _ALIAS_ENTRY_RE.findall(inner))


def entry_param_count(text: str) -> Optional[int]:
    """Number of entry parameters, from `entry_computation_layout`."""
    m = _ENTRY_LAYOUT_RE.search(text)
    if not m:
        return None
    params = m.group(1).strip()
    if not params:
        return 0
    # count top-level commas (shapes contain commas inside [...] and {...})
    depth, count = 0, 1
    for ch in params:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        elif ch == "," and depth == 0:
            count += 1
    return count
