"""h2o-danube-1.8b: dense 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig


@register("h2o-danube-1.8b")
def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        sliding_window=4096,
        act="silu",
        rope_theta=10_000.0,
        source="arXiv:2401.16818; hf",
    )


@register_smoke("h2o-danube-1.8b")
def smoke() -> ModelConfig:
    return full().replace(
        name="h2o-danube-1.8b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=32,
    )
