"""Architecture registry.

`get_config(arch)` returns the full published config; `get_smoke_config(arch)`
returns a reduced same-family config for CPU smoke tests (small layers/width,
few experts, tiny vocab) exercising the identical model code path.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs.base import (ALL_SHAPES, SHAPES, ModelConfig, RunConfig,
                                ShapeConfig)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def register_smoke(name: str):
    def deco(fn):
        _SMOKE[name] = fn
        return fn
    return deco


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _SMOKE:
        raise KeyError(f"no smoke config for {arch!r}; known: {sorted(_SMOKE)}")
    return _SMOKE[arch]()


def list_archs():
    return sorted(_REGISTRY)


def shapes_for(cfg: ModelConfig):
    """Which assigned shapes are runnable for this arch (skips recorded in
    DESIGN.md §Arch-applicability)."""
    out = []
    for s in ALL_SHAPES:
        if cfg.family == "encoder" and s.kind == "decode":
            continue  # encoder-only: no autoregressive decode
        if s.name == "long_500k" and not _subquadratic(cfg):
            continue  # 500k decode needs bounded state
        out.append(s)
    return out


def _subquadratic(cfg: ModelConfig) -> bool:
    return bool(cfg.attn_free or cfg.ssm is not None or cfg.sliding_window)


# import for registration side effects
from repro.configs import (deepseek_67b, granite_moe_3b_a800m,  # noqa: E402,F401
                           h2o_danube_1_8b, hymba_1_5b,
                           llama4_maverick_400b_a17b,
                           llama_3_2_vision_90b, mistral_large_123b,
                           musicgen_large, qwen2_7b, rwkv6_7b, vit_base_paper)

__all__ = [
    "ModelConfig", "RunConfig", "ShapeConfig", "SHAPES", "ALL_SHAPES",
    "get_config", "get_smoke_config", "list_archs", "shapes_for",
]
