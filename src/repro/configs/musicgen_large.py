"""musicgen-large: audio 48L d_model=2048 32H (kv=32 -> MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. The EnCodec frontend is a
STUB per assignment: input_specs() provides precomputed frame-token ids.
[arXiv:2306.05284; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig


@register("musicgen-large")
def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        act="gelu_mlp",           # musicgen uses a plain (non-gated) GELU MLP
        norm_type="layernorm",
        source="arXiv:2306.05284; hf",
    )


@register_smoke("musicgen-large")
def smoke() -> ModelConfig:
    return full().replace(
        name="musicgen-large-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128,
    )
