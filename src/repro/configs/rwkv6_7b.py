"""rwkv6-7b 'Finch': ssm-family 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — data-dependent decay linear attention.  [arXiv:2404.05892; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig, RWKVConfig


@register("rwkv6-7b")
def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,              # wkv heads = d_model / rwkv.head_dim
        n_kv_heads=64,
        d_ff=14336,
        vocab_size=65536,
        attn_free=True,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=64,
                        token_shift_lora=32),
        norm_type="layernorm",
        act="relu_sq",           # rwkv channel-mix uses squared relu
        source="arXiv:2404.05892; hf",
    )


@register_smoke("rwkv6-7b")
def smoke() -> ModelConfig:
    return full().replace(
        name="rwkv6-7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, gate_lora=8,
                        token_shift_lora=4),
    )
