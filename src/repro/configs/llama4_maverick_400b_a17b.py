"""llama4-maverick-400b-a17b: MoE 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, 128 experts top-1 (+ shared expert), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig, MoEConfig


@register("llama4-maverick-400b-a17b")
def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        moe=MoEConfig(n_experts=128, top_k=1),
        act="silu",
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )


@register_smoke("llama4-maverick-400b-a17b")
def smoke() -> ModelConfig:
    return full().replace(
        name="llama4-maverick-400b-a17b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=288, moe=MoEConfig(n_experts=4, top_k=1),
    )
