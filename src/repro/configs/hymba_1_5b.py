"""hymba-1.5b: hybrid 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig, SSMConfig


@register("hymba-1.5b")
def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        sliding_window=1024,   # hymba uses SWA for most layers
        parallel_ssm_heads=True,
        ssm=SSMConfig(state_dim=16, expand=2, conv_width=4),
        act="silu",
        source="arXiv:2411.13676; hf",
    )


@register_smoke("hymba-1.5b")
def smoke() -> ModelConfig:
    return full().replace(
        name="hymba-1.5b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=257, sliding_window=16,
        ssm=SSMConfig(state_dim=4, expand=2, conv_width=4),
    )
