"""llama-3.2-vision-90b: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attention image layers every 5th layer; the vision
frontend is a STUB per assignment (input_specs provides precomputed patch
embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs import register, register_smoke
from repro.configs.base import CrossAttnConfig, ModelConfig


@register("llama-3.2-vision-90b")
def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,              # 80 self-attn + 20 cross-attn
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        cross_attn=CrossAttnConfig(every=5, n_vision_tokens=1601,
                                   vision_dim=1280),
        act="silu",
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )


@register_smoke("llama-3.2-vision-90b")
def smoke() -> ModelConfig:
    return full().replace(
        name="llama-3.2-vision-90b-smoke",
        n_layers=10, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        cross_attn=CrossAttnConfig(every=5, n_vision_tokens=17, vision_dim=32),
    )
