"""Model/shape/run configuration dataclasses.

Every assigned architecture is expressed as a frozen `ModelConfig`. The same
dataclass also describes the reduced "smoke" variants used in CPU tests, so
tests exercise the identical code path as the full dry-run configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    # capacity factor used by the dropless-ish router (dense dispatch via
    # one-hot matmul keeps the dry-run shapes static).
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective-state head (hymba) parameters."""
    state_dim: int = 16
    expand: int = 2           # d_inner = expand * d_model
    conv_width: int = 4
    dt_rank: int = 0          # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 'Finch' parameters: data-dependent decay via low-rank adapters."""
    head_dim: int = 64
    decay_lora: int = 64
    gate_lora: int = 64
    token_shift_lora: int = 32


@dataclass(frozen=True)
class CrossAttnConfig:
    """Interleaved cross-attention (llama-3.2-vision style)."""
    every: int = 5            # one cross-attn layer per `every` layers
    n_vision_tokens: int = 1601
    vision_dim: int = 1280    # stub patch-embedding dim (projected in-model)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | vlm | audio | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int = 0   # 0 -> full causal attention
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    act: str = "silu"            # silu (gated) | gelu (gated) | gelu_mlp (plain 2-mat)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None
    attn_free: bool = False       # rwkv6: no attention layers at all
    parallel_ssm_heads: bool = False  # hymba: attn and mamba in parallel per layer
    causal: bool = True           # encoders (ViT) set False
    # numerics
    param_dtype: str = "float32"  # master copy dtype
    compute_dtype: str = "bfloat16"
    # notes recorded in DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; unit-tested)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE counts only top_k experts)."""
        from repro.models.model import count_params_analytic
        if self.moe is None:
            return count_params_analytic(self)
        return count_params_analytic(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes (identical across all ten archs).
TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs besides the model itself."""
    arch: str
    shape: str = "train_4k"
    multi_pod: bool = False
    microbatches: int = 1         # gradient accumulation steps
    remat: bool = True
    seed: int = 0
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # distributed-optimization knobs
    grad_compression: str = "none"   # none | int8_ef
    # checkpointing / fault tolerance
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    async_ckpt: bool = True
    keep_ckpts: int = 3
    # quantization (COMQ) defaults — paper §4: K=3..4, lambda<=1
    quant_bits: int = 4
    quant_granularity: str = "per_channel"   # per_channel | per_layer
    quant_order: str = "greedy"              # greedy | cyclic
    quant_sweeps: int = 3
    quant_lambda: float = 0.9
