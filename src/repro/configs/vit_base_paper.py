"""vit-base-16: the paper's own architecture (ViT-B/16, Dosovitskiy et al.)
— encoder-only, the primary quantization target of COMQ Tab. 1/2.
Patch frontend is treated like the other modality stubs: input_specs()
provides precomputed patch embeddings (196 tokens + cls).
"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig


@register("vit-base-16")
def full() -> ModelConfig:
    return ModelConfig(
        name="vit-base-16",
        family="encoder",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=1000,          # classifier head width (ImageNet classes)
        act="gelu_mlp",
        norm_type="layernorm",
        causal=False,
        source="arXiv:2010.11929 (paper's own eval arch)",
    )


@register_smoke("vit-base-16")
def smoke() -> ModelConfig:
    return full().replace(
        name="vit-base-16-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=16,
    )
