"""qwen2-7b: dense 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 —
GQA with QKV bias.  [arXiv:2407.10671; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig


@register("qwen2-7b")
def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        act="silu",
        rope_theta=1_000_000.0,
        source="arXiv:2407.10671; hf",
    )


@register_smoke("qwen2-7b")
def smoke() -> ModelConfig:
    return full().replace(
        name="qwen2-7b-smoke",
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, head_dim=14,
        d_ff=144, vocab_size=256,
    )
