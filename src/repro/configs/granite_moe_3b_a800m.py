"""granite-moe-3b-a800m: MoE 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig, MoEConfig


@register("granite-moe-3b-a800m")
def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        moe=MoEConfig(n_experts=40, top_k=8),
        act="silu",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    )


@register_smoke("granite-moe-3b-a800m")
def smoke() -> ModelConfig:
    return full().replace(
        name="granite-moe-3b-a800m-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=259, moe=MoEConfig(n_experts=4, top_k=2),
    )
