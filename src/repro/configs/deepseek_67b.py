"""deepseek-67b: dense 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch.  [arXiv:2401.02954; hf]"""
from repro.configs import register, register_smoke
from repro.configs.base import ModelConfig


@register("deepseek-67b")
def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        act="silu",
        rope_theta=10_000.0,
        source="arXiv:2401.02954; hf",
    )


@register_smoke("deepseek-67b")
def smoke() -> ModelConfig:
    return full().replace(
        name="deepseek-67b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=320,
    )
