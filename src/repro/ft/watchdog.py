"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-host cluster each host runs a `Heartbeat` (a periodically
touched file per host on shared storage) and the trainer's `Watchdog`
tracks per-step wall times. Policies:

* **straggler**: a step slower than `straggler_factor` × the EMA step time
  raises a `StragglerEvent` (logged; the launcher's response at scale is to
  checkpoint + evict the slow host — here we surface and count them).
* **dead host**: a heartbeat older than `dead_after_s` marks the host dead;
  `plan_recovery` returns the restart decision (resume step, healthy hosts).
* **restart**: `run_with_restarts` wraps a train function and restarts it
  from the latest committed checkpoint up to `max_restarts` times —
  exercised by tests/test_fault_tolerance.py with injected failures.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Type


@dataclass
class StragglerEvent:
    step: int
    seconds: float
    ema: float


class Watchdog:
    def __init__(self, straggler_factor: float = 3.0, ema_decay: float = 0.9,
                 warmup_steps: int = 3):
        self.factor = straggler_factor
        self.decay = ema_decay
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.count = 0
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.time()

    def step_end(self, step: int) -> Optional[StragglerEvent]:
        dt = time.time() - self._t0
        self.count += 1
        ev = None
        if self.ema is not None and self.count > self.warmup \
                and dt > self.factor * self.ema:
            ev = StragglerEvent(step, dt, self.ema)
            self.events.append(ev)
        self.ema = dt if self.ema is None else \
            self.decay * self.ema + (1 - self.decay) * dt
        return ev


class Heartbeat:
    """File-based host liveness (shared-filesystem clusters)."""

    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"heartbeat_{host_id}")
        os.makedirs(directory, exist_ok=True)
        self.host_id = host_id

    def beat(self, step: int, metrics: Optional[Dict] = None):
        # atomic publish: write the record to a temp file and rename it
        # over the live path, so a concurrent reader can never observe a
        # truncated JSON document (it sees either the old beat or the new
        # one — a torn read used to be swallowed as a dead host).
        # `metrics` is an optional JSON-able health snapshot (e.g.
        # Runtime.metrics_snapshot(): retired count, live occupancy,
        # last guard event) published under a "metrics" key so the
        # watchdog file is inspectable mid-run — liveness readers that
        # only look at step/time are unaffected.
        rec: Dict = {"step": step, "time": time.time()}
        if metrics:
            rec["metrics"] = metrics
        tmp = f"{self.path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(rec, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @staticmethod
    def alive_hosts(directory: str, dead_after_s: float = 60.0) -> Dict[int, Dict]:
        out = {}
        now = time.time()
        if not os.path.isdir(directory):
            return out
        for name in os.listdir(directory):
            if not name.startswith("heartbeat_"):
                continue
            try:
                with open(os.path.join(directory, name)) as f:
                    info = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            if now - info.get("time", 0) <= dead_after_s:
                out[int(name.split("_")[1])] = info
        return out


@dataclass
class RecoveryPlan:
    resume_step: Optional[int]
    healthy_hosts: List[int]
    lost_hosts: List[int]


def plan_recovery(heartbeat_dir: str, expected_hosts: int,
                  latest_ckpt_step: Optional[int],
                  dead_after_s: float = 60.0) -> RecoveryPlan:
    alive = Heartbeat.alive_hosts(heartbeat_dir, dead_after_s)
    healthy = sorted(alive)
    lost = [h for h in range(expected_hosts) if h not in alive]
    return RecoveryPlan(resume_step=latest_ckpt_step, healthy_hosts=healthy,
                        lost_hosts=lost)


def run_with_restarts(work_fn: Callable[[Optional[int]], int],
                      latest_step_fn: Callable[[], Optional[int]],
                      max_restarts: int = 3,
                      exceptions: Tuple[Type[BaseException], ...]
                      = (RuntimeError,),
                      backoff_s: float = 0.0,
                      backoff_cap_s: float = 30.0,
                      sleep_fn: Callable[[float], None] = time.sleep) -> int:
    """Supervisor loop: `work_fn(resume_point) -> result`, restarted from
    `latest_step_fn()` after each failure.

    Generalized beyond training (the serving runtime's crash-replay
    supervisor uses it with the journal's retired-request count as the
    progress signal): only exception types in `exceptions` trigger a
    restart — anything else propagates immediately; the attempt budget
    *resets whenever `latest_step_fn()` advances* between failures, so
    `max_restarts` bounds consecutive no-progress crashes rather than
    total lifetime failures; retries back off exponentially
    (`backoff_s · 2^(attempt-1)`, capped at `backoff_cap_s`; 0 disables —
    `sleep_fn` is injectable for tests)."""
    attempts = 0
    last_progress = latest_step_fn()
    while True:
        try:
            return work_fn(latest_step_fn())
        except exceptions:
            progress = latest_step_fn()
            if progress is not None and (last_progress is None
                                         or progress > last_progress):
                attempts = 0       # forward progress: reset the budget
                last_progress = progress
            attempts += 1
            if attempts > max_restarts:
                raise
            if backoff_s > 0.0:
                sleep_fn(min(backoff_s * 2.0 ** (attempts - 1),
                             backoff_cap_s))
