"""Crash-replay request journal for the serving runtime.

An append-only JSONL log of request lifecycle events. After a serving
process dies (crash, OOM-kill, injected fault), `Journal.replay` rebuilds
exactly which requests were in flight, and the runtime re-submits them
with their original rid, seed and sampling settings — bit-deterministic
decode (paged == dense, packed == materialized, per-request seeded
sampling) then reproduces each stream token-identically, so a crash loses
no requests and duplicates none (DESIGN.md §7).

Record kinds (one JSON object per line, `crc` = crc32 of the record's
canonical JSON without the crc field):

* ``submit``      — rid + everything needed to re-create the request:
                    prompt tokens, max_new, sampling settings, stop
                    tokens, priority, seed. fsync-gated: a request is
                    only acknowledged once its submit record is durable.
* ``first_token`` — rid + the TTFT token (observability + a replay-
                    identity cross-check). fsync-gated.
* ``retire``      — rid, finish_reason and the full emitted token list;
                    a retired request is never replayed and its output
                    survives the crash. fsync-gated.
* ``preempt`` / ``resume`` / ``replayed`` — observability only (flushed,
                    not fsynced): preemption counts and recovery audits.

Torn tails are expected — a crash mid-append leaves a partial last line,
which replay drops (detected by JSON parse or crc failure on the final
record), and which reopening for append truncates so the next record
starts on a fresh line (otherwise the first post-recovery append would
merge with the torn tail into a corrupt *non*-tail record and poison
every later replay). A torn or corrupt record *before* the tail is real
corruption and raises `JournalCorrupt`. Replay deduplicates by rid
(submit is idempotent, last retire wins), so recovery after a crash
*during* recovery converges too.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Dict, List, Optional

JOURNAL_NAME = "requests.jsonl"


class JournalCorrupt(RuntimeError):
    """A non-tail journal record failed to parse or checksum."""


def _crc(payload: Dict[str, Any]) -> int:
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


class Journal:
    """Append-only, fsync-gated request log under `directory`."""

    def __init__(self, directory: str, fsync: bool = True):
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, JOURNAL_NAME)
        self._fsync = fsync
        self._seq = self._truncate_torn_tail()
        self._f = open(self.path, "a", encoding="utf-8")

    def _truncate_torn_tail(self) -> int:
        """Drop a partial final line left by a crash mid-append, so the
        first record of this generation starts on a fresh line instead of
        merging with the torn tail (which would become corrupt non-tail
        data on the next replay). Returns the number of surviving lines,
        seeding `seq` so it stays monotonic across reopens."""
        if not os.path.exists(self.path):
            return 0
        with open(self.path, "r+b") as f:
            data = f.read()
            if data and not data.endswith(b"\n"):
                cut = data.rfind(b"\n") + 1      # 0: wipe a 1-line torn file
                f.truncate(cut)
                data = data[:cut]
        return data.count(b"\n")

    def append(self, ev: str, durable: bool = True, **fields) -> None:
        rec = {"ev": ev, "seq": self._seq, **fields}
        rec["crc"] = _crc(rec)
        self._seq += 1
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        if durable and self._fsync:
            os.fsync(self._f.fileno())

    # -- lifecycle records ---------------------------------------------------

    def record_submit(self, req) -> None:
        self.append("submit", rid=req.rid,
                    prompt=[int(t) for t in req.prompt],
                    max_new_tokens=req.max_new_tokens,
                    temperature=req.temperature, top_k=req.top_k,
                    top_p=req.top_p,
                    stop_tokens=list(req.stop_tokens),
                    priority=req.priority, seed=req.seed)

    def record_first_token(self, req, token: int) -> None:
        self.append("first_token", rid=req.rid, token=int(token))

    def record_retire(self, req) -> None:
        self.append("retire", rid=req.rid,
                    finish_reason=req.finish_reason,
                    tokens=[int(t) for t in req.out_tokens])

    def record_preempt(self, req) -> None:
        self.append("preempt", durable=False, rid=req.rid,
                    emitted=len(req.out_tokens))

    def record_resume(self, req) -> None:
        self.append("resume", durable=False, rid=req.rid,
                    emitted=len(req.out_tokens))

    def record_replayed(self, rid: int) -> None:
        self.append("replayed", durable=False, rid=rid)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    # -- recovery ------------------------------------------------------------

    @staticmethod
    def replay(directory: str) -> "JournalState":
        """Parse the journal, tolerating a torn final record (crash mid-
        append); classify every submitted rid as completed or in-flight."""
        path = os.path.join(directory, JOURNAL_NAME)
        records: List[Dict[str, Any]] = []
        if os.path.exists(path):
            with open(path, encoding="utf-8") as f:
                lines = f.read().splitlines()
            for i, line in enumerate(lines):
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                    crc = rec.pop("crc")
                    if crc != _crc(rec):
                        raise ValueError("crc mismatch")
                except (ValueError, KeyError, TypeError) as e:
                    if i == len(lines) - 1:
                        break        # torn tail: the crash interrupted it
                    raise JournalCorrupt(
                        f"{path}: record {i} is corrupt ({e}) but is not "
                        "the tail — the journal was damaged, not torn"
                    ) from e
                records.append(rec)
        submits: Dict[int, Dict[str, Any]] = {}
        retires: Dict[int, Dict[str, Any]] = {}
        first_tokens: Dict[int, int] = {}
        for rec in records:
            rid = rec.get("rid")
            if rec["ev"] == "submit":
                submits.setdefault(rid, rec)     # idempotent by rid
            elif rec["ev"] == "retire":
                retires[rid] = rec               # last retire wins
            elif rec["ev"] == "first_token":
                first_tokens.setdefault(rid, rec["token"])
        inflight = {rid: rec for rid, rec in submits.items()
                    if rid not in retires}
        max_rid = max(submits, default=-1)
        return JournalState(completed=retires, inflight=inflight,
                            first_tokens=first_tokens, max_rid=max_rid,
                            records=records)


@dataclasses.dataclass
class JournalState:
    completed: Dict[int, Dict[str, Any]]    # rid -> retire record
    inflight: Dict[int, Dict[str, Any]]     # rid -> submit record
    first_tokens: Dict[int, int]            # rid -> TTFT token
    max_rid: int
    records: List[Dict[str, Any]]

    def completed_tokens(self, rid: int) -> Optional[List[int]]:
        rec = self.completed.get(rid)
        return None if rec is None else list(rec["tokens"])
