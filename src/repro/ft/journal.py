"""Crash-replay journals: the serving request log and the quantization
run log share one append-only JSONL record discipline.

`_JsonlJournal` is the shared mechanics: one self-checksummed JSON object
per line (`crc` = crc32 of the record's canonical JSON without the crc
field), `flush` always, `fsync` gating durable records, torn-tail
truncation on reopen, and a monotonic `seq` that survives recovery
generations. A crash mid-append leaves a partial last line, which replay
drops (JSON parse or crc failure on the final record) and which reopening
for append truncates — otherwise the first post-recovery append would
merge with the torn tail into corrupt *non*-tail data and poison every
later replay. A torn or checksum-failing record *before* the tail is real
corruption and raises `JournalCorrupt`.

`Journal` (requests.jsonl) is the serving request log — see DESIGN.md §7:
submit/first_token/retire are fsync-gated, preempt/resume/replayed are
observability-only, and `Journal.replay` classifies every submitted rid
as completed or in-flight so recovery re-submits exactly the unfinished
requests.

`QuantJournal` (quant.jsonl + a `leaves/` spill directory) is the
quantization run log — DESIGN.md §8. Record kinds:

* ``run_start``   — the run digest (arch/policy/method/propagation/calib
                    tokens/mesh) plus metadata; fsync-gated. Replay keys
                    leaves to the *last* run_start, so starting a fresh
                    (non-resume) run in the same directory invalidates
                    older spills instead of mixing runs.
* ``leaf_solved`` — (layer, name, resolved-spec digest) plus the spill
                    filename, its payload crc32 and the host err_before/
                    err_after; fsync-gated, and written strictly *after*
                    the spill file is durably renamed into place
                    (solve → spill → journal ordering: a journaled leaf
                    always has a valid spill).
* ``layer_done`` / ``resume`` — observability only (flushed, not
                    fsynced).
* ``run_done``    — the walk completed; fsync-gated.

Each spilled QTensor is an atomic `ckpt.save_packed_ckpt` single file
(tmp + fsync + rename, format/version header + crc32 over the pickled
payload), so `QuantJournal.check_integrity` can assert — after any
injected fault — that every journaled leaf is present and checksum-valid.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

JOURNAL_NAME = "requests.jsonl"
QUANT_JOURNAL_NAME = "quant.jsonl"
SPILL_DIR = "leaves"


class JournalCorrupt(RuntimeError):
    """A non-tail journal record failed to parse or checksum."""


class ResumeMismatch(ValueError):
    """--resume against a journal written by a different run (arch,
    policy, method, calibration data or mesh changed) — resuming would
    silently mix incompatible codes, so refuse instead."""


def _crc(payload: Dict[str, Any]) -> int:
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


def _read_records(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL journal, tolerating a torn final record (crash
    mid-append); non-tail corruption raises JournalCorrupt."""
    records: List[Dict[str, Any]] = []
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
                crc = rec.pop("crc")
                if crc != _crc(rec):
                    raise ValueError("crc mismatch")
            except (ValueError, KeyError, TypeError) as e:
                if i == len(lines) - 1:
                    break        # torn tail: the crash interrupted it
                raise JournalCorrupt(
                    f"{path}: record {i} is corrupt ({e}) but is not "
                    "the tail — the journal was damaged, not torn"
                ) from e
            records.append(rec)
    return records


class _JsonlJournal:
    """Append-only, fsync-gated JSONL log under `directory`."""

    filename = "journal.jsonl"

    def __init__(self, directory: str, fsync: bool = True):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.path = os.path.join(directory, type(self).filename)
        self._fsync = fsync
        self._seq = self._truncate_torn_tail()
        self._f = open(self.path, "a", encoding="utf-8")

    def _truncate_torn_tail(self) -> int:
        """Drop a partial final line left by a crash mid-append, so the
        first record of this generation starts on a fresh line instead of
        merging with the torn tail (which would become corrupt non-tail
        data on the next replay). Returns the number of surviving lines,
        seeding `seq` so it stays monotonic across reopens."""
        if not os.path.exists(self.path):
            return 0
        with open(self.path, "r+b") as f:
            data = f.read()
            if data and not data.endswith(b"\n"):
                cut = data.rfind(b"\n") + 1      # 0: wipe a 1-line torn file
                f.truncate(cut)
                data = data[:cut]
        return data.count(b"\n")

    def append(self, ev: str, durable: bool = True, **fields) -> None:
        rec = {"ev": ev, "seq": self._seq, **fields}
        rec["crc"] = _crc(rec)
        self._seq += 1
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        if durable and self._fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class Journal(_JsonlJournal):
    """The serving request log (see module docstring / DESIGN.md §7)."""

    filename = JOURNAL_NAME

    # -- lifecycle records ---------------------------------------------------

    def record_submit(self, req) -> None:
        self.append("submit", rid=req.rid,
                    prompt=[int(t) for t in req.prompt],
                    max_new_tokens=req.max_new_tokens,
                    temperature=req.temperature, top_k=req.top_k,
                    top_p=req.top_p,
                    stop_tokens=list(req.stop_tokens),
                    priority=req.priority, seed=req.seed)

    def record_first_token(self, req, token: int) -> None:
        self.append("first_token", rid=req.rid, token=int(token))

    def record_retire(self, req) -> None:
        self.append("retire", rid=req.rid,
                    finish_reason=req.finish_reason,
                    tokens=[int(t) for t in req.out_tokens])

    def record_preempt(self, req) -> None:
        self.append("preempt", durable=False, rid=req.rid,
                    emitted=len(req.out_tokens))

    def record_resume(self, req) -> None:
        self.append("resume", durable=False, rid=req.rid,
                    emitted=len(req.out_tokens))

    def record_replayed(self, rid: int) -> None:
        self.append("replayed", durable=False, rid=rid)

    # -- recovery ------------------------------------------------------------

    @staticmethod
    def replay(directory: str) -> "JournalState":
        """Parse the journal, tolerating a torn final record (crash mid-
        append); classify every submitted rid as completed or in-flight."""
        records = _read_records(os.path.join(directory, JOURNAL_NAME))
        submits: Dict[int, Dict[str, Any]] = {}
        retires: Dict[int, Dict[str, Any]] = {}
        first_tokens: Dict[int, int] = {}
        for rec in records:
            rid = rec.get("rid")
            if rec["ev"] == "submit":
                submits.setdefault(rid, rec)     # idempotent by rid
            elif rec["ev"] == "retire":
                retires[rid] = rec               # last retire wins
            elif rec["ev"] == "first_token":
                first_tokens.setdefault(rid, rec["token"])
        inflight = {rid: rec for rid, rec in submits.items()
                    if rid not in retires}
        max_rid = max(submits, default=-1)
        return JournalState(completed=retires, inflight=inflight,
                            first_tokens=first_tokens, max_rid=max_rid,
                            records=records)


@dataclasses.dataclass
class JournalState:
    completed: Dict[int, Dict[str, Any]]    # rid -> retire record
    inflight: Dict[int, Dict[str, Any]]     # rid -> submit record
    first_tokens: Dict[int, int]            # rid -> TTFT token
    max_rid: int
    records: List[Dict[str, Any]]

    def completed_tokens(self, rid: int) -> Optional[List[int]]:
        rec = self.completed.get(rid)
        return None if rec is None else list(rec["tokens"])


# ---------------------------------------------------------------------------
# quantization run journal
# ---------------------------------------------------------------------------

class QuantJournal(_JsonlJournal):
    """The quantization run log + durable per-leaf QTensor spills (see
    module docstring / DESIGN.md §8). The ckpt imports are lazy:
    ckpt/quantized imports core.pipeline, which imports repro.ft — a
    module-level import here would close that cycle."""

    filename = QUANT_JOURNAL_NAME

    def __init__(self, directory: str, fsync: bool = True):
        super().__init__(directory, fsync)
        self.spill_dir = os.path.join(directory, SPILL_DIR)
        os.makedirs(self.spill_dir, exist_ok=True)

    # -- records -------------------------------------------------------------

    def record_run_start(self, run_digest: int, **meta) -> None:
        self.append("run_start", run=int(run_digest), **meta)

    def spill_leaf(self, layer: int, name: str, qt_host,
                   fault_cb=None) -> Tuple[str, int]:
        """Durably write one solved QTensor (host arrays) as an atomic
        packed-ckpt file; returns (filename, payload crc32). Runs
        *before* record_leaf — the solve → spill → journal ordering."""
        from repro.ckpt.quantized import save_packed_ckpt
        fname = f"L{layer}_{name.replace('/', '_')}.qt"
        crc = save_packed_ckpt(os.path.join(self.spill_dir, fname), qt_host,
                               fault_cb=fault_cb, layer=int(layer),
                               name=str(name))
        return fname, crc

    def record_leaf(self, layer: int, name: str, spec_digest: int,
                    fname: str, crc: int, err_before: float,
                    err_after: float) -> None:
        self.append("leaf_solved", layer=int(layer), name=str(name),
                    spec=int(spec_digest), file=fname, crc32=int(crc),
                    err_before=float(err_before),
                    err_after=float(err_after))

    def record_layer_done(self, layer: int) -> None:
        self.append("layer_done", durable=False, layer=int(layer))

    def record_resume(self, n_leaves: int) -> None:
        self.append("resume", durable=False, leaves=int(n_leaves))

    def record_run_done(self) -> None:
        self.append("run_done")

    # -- recovery ------------------------------------------------------------

    @staticmethod
    def replay(directory: str) -> "QuantState":
        """Rebuild the run state: the last run_start (earlier runs'
        leaves are discarded — a fresh run in the same directory starts
        clean), journaled leaves keyed (layer, name) last-wins, and
        whether the run completed."""
        records = _read_records(os.path.join(directory, QUANT_JOURNAL_NAME))
        run: Optional[Dict[str, Any]] = None
        leaves: Dict[Tuple[int, str], Dict[str, Any]] = {}
        done = False
        for rec in records:
            if rec["ev"] == "run_start":
                run, leaves, done = rec, {}, False
            elif rec["ev"] == "leaf_solved":
                leaves[(rec["layer"], rec["name"])] = rec
            elif rec["ev"] == "run_done":
                done = True
        return QuantState(run=run, leaves=leaves, done=done, records=records)

    @staticmethod
    def load_leaf(directory: str, rec: Dict[str, Any]):
        """Load one journaled leaf's spilled QTensor (host arrays),
        validating the spill's header checksum *and* that it matches the
        crc the journal recorded for this leaf."""
        from repro.ckpt.quantized import load_packed_ckpt
        path = os.path.join(directory, SPILL_DIR, rec["file"])
        return load_packed_ckpt(path, expect_crc=rec["crc32"])["tree"]

    @staticmethod
    def check_integrity(directory: str) -> int:
        """Assert journal↔checkpoint integrity: every journaled leaf's
        spill file exists and is checksum-valid (header crc over the
        payload, cross-checked against the journaled crc). Returns the
        number of verified leaves; raises PackedCkptError on any
        missing/corrupt spill."""
        from repro.ckpt.quantized import PackedCkptError
        st = QuantJournal.replay(directory)
        for (layer, name), rec in st.leaves.items():
            try:
                QuantJournal.load_leaf(directory, rec)
            except OSError as e:
                raise PackedCkptError(
                    f"journaled leaf layer {layer} {name!r}: spill "
                    f"{rec['file']!r} unreadable ({e})") from e
        return len(st.leaves)


@dataclasses.dataclass
class QuantState:
    run: Optional[Dict[str, Any]]            # last run_start record
    leaves: Dict[Tuple[int, str], Dict[str, Any]]
    done: bool
    records: List[Dict[str, Any]]
