"""Deterministic fault injection for the serving runtime and the
quantization pipeline.

A `FaultInjector` owns a set of named fault points; the runtime (and the
block allocator's `fail_hook`) call `fire(point)` at each hook site and
the injector decides — from an explicit occurrence schedule or a seeded
Bernoulli draw fixed at construction — whether that occurrence faults.
Schedules are pure functions of the constructor arguments, so a failing
test replays bit-identically.

Fault points wired through serve/runtime.py:

* ``page_alloc``   — `BlockAllocator.alloc` reports exhaustion with pages
                     free: exercises backpressure (reserve) and the
                     preemption-by-page-reclaim path (preempt).
* ``decode_step``  — raises `InjectedFault` immediately before the decode
                     program launches: an in-process serving failure the
                     supervisor loop (`ft.run_with_restarts`) restarts.
* ``callback``     — the per-token stream callback raises: must be
                     contained per-request (recorded on `Request.
                     cb_errors`), never poisoning the shared batch.
* ``kill``         — raises `SimulatedKill` between steps: models a
                     process death. No cleanup runs; recovery goes
                     through the crash-replay journal (ft/journal.py).

Pipeline fault points wired through core/pipeline.py (DESIGN.md §8; the
``kill`` site is shared — in the pipeline it fires between layers, after
the completed layer's leaves are journaled):

* ``gram_accumulate`` — raises `InjectedFault` right before a tap
                     group's Gram accumulation.
* ``leaf_solve``   — raises `InjectedFault` before a leaf's solve (one
                     occurrence per leaf, counted in walk order).
* ``ckpt_write``   — fires *inside* a leaf spill, after the tmp file is
                     written+fsynced but before the atomic rename —
                     the torn-write window the durability ordering must
                     survive (ckpt.save_packed_ckpt's fault_cb).
* ``nan_tap``      — does not raise: poisons one entry of the tap with
                     NaN, exercising the numeric sentinels
                     (core/guards.py) instead of the crash path.

Usage::

    inj = FaultInjector({"page_alloc": [3, 7], "kill": [5]})
    # ... the 3rd and 7th page allocs fail; the 5th kill-site check dies.

    inj = FaultInjector.random(seed=0, rates={"decode_step": 0.1})
    # ... seeded Bernoulli schedule, identical across replays.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np


# fault points with hook sites in serve/runtime.py or core/pipeline.py;
# parse() rejects anything else so a typo'd --inject fails loudly
# instead of never firing
FAULT_POINTS = frozenset({"page_alloc", "decode_step", "callback", "kill",
                          "gram_accumulate", "leaf_solve", "ckpt_write",
                          "nan_tap"})


class InjectedFault(RuntimeError):
    """A seeded in-process fault (decode-step / callback site)."""


class SimulatedKill(RuntimeError):
    """A seeded process death: nothing cleans up; recovery must come from
    the journal. Distinct from InjectedFault so tests can assert *which*
    failure mode they provoked."""


class FaultInjector:
    """Named fault points with deterministic firing schedules.

    `schedule` maps point name -> iterable of 1-based occurrence indices
    that fault. Occurrence counters persist for the injector's lifetime
    (spanning supervisor restarts), so "the 5th alloc ever" means exactly
    that even if the runtime is rebuilt around the same injector."""

    def __init__(self, schedule: Optional[Dict[str, Iterable[int]]] = None):
        self.schedule: Dict[str, set] = {
            k: set(int(i) for i in v) for k, v in (schedule or {}).items()}
        self.counts: Dict[str, int] = {}
        self.fired: List[tuple] = []       # (point, occurrence) audit log

    @classmethod
    def random(cls, seed: int, rates: Dict[str, float],
               horizon: int = 10_000) -> "FaultInjector":
        """Seeded Bernoulli schedule: occurrence i of `point` faults with
        probability rates[point], pre-drawn over `horizon` occurrences so
        the schedule is fixed at construction (replayable)."""
        rs = np.random.RandomState(seed)
        schedule = {}
        for point in sorted(rates):
            draws = rs.random_sample(horizon) < rates[point]
            schedule[point] = [i + 1 for i in np.flatnonzero(draws)]
        return cls(schedule)

    @classmethod
    def parse(cls, spec: str) -> "FaultInjector":
        """CLI form: "point:occ[+occ...],point:occ" — e.g.
        "page_alloc:3+7,kill:5" (launch/serve.py --inject)."""
        schedule: Dict[str, List[int]] = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            point, _, occs = part.partition(":")
            if not occs:
                raise ValueError(f"--inject entry {part!r} needs "
                                 "point:occurrence[+occurrence...]")
            if point not in FAULT_POINTS:
                raise ValueError(
                    f"--inject point {point!r} is not a known fault point "
                    f"(choose from {', '.join(sorted(FAULT_POINTS))})")
            schedule.setdefault(point, []).extend(
                int(o) for o in occs.split("+"))
        return cls(schedule)

    def fire(self, point: str) -> bool:
        """Count one occurrence of `point`; True when it should fault."""
        n = self.counts.get(point, 0) + 1
        self.counts[point] = n
        hit = n in self.schedule.get(point, ())
        if hit:
            self.fired.append((point, n))
        return hit

    def check(self, point: str, exc=InjectedFault) -> None:
        """fire() and raise `exc` on a hit (decode_step / kill sites)."""
        if self.fire(point):
            raise exc(f"injected fault at {point} occurrence "
                      f"{self.counts[point]}")
