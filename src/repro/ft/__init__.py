from repro.ft.inject import (FaultInjector, InjectedFault,  # noqa: F401
                             SimulatedKill)
from repro.ft.journal import (Journal, JournalCorrupt,  # noqa: F401
                              JournalState, QuantJournal, QuantState,
                              ResumeMismatch)
from repro.ft.watchdog import (Heartbeat, RecoveryPlan, StragglerEvent,  # noqa: F401,E501
                               Watchdog, plan_recovery, run_with_restarts)
