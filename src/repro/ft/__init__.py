from repro.ft.watchdog import (Heartbeat, RecoveryPlan, StragglerEvent,  # noqa: F401
                               Watchdog, plan_recovery, run_with_restarts)
