"""Token samplers (pure functions over logits).

`sample` keeps the engine's static-config API (python-scalar temperature /
top_k / top_p); `sample_batch` is the continuous-batching form — per-slot
temperature/top_k/top_p arrive as (B,) arrays so one jitted program serves
a batch of requests with heterogeneous sampling settings (no recompile per
mix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _nucleus_mask(scaled, top_k, top_p):
    """Mask (B, V) logits outside per-row top-k / top-p; top_k<=0 and
    top_p<=0 disable the respective filter. The most-likely token always
    survives."""
    B, V = scaled.shape
    order = jnp.argsort(-scaled, axis=-1)           # descending
    sorted_l = jnp.take_along_axis(scaled, order, axis=-1)
    rank = jnp.arange(V, dtype=jnp.int32)[None]
    k_eff = jnp.where(top_k > 0, top_k, V).astype(jnp.int32)[:, None]
    keep = rank < k_eff
    probs = jax.nn.softmax(sorted_l, axis=-1)
    csum_excl = jnp.cumsum(probs, axis=-1) - probs  # mass *before* token
    p_eff = jnp.where(top_p > 0, top_p, 1.0)[:, None]
    keep &= csum_excl < p_eff
    keep = keep.at[:, 0].set(True)
    masked_sorted = jnp.where(keep, sorted_l, NEG)
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(masked_sorted, inv, axis=-1)


def sample_batch(logits, rng, *, temperature, top_k, top_p):
    """logits: (B, V); temperature/top_p: (B,) f32; top_k: (B,) int32.
    Per-row: temperature<=0 -> greedy argmax; otherwise top-k/top-p-
    filtered categorical. Returns (B,) int32."""
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature,
                                                      1e-6)[:, None]
    masked = _nucleus_mask(scaled, top_k, top_p)
    drawn = jax.random.categorical(rng, masked, axis=-1)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(temperature <= 0.0, greedy, drawn).astype(jnp.int32)


def sample_batch_seeded(logits, seeds, counts, *, temperature, top_k,
                        top_p):
    """Replayable per-request sampling: logits (B, V); seeds (B,) uint32
    per-request sampling seeds; counts (B,) int32 index of the token being
    drawn. Row i's draw is a pure function of (seeds[i], counts[i]) — not
    of the slot index, the decode-step count, or which other requests
    share the batch — so a preempted/resumed or crash-replayed request
    redraws its exact stream (DESIGN.md §7). Greedy rows (temperature<=0)
    ignore the rng entirely."""
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature,
                                                      1e-6)[:, None]
    masked = _nucleus_mask(scaled, top_k, top_p)
    keys = jax.vmap(lambda s, c: jax.random.fold_in(
        jax.random.PRNGKey(s), c))(jnp.asarray(seeds, jnp.uint32),
                                   jnp.asarray(counts, jnp.int32))
    drawn = jax.vmap(lambda k, row: jax.random.categorical(k, row))(
        keys, masked)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(temperature <= 0.0, greedy, drawn).astype(jnp.int32)


def sample(logits, rng, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 0.0):
    """logits: (B, V) -> (B,) int32. Static (python-scalar) config form."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, NEG, logits)
    if top_p > 0.0:
        B = logits.shape[0]
        logits = _nucleus_mask(logits,
                               jnp.zeros((B,), jnp.int32),
                               jnp.full((B,), top_p, jnp.float32))
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
