"""Paged KV-cache pool: fixed-size pages + per-slot block tables.

The dense engine allocates a (B, max_len) cache per slot — every slot pays
for the longest possible sequence. The paged pool instead owns
`num_blocks` pages of `block_size` tokens shared by all slots; a slot maps
logical block i -> physical page via its block-table row, pages are
allocated at admission and freed at completion, and attention walks the
table (kernels/paged_attention.py Pallas kernel on TPU, gather fallback on
XLA — models/attention.paged_decode_attend). Memory scales with the
*live* tokens, not max_slots x max_len.

Device layout (models/model.decode_step_paged scans layers over the pool):

    pool["k"], pool["v"]: (L, num_blocks, block_size, KV, hd)
    block_tables:         (max_slots, max_blocks_per_slot) int32
    pos:                  (max_slots,) absolute next position, -1 inactive

`BlockAllocator` is plain host state (the scheduler thread owns it); the
jitted `write_prefill` scatters a prefilled dense cache's rows into the
slot's pages (ring-aware: rows route by their absolute `pos`, so SWA
prefill caches land on the right pages).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def blocks_for(tokens: int, block_size: int) -> int:
    """Pages needed to hold `tokens` positions."""
    return max(1, math.ceil(tokens / block_size))


def init_paged_cache(cfg, plan, num_blocks: int,
                     block_size: int) -> Dict[str, Array]:
    """Zeroed K/V page pools, stacked over layers for the decode scan."""
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, plan.cache_dtype),
            "v": jnp.zeros(shape, plan.cache_dtype)}


def paged_cache_bytes(cfg, plan, num_blocks: int, block_size: int) -> int:
    hd = cfg.resolved_head_dim
    itemsize = jnp.dtype(plan.cache_dtype).itemsize
    return 2 * cfg.n_layers * num_blocks * block_size * cfg.n_kv_heads \
        * hd * itemsize


class BlockAllocator:
    """Host-side free list over the physical pages. No device state: the
    pool itself never moves — allocation only decides which page ids a
    slot's block-table row points at.

    `fail_hook` is the fault-injection seam (ft/inject.py): when set and it
    returns True, alloc reports exhaustion even with pages free —
    exercising the backpressure/preemption paths deterministically."""

    def __init__(self, num_blocks: int,
                 fail_hook: Optional[Callable[[], bool]] = None):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._held: set = set()
        self.peak_in_use = 0
        self.fail_hook = fail_hook

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None when exhausted (admission backpressure /
        preemption trigger) or when the injected fault hook fires."""
        if self.fail_hook is not None and self.fail_hook():
            return None
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._held.update(out)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b < 0 or b >= self.num_blocks:
                raise ValueError(f"freeing unknown block {b}")
            if b not in self._held:
                raise ValueError(f"double free of block {b}")
        for b in blocks:
            self._held.discard(b)
        self._free.extend(blocks)

    def check_integrity(self) -> None:
        """Free list and held set must exactly partition the pool — the
        no-leak/no-double-free oracle the fault tests assert after every
        injected failure."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate page ids on the free list")
        if free & self._held:
            raise AssertionError(
                f"pages both free and held: {sorted(free & self._held)}")
        if len(free) + len(self._held) != self.num_blocks:
            missing = set(range(self.num_blocks)) - free - self._held
            raise AssertionError(f"leaked pages: {sorted(missing)}")


def write_prefill(pool: Dict[str, Array], k_seq: Array, v_seq: Array,
                  pos_row: Array, table_row: Array) -> Dict[str, Array]:
    """Scatter one request's prefilled K/V rows into its pages.

    k_seq/v_seq: (L, S, KV, hd) from the dense prefill cache; pos_row: (S,)
    absolute positions (-1 = unwritten row, dropped); table_row: (MAXB,)
    physical page ids. Rows route by position — block pos//BS, offset
    pos%BS — so ring-buffer (SWA) prefill caches scatter correctly."""
    k_pool, v_pool = pool["k"], pool["v"]
    L, NB, BS = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    safe = jnp.maximum(pos_row, 0)
    phys = table_row[safe // BS]
    dest = jnp.where(pos_row >= 0, phys * BS + safe % BS, NB * BS)
    kf = k_pool.reshape(L, NB * BS, *k_pool.shape[3:])
    vf = v_pool.reshape(L, NB * BS, *v_pool.shape[3:])
    kf = kf.at[:, dest].set(k_seq.astype(kf.dtype), mode="drop")
    vf = vf.at[:, dest].set(v_seq.astype(vf.dtype), mode="drop")
    return {"k": kf.reshape(k_pool.shape), "v": vf.reshape(v_pool.shape)}
