"""Paged KV-cache pool: fixed-size pages + per-slot block tables.

The dense engine allocates a (B, max_len) cache per slot — every slot pays
for the longest possible sequence. The paged pool instead owns
`num_blocks` pages of `block_size` tokens shared by all slots; a slot maps
logical block i -> physical page via its block-table row, pages are
allocated at admission and freed at completion, and attention walks the
table (kernels/paged_attention.py Pallas kernel on TPU, gather fallback on
XLA — models/attention.paged_decode_attend). Memory scales with the
*live* tokens, not max_slots x max_len.

Device layout (models/model.decode_step_paged scans layers over the pool):

    pool["k"], pool["v"]: (L, num_blocks, block_size, KV, hd)
    block_tables:         (max_slots, max_blocks_per_slot) int32
    pos:                  (max_slots,) absolute next position, -1 inactive

`BlockAllocator` is plain host state (the scheduler thread owns it); the
jitted `write_prefill` scatters a prefilled dense cache's rows into the
slot's pages (ring-aware: rows route by their absolute `pos`, so SWA
prefill caches land on the right pages).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def blocks_for(tokens: int, block_size: int) -> int:
    """Pages needed to hold `tokens` positions."""
    return max(1, math.ceil(tokens / block_size))


# Quantized pages (DESIGN.md §11): integer codes + one f32 scale per
# (layer, page, kv_head). int8 is symmetric absmax/127; 4-bit packs two
# offset-binary nibbles per byte (code = q + 8, q in [-7, 7]) with a
# clip-aware scale shrink — at 4 bits the absmax code wastes range on the
# single largest row entry, and clipping the tail slightly beats pure
# absmax (arXiv 2510.04044 range-estimation discipline).
KV4_CLIP = 0.96


def _kv_qmax(kv_bits: int) -> float:
    return 127.0 if kv_bits == 8 else 7.0


def kv_code_width(kv_bits: int) -> int:
    """Codes per byte of pool storage (1 for int8, 2 for packed 4-bit)."""
    if kv_bits not in (4, 8):
        raise ValueError(f"kv_bits must be 4 or 8, got {kv_bits}")
    return 1 if kv_bits == 8 else 2


def kv_scale_of(absmax: Array, kv_bits: int) -> Array:
    """Per-(page, kv_head) scale from the page's row absmax."""
    clip = 1.0 if kv_bits == 8 else KV4_CLIP
    return (clip / _kv_qmax(kv_bits)) * absmax.astype(jnp.float32)


def kv_encode(rows: Array, scale: Array, kv_bits: int) -> Array:
    """rows (..., hd) float -> integer codes under `scale` (broadcast over
    hd). Zero scale (all-zero page) encodes to zero codes exactly."""
    qmax = _kv_qmax(kv_bits)
    s = scale.astype(jnp.float32)[..., None]
    q = jnp.where(s > 0, rows.astype(jnp.float32) / jnp.where(s > 0, s, 1.0),
                  0.0)
    q = jnp.clip(jnp.round(q), -qmax, qmax)
    if kv_bits == 8:
        return q.astype(jnp.int8)
    from repro.core.quantizer import pack_int4  # function-level: no cycle
    return pack_int4((q + 8.0).astype(jnp.uint8))


def kv_decode(codes: Array, scale: Array, kv_bits: int,
              dtype=jnp.float32) -> Array:
    """Inverse of kv_encode: codes (..., hd / cpb) -> (..., hd) floats."""
    if kv_bits == 8:
        q = codes.astype(jnp.float32)
    else:
        from repro.core.quantizer import unpack_int4  # no import cycle
        q = unpack_int4(codes).astype(jnp.float32) - 8.0
    return (q * scale.astype(jnp.float32)[..., None]).astype(dtype)


def init_paged_cache(cfg, plan, num_blocks: int,
                     block_size: int) -> Dict[str, Array]:
    """Zeroed K/V page pools, stacked over layers for the decode scan.
    With `plan.kv_bits` in {4, 8} pages hold integer codes plus per-
    (layer, page, kv_head) f32 scales under "k_scale"/"v_scale"."""
    hd = cfg.resolved_head_dim
    kv_bits = int(getattr(plan, "kv_bits", 0) or 0)
    if not kv_bits:
        shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, plan.cache_dtype),
                "v": jnp.zeros(shape, plan.cache_dtype)}
    cpb = kv_code_width(kv_bits)
    if hd % cpb:
        raise ValueError(f"kv_bits={kv_bits} needs head_dim % {cpb} == 0")
    dt = jnp.int8 if kv_bits == 8 else jnp.uint8
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd // cpb)
    sshape = (cfg.n_layers, num_blocks, cfg.n_kv_heads)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32)}


def paged_cache_bytes(cfg, plan, num_blocks: int, block_size: int) -> int:
    """Device bytes the pool holds: code (or bf16) payload plus, when
    quantized, the per-(layer, page, kv_head) f32 scale tensors."""
    hd = cfg.resolved_head_dim
    kv_bits = int(getattr(plan, "kv_bits", 0) or 0)
    if not kv_bits:
        itemsize = jnp.dtype(plan.cache_dtype).itemsize
        return 2 * cfg.n_layers * num_blocks * block_size * cfg.n_kv_heads \
            * hd * itemsize
    payload = 2 * cfg.n_layers * num_blocks * block_size * cfg.n_kv_heads \
        * (hd // kv_code_width(kv_bits))
    scales = 2 * cfg.n_layers * num_blocks * cfg.n_kv_heads * 4
    return payload + scales


class BlockAllocator:
    """Host-side free list over the physical pages. No device state: the
    pool itself never moves — allocation only decides which page ids a
    slot's block-table row points at.

    `fail_hook` is the fault-injection seam (ft/inject.py): when set and it
    returns True, alloc reports exhaustion even with pages free —
    exercising the backpressure/preemption paths deterministically.

    `partitions` > 1 splits the pool into contiguous equal ranges for the
    TP-sharded runtime: partition p owns pages [p*npp, (p+1)*npp), which
    is exactly shard p's slice of the page-dim-sharded device pool, so a
    slot pinned to partition p only ever references device-local pages
    (dist/sharding.py `paged_pool_specs`; 0-collective decode)."""

    def __init__(self, num_blocks: int,
                 fail_hook: Optional[Callable[[], bool]] = None,
                 partitions: int = 1):
        if partitions < 1 or num_blocks % partitions:
            raise ValueError(f"num_blocks={num_blocks} must split evenly "
                             f"over {partitions} partitions")
        self.num_blocks = num_blocks
        self.partitions = partitions
        self.partition_blocks = num_blocks // partitions
        npp = self.partition_blocks
        # LIFO within each partition, matching the single-partition order
        self._frees: List[List[int]] = [
            list(range((p + 1) * npp - 1, p * npp - 1, -1))
            for p in range(partitions)]
        self._held: set = set()
        self.peak_in_use = 0
        self.fail_hook = fail_hook

    @property
    def num_free(self) -> int:
        return sum(len(f) for f in self._frees)

    def num_free_in(self, part: int) -> int:
        return len(self._frees[part])

    @property
    def in_use(self) -> int:
        return self.num_blocks - self.num_free

    def alloc(self, n: int, part: int = 0) -> Optional[List[int]]:
        """n pages from `part`, or None when the partition is exhausted
        (admission backpressure / preemption trigger) or when the
        injected fault hook fires."""
        if self.fail_hook is not None and self.fail_hook():
            return None
        free = self._frees[part]
        if n > len(free):
            return None
        out = [free.pop() for _ in range(n)]
        self._held.update(out)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def partition_of(self, block: int) -> int:
        return block // self.partition_blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b < 0 or b >= self.num_blocks:
                raise ValueError(f"freeing unknown block {b}")
            if b not in self._held:
                raise ValueError(f"double free of block {b}")
        for b in blocks:
            self._held.discard(b)
            self._frees[self.partition_of(b)].append(b)

    def check_integrity(self) -> None:
        """Free list and held set must exactly partition the pool — the
        no-leak/no-double-free oracle the fault tests assert after every
        injected failure."""
        free = set()
        for p, fl in enumerate(self._frees):
            if len(set(fl)) != len(fl):
                raise AssertionError("duplicate page ids on the free list")
            for b in fl:
                if self.partition_of(b) != p:
                    raise AssertionError(
                        f"page {b} on partition {p}'s free list")
            free.update(fl)
        if free & self._held:
            raise AssertionError(
                f"pages both free and held: {sorted(free & self._held)}")
        if len(free) + len(self._held) != self.num_blocks:
            missing = set(range(self.num_blocks)) - free - self._held
            raise AssertionError(f"leaked pages: {sorted(missing)}")


def write_prefill(pool: Dict[str, Array], k_seq: Array, v_seq: Array,
                  pos_row: Array, table_row: Array,
                  kv_bits: int = 0) -> Dict[str, Array]:
    """Scatter one request's prefilled K/V rows into its pages.

    k_seq/v_seq: (L, S, KV, hd) from the dense prefill cache; pos_row: (S,)
    absolute positions (-1 = unwritten row, dropped); table_row: (MAXB,)
    physical page ids. Rows route by position — block pos//BS, offset
    pos%BS — so ring-buffer (SWA) prefill caches scatter correctly.

    With `kv_bits` set the rows quantize on the way in: every touched page
    gets a fresh scale from a scatter-max of its incoming row absmaxes
    (prefill owns all live rows of its pages, so overwriting the page
    scale is exact and also wipes any stale scale left by a freed
    request), then rows encode at their page's scale and the codes
    scatter. Untouched pages keep code and scale bits untouched."""
    k_pool, v_pool = pool["k"], pool["v"]
    L, NB, BS = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    safe = jnp.maximum(pos_row, 0)
    valid = pos_row >= 0
    phys = table_row[safe // BS]
    dest = jnp.where(valid, phys * BS + safe % BS, NB * BS)
    if not kv_bits:
        kf = k_pool.reshape(L, NB * BS, *k_pool.shape[3:])
        vf = v_pool.reshape(L, NB * BS, *v_pool.shape[3:])
        kf = kf.at[:, dest].set(k_seq.astype(kf.dtype), mode="drop")
        vf = vf.at[:, dest].set(v_seq.astype(vf.dtype), mode="drop")
        return {"k": kf.reshape(k_pool.shape), "v": vf.reshape(v_pool.shape)}

    page = jnp.where(valid, phys, NB)           # OOB sentinel -> drop
    touched = jnp.zeros((NB,), bool).at[page].set(True, mode="drop")
    out = {}
    for name, cpool, rows in (("k", k_pool, k_seq), ("v", v_pool, v_seq)):
        KV = cpool.shape[3]
        absmax = jnp.max(jnp.abs(rows.astype(jnp.float32)), axis=-1)
        absmax = jnp.where(valid[None, :, None], absmax, 0.0)   # (L, S, KV)
        pmax = jnp.zeros((L, NB, KV), jnp.float32) \
            .at[:, page].max(absmax, mode="drop")
        new_scale = jnp.where(touched[None, :, None],
                              kv_scale_of(pmax, kv_bits),
                              pool[name + "_scale"])
        codes = kv_encode(rows, new_scale[:, page], kv_bits)
        cf = cpool.reshape(L, NB * BS, *cpool.shape[3:])
        cf = cf.at[:, dest].set(codes, mode="drop")
        out[name] = cf.reshape(cpool.shape)
        out[name + "_scale"] = new_scale
    return out
