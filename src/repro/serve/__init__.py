"""Serving subsystem.

`Runtime` (serve/runtime.py) is the continuous-batching paged-KV serving
loop — priority admission, preemption-by-page-reclaim, mixed lengths,
staggered arrivals, packed-QT params, optional crash-replay journal
(`recover_runtime` rebuilds the queue after a process death). `Engine`
(serve/engine.py) is the static-slot equal-length batcher kept as the
equivalence baseline.
"""
from repro.serve.engine import Engine  # noqa: F401
from repro.serve.kv_cache import (BlockAllocator, blocks_for,  # noqa: F401
                                  init_paged_cache, paged_cache_bytes)
from repro.serve.runtime import (Runtime, ServeConfig,  # noqa: F401
                                 recover_runtime)
from repro.serve.sampler import (sample, sample_batch,  # noqa: F401
                                 sample_batch_seeded)
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
