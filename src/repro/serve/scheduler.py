"""Continuous-batching scheduler: priority admission, page accounting,
preemption-by-page-reclaim.

Host-side policy only — no device arrays. The runtime asks the scheduler
which queued requests can start *now* and, each decode step, for the pages
the step is about to write. Two admission policies:

* ``policy="preempt"`` (default) — **incremental allocation**: admission
  needs a decode slot plus only the pages the prefill will write; decode
  growth allocates one page at a time (`ensure_pages`). On pool exhaustion
  the scheduler reclaims pages by preempting the *victim* — the running
  request with the numerically largest ``(priority, rid)``, i.e. the least
  important, latest-arrived one — freeing its pages and re-queueing it for
  recompute-based resume (the runtime re-prefills prompt + already-emitted
  tokens; bit-determinism makes the resumed stream token-identical, which
  is what the fault tests assert). A preempted request keeps its rid, so
  within its priority class it re-admits ahead of anything newer —
  starvation-free. Reservation no longer caps occupancy: pages track live
  tokens.
* ``policy="reserve"`` — the PR-4 behavior kept for A/B
  (`serve/preempt_occupancy_vs_reserved` bench): every page the request
  can ever touch is reserved at admission, so an admitted request runs to
  completion with no preemption; exhaustion backpressures the queue.

Admission is ordered by ``(priority, rid)`` — priority class first (lower
= more urgent), arrival order within a class; `priority=0` everywhere
degrades to the old strict FCFS. The head of the order blocks later
requests (no head-of-line bypass), and under ``preempt`` a head that is
*strictly* more urgent than a running victim may reclaim that victim's
slot/pages at admission too.

Prompts are padded to a small static set of bucket lengths so the jitted
prefill closures recompile at most once per bucket (right-padding: causal
attention makes the prefix K/V and the last-prompt-token logits exact; pad
rows are never copied into the paged pool). Resumed requests re-prefill
prompt + emitted tokens, which can exceed the configured buckets — those
extend to the next power of two (still a bounded compile set).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.kv_cache import BlockAllocator, blocks_for

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512)


@dataclasses.dataclass(eq=False)     # identity equality: queue bookkeeping
class Request:
    """A generation request and its full lifecycle record.

    `priority` is the admission class: lower is more urgent; ties admit in
    arrival order. `seed` makes sampling replayable — every sampled token
    is a pure function of (seed, token index), independent of batch
    composition, decode-step count or slot, so a preempted/resumed or
    crash-replayed request redraws the identical stream. `stop_tokens`
    terminates generation early (the stop token itself is emitted and the
    request retires on the same step). `finish_reason` records which bound
    fired. Exceptions raised by `stream_cb` are contained (recorded in
    `cb_errors`) — a broken consumer must not poison the shared decode
    batch."""
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    stream_cb: Optional[Callable[["Request", int], None]] = None
    priority: int = 0
    seed: Optional[int] = None
    # filled by scheduler/runtime
    rid: int = -1
    state: str = "queued"               # queued | running | done
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    blocks: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    itl: List[float] = dataclasses.field(default_factory=list)
    finish_reason: str = ""             # "stop_token" | "length"
    n_preempts: int = 0
    cb_errors: List[BaseException] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    def emit(self, token: int, now: float) -> None:
        if self.out_tokens:
            self.itl.append(now - self._t_last)
        else:
            self.t_first_token = now
        self._t_last = now
        self.out_tokens.append(int(token))
        if self.stream_cb is not None:
            try:
                self.stream_cb(self, int(token))
            except Exception as e:   # noqa: BLE001 — contain consumer bugs
                self.cb_errors.append(e)

    def finished(self) -> bool:
        """Stop-token or length bound reached; sets finish_reason."""
        if self.out_tokens and self.out_tokens[-1] in self.stop_tokens:
            self.finish_reason = "stop_token"
            return True
        if len(self.out_tokens) >= self.max_new_tokens:
            self.finish_reason = "length"
            return True
        return False


def _order_key(req: Request) -> Tuple[int, int]:
    return (req.priority, req.rid)


class Scheduler:
    """Priority queue + slot table + page accounting over a BlockAllocator."""

    def __init__(self, max_slots: int, allocator: BlockAllocator,
                 buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                 block_size: int = 16,
                 max_blocks_per_slot: Optional[int] = None,
                 policy: str = "preempt"):
        if policy not in ("preempt", "reserve"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.max_slots = max_slots
        self.allocator = allocator
        self.buckets = tuple(sorted(buckets))
        self.block_size = block_size
        self.policy = policy
        self.max_blocks_per_slot = (
            max_blocks_per_slot
            if max_blocks_per_slot is not None
            else blocks_for(self.buckets[-1] + 64, block_size))
        self.queue: List[Request] = []
        self.running: Dict[int, Request] = {}     # slot -> request
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._rid = itertools.count()
        self.completed: List[Request] = []
        self.preemptions = 0
        # TP pool sharding (DESIGN.md §11): the allocator splits the pool
        # into one contiguous page range per device shard, and slots pin
        # to the partition holding their shard's slice of the batch dim —
        # a slot only ever references pages its own device owns, which is
        # what keeps the sharded decode step collective-free.
        if max_slots % allocator.partitions:
            raise ValueError(
                f"max_slots={max_slots} must split evenly over "
                f"{allocator.partitions} pool partitions")
        self._slots_per_part = max_slots // allocator.partitions

    def partition_of_slot(self, slot: int) -> int:
        return slot // self._slots_per_part

    # -- intake --------------------------------------------------------------

    def bucket_for(self, prompt_len: int, extend: bool = False) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        if extend:
            # resumed requests re-prefill prompt + emitted tokens, which is
            # bounded by prompt + max_new — power-of-two extents keep the
            # extra compile set small
            return 1 << max(prompt_len - 1, 1).bit_length()
        raise ValueError(f"prompt length {prompt_len} exceeds the largest "
                         f"prefill bucket {self.buckets[-1]}")

    def lifetime_blocks(self, req: Request) -> int:
        """Pages the request can ever touch (prompt rows + max_new-1
        decoded K/V rows; the final sampled token is never fed back).
        Reserved up front under ``reserve``; under ``preempt`` it is only
        the submit-time feasibility bound (a solo request must fit the
        pool, or no amount of preemption could finish it)."""
        n = blocks_for(req.prompt_len + max(req.max_new_tokens - 1, 0),
                       self.block_size)
        if n > self.max_blocks_per_slot:
            raise ValueError(
                f"request needs {n} pages > max_blocks_per_slot="
                f"{self.max_blocks_per_slot} (prompt {req.prompt_len} + "
                f"max_new {req.max_new_tokens})")
        return n

    def initial_blocks(self, req: Request) -> int:
        """Pages needed at (re-)admission: full lifetime under ``reserve``;
        just the prefill rows under ``preempt`` (fresh: the prompt; resume:
        prompt + all emitted tokens but the last, which the decode step
        feeds back and writes via `ensure_pages`)."""
        if self.policy == "reserve":
            return self.lifetime_blocks(req)
        rows = req.prompt_len + max(len(req.out_tokens) - 1, 0)
        return blocks_for(rows, self.block_size)

    def submit(self, req: Request) -> Request:
        req.rid = next(self._rid)
        req.t_submit = time.time()
        self.bucket_for(req.prompt_len)       # validate early
        need = self.lifetime_blocks(req)
        if need > self.allocator.partition_blocks:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.allocator.partition_blocks} per partition — it "
                "could never be admitted")
        self.queue.append(req)
        return req

    def resubmit(self, req: Request, rid: int) -> Request:
        """Crash-replay intake: re-queue a journaled in-flight request
        under its *original* rid (admission precedence and journal
        identity are keyed on it). The rid counter must already be
        advanced past every journaled rid (`advance_rids`)."""
        req.rid = rid
        req.t_submit = time.time()
        self.bucket_for(req.prompt_len)
        if self.lifetime_blocks(req) > self.allocator.partition_blocks:
            raise ValueError("replayed request no longer fits the pool")
        self.queue.append(req)
        return req

    def advance_rids(self, past: int) -> None:
        self._rid = itertools.count(past + 1)

    # -- admission -----------------------------------------------------------

    def _head(self) -> Optional[Request]:
        return min(self.queue, key=_order_key) if self.queue else None

    def _pick_victim(self, part: Optional[int] = None) -> Optional[Request]:
        """The least-important running request: largest (priority, rid).
        With `part` set, only requests whose slot lives in that pool
        partition qualify — reclaiming pages a different device shard
        owns could never satisfy this allocation."""
        pool = [r for r in self.running.values()
                if part is None or self.partition_of_slot(r.slot) == part]
        return max(pool, key=_order_key) if pool else None

    def _slot_index_for(self, need: int) -> int:
        """Index into `_free_slots` of the slot to admit into: the pop-
        order (last) slot unless another free slot's partition can already
        satisfy the page allocation. Single-partition pools always take
        the last slot — identical to the pre-partition behavior."""
        for i in range(len(self._free_slots) - 1, -1, -1):
            part = self.partition_of_slot(self._free_slots[i])
            if self.allocator.num_free_in(part) >= need:
                return i
        return len(self._free_slots) - 1

    def preempt(self, req: Request,
                on_preempt: Optional[Callable[[Request], None]] = None
                ) -> None:
        """Reclaim a running request's slot and pages; re-queue it for
        recompute-based resume. `on_preempt(req)` runs while `req.slot` is
        still set, so the runtime can clear its device-side slot state."""
        assert self.policy == "preempt", "no preemption under reserve"
        assert self.running.get(req.slot) is req, "preempt of non-running"
        del self.running[req.slot]
        self.allocator.free(req.blocks)
        req.blocks = []
        if on_preempt is not None:
            on_preempt(req)
        self._free_slots.append(req.slot)
        req.slot = -1
        req.state = "queued"
        req.n_preempts += 1
        self.preemptions += 1
        self.queue.append(req)

    def admit(self, on_preempt: Optional[Callable[[Request], None]] = None
              ) -> List[Request]:
        """Admit queued requests in (priority, rid) order while a slot +
        pages are available. The head of the order blocks later requests —
        no bypass, so arrival order is preserved within a priority class.
        Under ``preempt``, a head that is strictly more urgent than the
        current victim candidate reclaims that victim's slot/pages."""
        admitted = []
        while self.queue:
            req = self._head()
            need = self.initial_blocks(req)
            while True:
                if self._free_slots:
                    idx = self._slot_index_for(need)
                    part = self.partition_of_slot(self._free_slots[idx])
                    if self.allocator.num_free_in(part) >= need:
                        break
                else:
                    part = None      # need a slot first: any victim works
                victim = self._pick_victim(part)
                if (self.policy != "preempt" or victim is None
                        or _order_key(victim) <= _order_key(req)):
                    break
                self.preempt(victim, on_preempt)
            if not self._free_slots:
                break
            idx = self._slot_index_for(need)
            part = self.partition_of_slot(self._free_slots[idx])
            blocks = self.allocator.alloc(need, part)
            if blocks is None:       # pool exhausted: backpressure
                break
            self.queue.remove(req)
            req.blocks = blocks
            req.slot = self._free_slots.pop(idx)
            req.state = "running"
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    # -- decode-time page growth ---------------------------------------------

    def ensure_pages(self, req: Request, total_blocks: int,
                     on_preempt: Optional[Callable[[Request], None]] = None
                     ) -> bool:
        """Grow `req.blocks` to `total_blocks` pages before a decode step
        writes into them. Under ``reserve`` the pages were all allocated at
        admission. Under ``preempt``, exhaustion preempts victims until the
        allocation fits; if `req` itself is the victim (it is the least
        important running request) it is preempted and False is returned —
        the caller must drop it from the step."""
        if total_blocks > self.max_blocks_per_slot:
            raise ValueError(f"request {req.rid} grew past "
                             f"max_blocks_per_slot={self.max_blocks_per_slot}")
        part = self.partition_of_slot(req.slot)
        while len(req.blocks) < total_blocks:
            got = self.allocator.alloc(total_blocks - len(req.blocks), part)
            if got is not None:
                req.blocks.extend(got)
                return True
            if self.policy != "preempt":
                raise RuntimeError(
                    f"page pool exhausted growing request {req.rid} under "
                    "reserve policy — lifetime reservation should have "
                    "covered this (allocator accounting bug)")
            victim = self._pick_victim(part)
            if victim is None or victim is req:
                # req is the least-important running request (or an
                # injected alloc fault fired with nothing to reclaim):
                # preempt req itself; it re-queues and resumes later.
                if self.running.get(req.slot) is req:
                    self.preempt(req, on_preempt)
                return False
            self.preempt(victim, on_preempt)
        return True

    def release(self, req: Request) -> None:
        """Return a finished request's slot and pages to the pool."""
        assert self.running.get(req.slot) is req, "release of non-running"
        del self.running[req.slot]
        self.allocator.free(req.blocks)
        req.blocks = []
        self._free_slots.append(req.slot)
        req.slot = -1
        req.state = "done"
        req.t_done = time.time()
        self.completed.append(req)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running
