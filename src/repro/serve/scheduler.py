"""FCFS continuous-batching scheduler: admission, bucketing, backpressure.

Host-side policy only — no device arrays. The runtime asks the scheduler
which queued requests can start *now*; a request is admissible when a
decode slot is free AND the block allocator can reserve every page the
request will ever need (prompt + max_new tokens). Reserving the full
lifetime up front keeps the system deadlock-free without preemption: an
admitted request always runs to completion. When the pool is exhausted the
queue simply waits (cache-exhaustion backpressure) and drains FCFS as
completions free pages.

Prompts are padded to a small static set of bucket lengths so the jitted
prefill closures recompile at most once per bucket (right-padding: causal
attention makes the prefix K/V and the last-prompt-token logits exact; pad
rows are never copied into the paged pool).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.kv_cache import BlockAllocator, blocks_for

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512)


@dataclasses.dataclass
class Request:
    """A generation request and its full lifecycle record (absorbs the old
    serve/engine.py Request, whose out_tokens were never written).

    `stop_tokens` terminates generation early: the stop token itself is
    emitted (it closes the stream) and the request retires on the same
    step — its slot and every reserved page return to the pool
    immediately, so EOS-heavy traffic frees KV memory long before
    max_new_tokens. `finish_reason` records which bound fired."""
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    stream_cb: Optional[Callable[["Request", int], None]] = None
    # filled by scheduler/runtime
    rid: int = -1
    state: str = "queued"               # queued | running | done
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    blocks: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    itl: List[float] = dataclasses.field(default_factory=list)
    finish_reason: str = ""             # "stop_token" | "length"

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    def emit(self, token: int, now: float) -> None:
        if self.out_tokens:
            self.itl.append(now - self._t_last)
        else:
            self.t_first_token = now
        self._t_last = now
        self.out_tokens.append(int(token))
        if self.stream_cb is not None:
            self.stream_cb(self, int(token))

    def finished(self) -> bool:
        """Stop-token or length bound reached; sets finish_reason. The
        lifetime page reservation is unchanged — stopping early only
        *frees* pages sooner, so admission stays deadlock-free."""
        if self.out_tokens and self.out_tokens[-1] in self.stop_tokens:
            self.finish_reason = "stop_token"
            return True
        if len(self.out_tokens) >= self.max_new_tokens:
            self.finish_reason = "length"
            return True
        return False


class Scheduler:
    """FCFS queue + slot table + page accounting over a BlockAllocator."""

    def __init__(self, max_slots: int, allocator: BlockAllocator,
                 buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                 block_size: int = 16,
                 max_blocks_per_slot: Optional[int] = None):
        self.max_slots = max_slots
        self.allocator = allocator
        self.buckets = tuple(sorted(buckets))
        self.block_size = block_size
        self.max_blocks_per_slot = (
            max_blocks_per_slot
            if max_blocks_per_slot is not None
            else blocks_for(self.buckets[-1] + 64, block_size))
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}     # slot -> request
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._rid = itertools.count()
        self.completed: List[Request] = []

    # -- intake --------------------------------------------------------------

    def bucket_for(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(f"prompt length {prompt_len} exceeds the largest "
                         f"prefill bucket {self.buckets[-1]}")

    def lifetime_blocks(self, req: Request) -> int:
        """Pages reserved at admission: every position the request can
        ever write (prompt rows + max_new-1 decoded K/V rows; the final
        sampled token is never fed back)."""
        n = blocks_for(req.prompt_len + max(req.max_new_tokens - 1, 0),
                       self.block_size)
        if n > self.max_blocks_per_slot:
            raise ValueError(
                f"request needs {n} pages > max_blocks_per_slot="
                f"{self.max_blocks_per_slot} (prompt {req.prompt_len} + "
                f"max_new {req.max_new_tokens})")
        return n

    def submit(self, req: Request) -> Request:
        req.rid = next(self._rid)
        req.t_submit = time.time()
        self.bucket_for(req.prompt_len)       # validate early
        need = self.lifetime_blocks(req)
        if need > self.allocator.num_blocks:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.allocator.num_blocks} — it could never be admitted")
        self.queue.append(req)
        return req

    # -- admission -----------------------------------------------------------

    def admit(self) -> List[Request]:
        """Admit queued requests FCFS while a slot + pages are available.
        Strict FCFS: the head of the queue blocks later (smaller) requests
        — no head-of-line bypass, so admission order is arrival order."""
        admitted = []
        while self.queue and self._free_slots:
            req = self.queue[0]
            blocks = self.allocator.alloc(self.lifetime_blocks(req))
            if blocks is None:       # pool exhausted: backpressure
                break
            self.queue.popleft()
            req.blocks = blocks
            req.slot = self._free_slots.pop()
            req.state = "running"
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def release(self, req: Request) -> None:
        """Return a finished request's slot and pages to the pool."""
        assert self.running.get(req.slot) is req, "release of non-running"
        del self.running[req.slot]
        self.allocator.free(req.blocks)
        req.blocks = []
        self._free_slots.append(req.slot)
        req.slot = -1
        req.state = "done"
        req.t_done = time.time()
        self.completed.append(req)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running
