"""Static-slot batched engine: prefill + decode over equal-length prompts.

Kept as the equivalence baseline for the continuous-batching `Runtime`
(serve/runtime.py): dense per-slot `max_len` KV cache, one shared scalar
position, equal-length right-aligned prompts. The request dataclass lives
in serve/scheduler.py (`Request`) and is shared by both.

Works with dense params or COMQ-quantized params: pass the materialized
tree, or a packed QT-leaf tree (`core/apply.serving_params`) — QT leaves
dequantize (or quant_matmul-fuse) per layer inside the compiled step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_cache, prefill
from repro.serve.sampler import sample


class Engine:
    def __init__(self, params, cfg, plan, *, max_len: int = 512,
                 rng_seed: int = 0):
        self.params = params
        self.cfg = cfg
        plan = plan.replace(prefill_cache_len=max_len)
        self.plan = plan
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(rng_seed)

        self._prefill = jax.jit(
            lambda p, t, ve=None: prefill(p, cfg, plan, t, vision_embeds=ve))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, plan, c, t, pos))

    def generate_batch(self, prompts: np.ndarray, *, max_new_tokens: int = 32,
                       temperature: float = 0.0,
                       vision_embeds=None) -> np.ndarray:
        """prompts: (B, T) int32 (right-aligned equal length for simplicity).
        Returns (B, max_new_tokens)."""
        B, T = prompts.shape
        tokens = jnp.asarray(prompts, jnp.int32)
        if vision_embeds is not None:
            logits, cache = self._prefill(self.params, tokens, vision_embeds)
        else:
            logits, cache = self._prefill(self.params, tokens)
        # sample into a device-side buffer: the decode loop only *dispatches*
        # (no per-token host sync); tokens transfer once at the end
        out = jnp.zeros((B, max_new_tokens), jnp.int32)
        pos = T
        for i in range(max_new_tokens):
            self.rng, k = jax.random.split(self.rng)
            nxt = sample(logits, k, temperature=temperature)
            out = out.at[:, i].set(nxt)
            logits, cache = self._decode(self.params, cache, nxt[:, None],
                                         jnp.int32(pos))
            pos += 1
        # comq: allow(host-sync) end-of-batch: tokens leave the device once
        return np.asarray(jax.device_get(out))
