"""Continuous-batching serving runtime over the paged KV cache.

The runtime ties together:

* `serve/scheduler.py` — FCFS admission, prefill buckets, backpressure;
* `serve/kv_cache.py` — the paged pool + block tables + host allocator;
* `models/model.py::decode_step_paged` — one jitted decode program with
  per-slot positions, so slots at different sequence lengths (mixed
  lengths, staggered arrivals) share every decode step;
* `serve/sampler.py::sample_batch` — per-slot sampling settings as arrays.

Compile surface is bounded and static: one prefill program per bucket
length, one scatter program per prefill-cache extent, one decode program,
one sampler program. The pool is donated through prefill-writes and decode
steps so XLA updates pages in place.

Params may be dense, materialized, or a *packed* QT-leaf tree
(`core/apply.serving_params`) — QT projections stay packed in HBM and
route through the dequant-fused quant_matmul inside the decode scan; no
`materialize` call anywhere on the serve path.

Host/device traffic per decode step: one (B,) token fetch (required to
stream tokens and retire finished requests) and the small int32 control
arrays (tokens, positions, block tables) going down.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step_paged, forward
from repro.serve.kv_cache import (BlockAllocator, init_paged_cache,
                                  paged_cache_bytes, write_prefill)
from repro.serve.sampler import sample_batch
from repro.serve.scheduler import DEFAULT_BUCKETS, Request, Scheduler


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    block_size: int = 16
    num_blocks: int = 64
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    max_blocks_per_slot: Optional[int] = None
    rng_seed: int = 0


class Runtime:
    """Continuous-batching runtime: submit() requests, run() to drain."""

    def __init__(self, params, cfg, plan, serve_cfg: ServeConfig = None):
        if cfg.attn_free or cfg.parallel_ssm_heads or cfg.family == "vlm":
            raise NotImplementedError(
                f"paged runtime does not cover family={cfg.family!r} / "
                "attention-free / parallel-ssm archs — use serve.Engine")
        if plan.cache_quant:
            raise NotImplementedError(
                "int8 KV quantization is dense-cache only for now "
                "(ROADMAP open item); use serve.Engine")
        self.params = params
        self.cfg = cfg
        self.plan = plan
        sc = serve_cfg or ServeConfig()
        self.serve_cfg = sc
        self.rng = jax.random.PRNGKey(sc.rng_seed)

        self.allocator = BlockAllocator(sc.num_blocks)
        self.scheduler = Scheduler(sc.max_slots, self.allocator,
                                   buckets=sc.buckets,
                                   block_size=sc.block_size,
                                   max_blocks_per_slot=sc.max_blocks_per_slot)
        self.maxb = self.scheduler.max_blocks_per_slot
        self.pool = init_paged_cache(cfg, plan, sc.num_blocks, sc.block_size)

        B = sc.max_slots
        # host-side decode state, one row per slot
        self._bt = np.zeros((B, self.maxb), np.int32)
        self._pos = np.full((B,), -1, np.int32)
        self._tok = np.zeros((B,), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._topp = np.zeros((B,), np.float32)

        self._prefill_cache: Dict[int, object] = {}
        self._write_cache: Dict[int, object] = {}
        self._decode = jax.jit(
            lambda p, pool, bt, t, pos: decode_step_paged(
                p, cfg, plan, pool, bt, t, pos),
            donate_argnums=(1,))
        self._sample = jax.jit(
            lambda lg, k, t, tk, tp: sample_batch(
                lg, k, temperature=t, top_k=tk, top_p=tp))
        # all-greedy fast path: skips the (B, V) sort/softmax machinery
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        # run() metrics
        self.steps = 0
        self.decode_seconds = 0.0

    # -- jitted closures (bounded: one per bucket / cache extent) ------------

    def _prefill_fn(self, bucket: int):
        fn = self._prefill_cache.get(bucket)
        if fn is None:
            cfg = self.cfg
            # cache capacity >= bucket even for SWA archs: the right-pad
            # rows must not ring-evict real in-window rows (the scatter
            # drops the pads afterwards; attention masks by window)
            plan = self.plan.replace(prefill_cache_len=bucket)

            def prefill_full(p, t):
                logits, _, cache = forward(p, cfg, plan, t, make_cache=True)
                return logits, cache

            fn = jax.jit(prefill_full)
            self._prefill_cache[bucket] = fn
        return fn

    def _write_fn(self, cache_len: int):
        fn = self._write_cache.get(cache_len)
        if fn is None:
            def write(pool, k_seq, v_seq, kv_pos, tlen, table_row):
                # exclude right-pad rows: only positions < true length
                pos_row = jnp.where((kv_pos >= 0) & (kv_pos < tlen),
                                    kv_pos, -1)
                return write_prefill(pool, k_seq, v_seq, pos_row, table_row)
            fn = jax.jit(write, donate_argnums=(0,))
            self._write_cache[cache_len] = fn
        return fn

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 32,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
               stop_tokens=(), stream_cb=None) -> Request:
        req = Request(prompt=np.asarray(prompt, np.int32).reshape(-1),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      top_k=top_k, top_p=top_p,
                      stop_tokens=tuple(int(t) for t in stop_tokens),
                      stream_cb=stream_cb)
        return self.scheduler.submit(req)

    # -- serving loop --------------------------------------------------------

    def _admit_one(self, req: Request) -> None:
        sched = self.scheduler
        bucket = sched.bucket_for(req.prompt_len)
        tlen = req.prompt_len
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :tlen] = req.prompt
        logits, cache = self._prefill_fn(bucket)(self.params,
                                                 jnp.asarray(tokens))
        kv = cache["kv"]
        table_row = np.zeros((self.maxb,), np.int32)
        table_row[:len(req.blocks)] = req.blocks
        table_row_j = jnp.asarray(table_row)
        self.pool = self._write_fn(int(kv.k.shape[2]))(
            self.pool, kv.k[:, 0], kv.v[:, 0], kv.pos[0, 0],
            jnp.int32(tlen), table_row_j)
        # first token comes straight from the prefill logits (TTFT token)
        if req.temperature <= 0.0:
            first = self._argmax(logits[:, tlen - 1])
        else:
            self.rng, key = jax.random.split(self.rng)
            first = self._sample(
                logits[:, tlen - 1],
                key,
                jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.top_k], jnp.int32),
                jnp.asarray([req.top_p], jnp.float32))
        first = int(np.asarray(first)[0])
        req.emit(first, time.time())
        s = req.slot
        self._bt[s] = table_row
        self._pos[s] = tlen          # next decode writes the first token here
        self._tok[s] = first
        self._temp[s] = req.temperature
        self._topk[s] = req.top_k
        self._topp[s] = req.top_p
        if req.finished():       # max_new == 1, or the TTFT token is a stop
            self._retire(req)

    def _retire(self, req: Request) -> None:
        s = req.slot
        self.scheduler.release(req)
        self._pos[s] = -1
        self._bt[s] = 0
        self._tok[s] = 0

    def step(self) -> int:
        """Admit what fits, then run one decode step for all active slots.
        Returns the number of tokens emitted (prefill first-tokens
        included)."""
        emitted = 0
        for req in self.scheduler.admit():
            self._admit_one(req)
            emitted += 1          # the prefill-sampled first token
        running = dict(self.scheduler.running)
        if not running:
            return emitted
        t0 = time.time()
        logits, self.pool = self._decode(
            self.params, self.pool, jnp.asarray(self._bt),
            jnp.asarray(self._tok[:, None]), jnp.asarray(self._pos))
        if (self._temp > 0.0).any():
            self.rng, key = jax.random.split(self.rng)
            toks = np.asarray(self._sample(
                logits, key, jnp.asarray(self._temp),
                jnp.asarray(self._topk), jnp.asarray(self._topp)))
        else:
            toks = np.asarray(self._argmax(logits))
        now = time.time()
        self.steps += 1
        self.decode_seconds += now - t0
        for s, req in running.items():
            req.emit(int(toks[s]), now)
            emitted += 1
            self._pos[s] += 1
            self._tok[s] = int(toks[s])
            # stop-token or length: slot + pages free on this very step, so
            # queued requests can admit next step. Tokens after the stop
            # are never emitted — metrics count what was actually streamed.
            if req.finished():
                self._retire(req)
        return emitted

    def run(self) -> Dict[str, object]:
        """Drain the queue; returns aggregate + per-request metrics for
        *this* call (tokens emitted and requests completed while run()
        was draining — pre-run step() calls and earlier run()s are not
        re-counted, so wall-clock rates stay honest)."""
        t0 = time.time()
        done_before = len(self.scheduler.completed)
        steps_before = self.steps
        new_tokens = 0
        while not self.scheduler.idle:
            new_tokens += self.step()
        wall = time.time() - t0
        done = self.scheduler.completed[done_before:]
        itls = [dt for r in done for dt in r.itl]
        return {
            "requests": len(done),
            "finish_reasons": [r.finish_reason for r in done],
            "new_tokens": new_tokens,
            "wall_seconds": wall,
            "tok_per_s": new_tokens / max(wall, 1e-9),
            "ttft_s": [r.ttft for r in done],
            "itl_mean_s": float(np.mean(itls)) if itls else 0.0,
            "decode_steps": self.steps - steps_before,
            "cache_blocks": self.allocator.num_blocks,
            "cache_peak_blocks": self.allocator.peak_in_use,
            "cache_peak_occupancy": (self.allocator.peak_in_use
                                     / self.allocator.num_blocks),
            "cache_bytes": paged_cache_bytes(
                self.cfg, self.plan, self.serve_cfg.num_blocks,
                self.serve_cfg.block_size),
        }

    # -- convenience ---------------------------------------------------------

    def generate(self, prompts, max_new_tokens: int = 32, **kw
                 ) -> List[np.ndarray]:
        """Submit `prompts` (list of 1-D int arrays) FCFS, drain, and return
        each request's tokens in submission order."""
        reqs = [self.submit(p, max_new_tokens=max_new_tokens, **kw)
                for p in prompts]
        self.run()
        return [np.asarray(r.out_tokens, np.int32) for r in reqs]
