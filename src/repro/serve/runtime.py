"""Continuous-batching serving runtime over the paged KV cache.

The runtime ties together:

* `serve/scheduler.py` — priority admission, prefill buckets, incremental
  page allocation + preemption-by-page-reclaim (or the legacy
  full-lifetime reservation under ``policy="reserve"``);
* `serve/kv_cache.py` — the paged pool + block tables + host allocator;
* `models/model.py::decode_step_paged` — one jitted decode program with
  per-slot positions, so slots at different sequence lengths (mixed
  lengths, staggered arrivals) share every decode step;
* `serve/sampler.py::sample_batch_seeded` — per-slot sampling settings as
  arrays, with every draw a pure function of (request seed, token index);
* `ft/journal.py` — optional crash-replay request journal: submits,
  first tokens and retirements are fsync-gated, and `recover_runtime`
  rebuilds the queue after a process death, replaying in-flight requests
  token-identically (bit-deterministic decode + seeded sampling);
* `ft/inject.py` — optional deterministic fault injection (page-alloc
  failure, decode-step exception, callback error, simulated kill) for the
  invariant tests.

Compile surface is bounded and static: one prefill program per bucket
length (resume extents round up to powers of two), one scatter program per
prefill-cache extent, one decode program, one sampler program. The pool is
donated through prefill-writes and decode steps so XLA updates pages in
place.

Preemption is recompute-based: the victim's pages are freed and it
re-queues; on re-admission the runtime re-prefills prompt + all emitted
tokens but the last, then feeds the last emitted token through the normal
decode step — every resumed token is produced by the same decode program
as an uninterrupted run, which is what makes preempt/resume
token-identity hold (and testable) rather than merely approximate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.retrace import guard_jit
from repro.ft.inject import InjectedFault, SimulatedKill  # noqa: F401
from repro.ft.journal import Journal
from repro.models.model import decode_step_paged, forward
from repro.obs.metrics import NULL_METRICS, Histogram
from repro.obs.trace import NULL_TRACER
from repro.serve.kv_cache import (BlockAllocator, blocks_for,
                                  init_paged_cache, paged_cache_bytes,
                                  write_prefill)
from repro.serve.sampler import sample_batch_seeded
from repro.serve.scheduler import DEFAULT_BUCKETS, Request, Scheduler


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    block_size: int = 16
    num_blocks: int = 64
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    max_blocks_per_slot: Optional[int] = None
    rng_seed: int = 0
    policy: str = "preempt"          # "preempt" | "reserve" (legacy A/B)


class Runtime:
    """Continuous-batching runtime: submit() requests, run() to drain."""

    def __init__(self, params, cfg, plan, serve_cfg: ServeConfig = None,
                 journal: Optional[Journal] = None, injector=None,
                 tracer=None, metrics=None, mesh=None):
        if cfg.attn_free or cfg.parallel_ssm_heads or cfg.family == "vlm":
            raise NotImplementedError(
                f"paged runtime does not cover family={cfg.family!r} / "
                "attention-free / parallel-ssm archs — use serve.Engine")
        # Quantized pages (DESIGN.md §11): a `kv=` policy rider on the
        # paged path means integer page codes + per-(layer, page, kv_head)
        # scales, not the dense engine's per-slot int8 cache — so the plan
        # the prefill programs see must produce bf16 rows (cache_quant
        # off) for write_prefill to quantize page-wise on the way in.
        kv_bits = int(getattr(plan, "kv_bits", 0) or 0)
        if plan.cache_quant and kv_bits == 0:
            kv_bits = 8
        if kv_bits not in (0, 4, 8):
            raise ValueError(f"kv_bits must be 0, 4 or 8, got {kv_bits}")
        if kv_bits:
            plan = plan.replace(cache_quant=False, kv_bits=kv_bits)
        self.kv_bits = kv_bits
        self.params = params
        self.cfg = cfg
        self.plan = plan
        sc = serve_cfg or ServeConfig()
        self.serve_cfg = sc
        self.mesh = mesh
        if mesh is not None:
            from repro.dist.sharding import tp_size
            tp = tp_size(mesh)
        else:
            tp = 1
        self._tp = tp
        self.journal = journal
        self.injector = injector
        # observability (DESIGN.md §10): null singletons when disabled, so
        # every hook below is an unconditional call that costs nothing.
        # Instrument handles are resolved once here — hot-zone call sites
        # never do registry lookups, only a float add / list append on
        # values that are already host scalars (sync-free rule).
        self.tracer = tracer or NULL_TRACER
        self.metrics = metrics or NULL_METRICS
        self._m_ttft = self.metrics.histogram("serve.ttft_seconds")
        self._m_itl = self.metrics.histogram("serve.itl_seconds")
        self._m_tokens = self.metrics.counter("serve.tokens_emitted")
        self._m_retired = self.metrics.counter("serve.requests_retired")
        self._m_preempt = self.metrics.counter("serve.preemptions")
        self._m_admits = self.metrics.counter("serve.admits")
        self._m_resumes = self.metrics.counter("serve.resumes")
        self._m_free = self.metrics.gauge("serve.pool_free_blocks")
        self._m_occ = self.metrics.gauge("serve.pool_live_occupancy")
        self._m_pool_bytes = self.metrics.gauge("serve.pool_kv_bytes")

        fail_hook = None
        if injector is not None:
            fail_hook = lambda: injector.fire("page_alloc")  # noqa: E731
        self.allocator = BlockAllocator(sc.num_blocks, fail_hook=fail_hook,
                                        partitions=tp)
        self.scheduler = Scheduler(sc.max_slots, self.allocator,
                                   buckets=sc.buckets,
                                   block_size=sc.block_size,
                                   max_blocks_per_slot=sc.max_blocks_per_slot,
                                   policy=sc.policy)
        self.maxb = self.scheduler.max_blocks_per_slot
        self.pool = init_paged_cache(cfg, plan, sc.num_blocks, sc.block_size)
        # bytes per live page (codes + its share of the scale rows) — the
        # pool-bytes gauge below is a host multiply, never a device sync
        self._page_bytes = paged_cache_bytes(
            cfg, plan, sc.num_blocks, sc.block_size) // sc.num_blocks
        if mesh is not None:
            from repro.dist.sharding import named, paged_runtime_specs
            self._specs = paged_runtime_specs(self.pool, mesh, sc.max_slots,
                                              sc.num_blocks)
            # pages live pre-sharded over "model" so the donated decode
            # pool never reshards (slot s's pages sit on s's partition)
            self.pool = jax.device_put(self.pool,
                                       named(mesh, self._specs["pool"]))

        B = sc.max_slots
        # host-side decode state, one row per slot
        self._bt = np.zeros((B, self.maxb), np.int32)
        self._pos = np.full((B,), -1, np.int32)
        self._tok = np.zeros((B,), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._topp = np.zeros((B,), np.float32)
        self._seed = np.zeros((B,), np.uint32)   # per-request sampling seed
        self._count = np.zeros((B,), np.int32)   # tokens emitted so far

        self._prefill_cache: Dict[int, object] = {}
        self._write_cache: Dict[int, object] = {}
        # retrace budgets (analysis/retrace.py): the decode program compiles
        # exactly once per Runtime — a second trace means shape-unstable
        # decode state and would serialize every step behind a compile
        if mesh is None:
            step_fn = lambda p, pool, bt, t, pos: decode_step_paged(  # noqa: E731
                p, cfg, plan, pool, bt, t, pos)
        else:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            nbl = sc.num_blocks // tp
            sp = self._specs

            def local_step(p, pool, bt, t, pos):
                # block tables carry *global* page ids; a shard's slots
                # only ever hold pages it owns (partitioned allocator), so
                # localizing is a subtract — the clamp only touches the
                # padding entries past a slot's live blocks, which the
                # length mask already hides from attention
                me = jax.lax.axis_index("model")
                btl = jnp.maximum(bt - me * nbl, 0)
                return decode_step_paged(p, cfg, plan, pool, btl, t, pos)

            step_fn = shard_map(
                local_step, mesh=mesh,
                in_specs=(P(), sp["pool"], sp["bt"], sp["tok"], sp["pos"]),
                out_specs=(sp["logits"], sp["pool"]),
                check_rep=False)
        self._decode = guard_jit(
            step_fn, name="serve.decode_step", max_traces=1,
            donate_argnums=(1,))
        self._sample = guard_jit(
            lambda lg, sd, ct, t, tk, tp: sample_batch_seeded(
                lg, sd, ct, temperature=t, top_k=tk, top_p=tp),
            name="serve.sample", per_signature=True)
        # all-greedy fast path: skips the (B, V) sort/softmax machinery
        self._argmax = guard_jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32),
            name="serve.argmax", per_signature=True)
        # device-resident block tables, re-uploaded only on change: steady
        # greedy decode keeps the table constant, so the per-step
        # host->device copy is pure overhead the moment tables settle
        self._bt_dev = None
        self._bt_dirty = True
        self._any_sampling = False   # any live slot with temperature > 0
        # run() metrics
        self.steps = 0
        self.decode_seconds = 0.0
        self._occ_sum = 0.0          # live-token occupancy, summed per step
        self._occ_steps = 0

    # -- jitted closures (bounded: one per bucket / cache extent) ------------

    def _prefill_fn(self, bucket: int):
        fn = self._prefill_cache.get(bucket)
        if fn is None:
            cfg = self.cfg
            # cache capacity >= bucket even for SWA archs: the right-pad
            # rows must not ring-evict real in-window rows (the scatter
            # drops the pads afterwards; attention masks by window)
            plan = self.plan.replace(prefill_cache_len=bucket)

            def prefill_full(p, t):
                logits, _, cache = forward(p, cfg, plan, t, make_cache=True)
                return logits, cache

            fn = guard_jit(prefill_full, name=f"serve.prefill[{bucket}]",
                           max_traces=1)
            self._prefill_cache[bucket] = fn
        return fn

    def _write_fn(self, cache_len: int):
        fn = self._write_cache.get(cache_len)
        if fn is None:
            kv_bits = self.kv_bits

            def write(pool, k_seq, v_seq, kv_pos, tlen, table_row):
                # exclude right-pad rows: only positions < true length
                pos_row = jnp.where((kv_pos >= 0) & (kv_pos < tlen),
                                    kv_pos, -1)
                return write_prefill(pool, k_seq, v_seq, pos_row, table_row,
                                     kv_bits=kv_bits)

            if self.mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                nbl = self.serve_cfg.num_blocks // self._tp
                sp = self._specs

                def write_sharded(pool, k_seq, v_seq, kv_pos, tlen,
                                  table_row):
                    # the prefill rows are replicated; every shard runs
                    # the same scatter with unowned pages remapped to the
                    # local out-of-range sentinel, so only the owner's
                    # pages take the rows (write_prefill drops OOB)
                    me = jax.lax.axis_index("model")
                    owned = (table_row // nbl) == me
                    tbl = jnp.where(owned, table_row - me * nbl, nbl)
                    return write(pool, k_seq, v_seq, kv_pos, tlen, tbl)

                inner = shard_map(
                    write_sharded, mesh=self.mesh,
                    in_specs=(sp["pool"], P(), P(), P(), P(), P()),
                    out_specs=sp["pool"], check_rep=False)
            else:
                inner = write
            fn = guard_jit(inner, name=f"serve.prefill_write[{cache_len}]",
                           max_traces=1, donate_argnums=(0,))
            self._write_cache[cache_len] = fn
        return fn

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 32,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
               stop_tokens=(), stream_cb=None, priority: int = 0,
               seed: Optional[int] = None) -> Request:
        req = Request(prompt=np.asarray(prompt, np.int32).reshape(-1),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      top_k=top_k, top_p=top_p,
                      stop_tokens=tuple(int(t) for t in stop_tokens),
                      stream_cb=stream_cb, priority=priority, seed=seed)
        self.scheduler.submit(req)
        if req.seed is None:
            # deterministic per-request default, journaled for replay
            req.seed = (self.serve_cfg.rng_seed * 1_000_003
                        + req.rid) & 0x7FFFFFFF
        if self.journal is not None:
            self.journal.record_submit(req)
        self.tracer.request_event("submit", req.rid,
                                  prompt_len=int(req.prompt.shape[0]),
                                  max_new_tokens=int(max_new_tokens),
                                  priority=int(priority))
        return req

    # -- serving loop --------------------------------------------------------

    def _emit(self, req: Request, token: int, now: float) -> None:
        inj = self.injector
        if inj is not None and req.stream_cb is not None:
            orig = req.stream_cb

            def guarded(r, t):
                if inj.fire("callback"):
                    raise InjectedFault("injected stream-callback failure")
                orig(r, t)

            req.stream_cb = guarded
            try:
                req.emit(token, now)    # cb errors contained per-request
            finally:
                req.stream_cb = orig
        else:
            req.emit(token, now)
        # token index is its position in the output stream; crash-replay
        # re-delivers the same prefix, so timelines dedup by (rid, i)
        self.tracer.token_event(req.rid, len(req.out_tokens) - 1, token,
                                now * 1e6)
        self._m_tokens.inc()

    def _clear_slot(self, req: Request) -> None:
        """Scheduler preemption callback: wipe the victim's device-side
        slot state while `req.slot` is still assigned."""
        s = req.slot
        self._pos[s] = -1
        self._bt[s] = 0
        self._tok[s] = 0
        self._temp[s] = 0.0
        self._topk[s] = 0
        self._topp[s] = 0.0
        self._seed[s] = 0
        self._count[s] = 0
        self._bt_dirty = True
        self._any_sampling = bool((self._temp > 0.0).any())
        if self.journal is not None:
            self.journal.record_preempt(req)
        self.tracer.request_event("preempt", req.rid,
                                  n_preempts=int(req.n_preempts) + 1)
        self._m_preempt.inc()

    def _admit_one(self, req: Request) -> int:
        """Prefill + scatter for a newly (re-)admitted request. Fresh
        requests sample their first token from the prefill logits (TTFT)
        and return 1; resumed requests re-prefill prompt + emitted[:-1]
        and feed emitted[-1] through the next decode step — every resumed
        token then comes from the same decode program as an uninterrupted
        run (token-identity), and 0 new tokens are emitted here."""
        sched = self.scheduler
        resume = bool(req.out_tokens)
        if resume:
            tokens_in = np.concatenate(
                [req.prompt, np.asarray(req.out_tokens[:-1], np.int32)])
        else:
            tokens_in = req.prompt
        tlen = int(len(tokens_in))
        bucket = sched.bucket_for(tlen, extend=resume)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :tlen] = tokens_in
        logits, cache = self._prefill_fn(bucket)(self.params,
                                                 jnp.asarray(tokens))
        kv = cache["kv"]
        table_row = np.zeros((self.maxb,), np.int32)
        table_row[:len(req.blocks)] = req.blocks
        table_row_j = jnp.asarray(table_row)
        self.pool = self._write_fn(int(kv.k.shape[2]))(
            self.pool, kv.k[:, 0], kv.v[:, 0], kv.pos[0, 0],
            jnp.int32(tlen), table_row_j)
        s = req.slot
        self._bt[s] = table_row
        self._pos[s] = tlen          # next decode writes K/V here
        self._temp[s] = req.temperature
        self._topk[s] = req.top_k
        self._topp[s] = req.top_p
        self._seed[s] = np.uint32(req.seed or 0)
        self._bt_dirty = True
        self._any_sampling = bool((self._temp > 0.0).any())
        self.tracer.request_event("admit", req.rid, slot=int(s),
                                  resumed=resume, prefill_len=tlen)
        self._m_admits.inc()
        if resume:
            self._tok[s] = req.out_tokens[-1]
            self._count[s] = len(req.out_tokens)
            if self.journal is not None:
                self.journal.record_resume(req)
            self._m_resumes.inc()
            return 0
        # first token comes straight from the prefill logits (TTFT token)
        if req.temperature <= 0.0:
            first = self._argmax(logits[:, tlen - 1])
        else:
            first = self._sample(
                logits[:, tlen - 1],
                jnp.asarray([req.seed or 0], jnp.uint32),
                jnp.asarray([0], jnp.int32),
                jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.top_k], jnp.int32),
                jnp.asarray([req.top_p], jnp.float32))
        first = int(np.asarray(first)[0])  # comq: allow(host-sync) TTFT token must reach the stream callback
        self._emit(req, first, time.time())
        self._tok[s] = first
        self._count[s] = 1
        if self.journal is not None:
            self.journal.record_first_token(req, first)
        self.tracer.request_event("first_token", req.rid, token=first)
        self._m_ttft.observe(req.ttft)
        if req.finished():       # max_new == 1, or the TTFT token is a stop
            self._retire(req)
        return 1

    def _retire(self, req: Request) -> None:
        s = req.slot
        # the retire record is the source of truth for "delivered": it is
        # durable before the pages are reused, so a crash can re-stream a
        # request's tokens (at-least-once) but never lose or re-run a
        # retired request
        req.finished()               # ensure finish_reason is set
        if self.journal is not None:
            self.journal.record_retire(req)
        self.tracer.request_event("retire", req.rid,
                                  reason=req.finish_reason,
                                  new_tokens=len(req.out_tokens))
        self._m_retired.inc()
        for dt in req.itl:           # host floats collected by emit()
            self._m_itl.observe(dt)
        self.scheduler.release(req)
        self._pos[s] = -1
        self._bt[s] = 0
        self._tok[s] = 0
        self._count[s] = 0
        # clear sampling settings too: greedy rows of the seeded sampler
        # are bit-identical to the argmax fast path, so dropping back to
        # it when the last sampling request retires cannot change tokens
        self._temp[s] = 0.0
        self._topk[s] = 0
        self._topp[s] = 0.0
        self._bt_dirty = True
        self._any_sampling = bool((self._temp > 0.0).any())

    def step(self) -> int:
        """Admit what fits (possibly preempting lower-priority victims),
        grow pages for the rows this step writes (possibly preempting),
        then run one decode step for all active slots. Returns the number
        of tokens emitted (prefill first-tokens included)."""
        if self.injector is not None:
            self.injector.check("kill", SimulatedKill)
        emitted = 0
        for req in self.scheduler.admit(on_preempt=self._clear_slot):
            emitted += self._admit_one(req)
        bs = self.serve_cfg.block_size
        for s, req in sorted(self.scheduler.running.items()):
            if req.state != "running":      # preempted earlier this pass
                continue
            needed = int(self._pos[s]) // bs + 1
            self.scheduler.ensure_pages(req, needed,
                                        on_preempt=self._clear_slot)
        running = dict(self.scheduler.running)
        if not running:
            return emitted
        for s, req in running.items():
            row = np.asarray(req.blocks, np.int32)       # grown tables
            if not np.array_equal(self._bt[s, :len(row)], row):
                self._bt[s, :len(row)] = row
                self._bt_dirty = True
        if self.injector is not None:
            self.injector.check("decode_step")
        t0 = time.time()
        # block tables only cross to the device when they changed (admit,
        # retire, preempt, page growth) — steady decode re-uses the
        # device-resident copy instead of re-uploading (B, maxb) per step
        if self._bt_dirty or self._bt_dev is None:
            self._bt_dev = jnp.asarray(self._bt)
            self._bt_dirty = False
        # the span brackets dispatch + the token pull the loop needs
        # anyway — no extra syncs, and device=True annotates the XLA
        # timeline so profiler slices line up with this host span
        with self.tracer.span("decode_step", device=True,
                              step=self.steps, slots=len(running)):
            logits, self.pool = self._decode(
                self.params, self.pool, self._bt_dev,
                jnp.asarray(self._tok[:, None]), jnp.asarray(self._pos))
            if self._any_sampling:
                toks = np.asarray(self._sample(  # comq: allow(host-sync) decode loop needs the tokens
                    logits, jnp.asarray(self._seed), jnp.asarray(self._count),
                    jnp.asarray(self._temp), jnp.asarray(self._topk),
                    jnp.asarray(self._topp)))
            else:
                toks = np.asarray(self._argmax(logits))  # comq: allow(host-sync) decode loop needs the tokens
        now = time.time()
        self.steps += 1
        self.decode_seconds += now - t0
        for s, req in running.items():
            self._emit(req, int(toks[s]), now)
            emitted += 1
            self._pos[s] += 1
            self._tok[s] = int(toks[s])
            self._count[s] += 1
            # stop-token or length: slot + pages free on this very step, so
            # queued requests can admit next step. Tokens after the stop
            # are never emitted — metrics count what was actually streamed.
            if req.finished():
                self._retire(req)
        # live-token occupancy: pages actually holding written K/V rows —
        # under "reserve" this is what full-lifetime reservation caps
        live = sum(blocks_for(int(self._pos[s]), bs)
                   for s in range(self.serve_cfg.max_slots)
                   if self._pos[s] >= 0)
        self._occ_sum += live / self.allocator.num_blocks
        self._occ_steps += 1
        self._m_free.set(self.allocator.num_free)
        self._m_occ.set(live / self.allocator.num_blocks)
        self._m_pool_bytes.set(live * self._page_bytes)
        return emitted

    def run(self) -> Dict[str, object]:
        """Drain the queue; returns aggregate + per-request metrics for
        *this* call (tokens emitted and requests completed while run()
        was draining — pre-run step() calls and earlier run()s are not
        re-counted, so wall-clock rates stay honest)."""
        t0 = time.time()
        done_before = len(self.scheduler.completed)
        steps_before = self.steps
        occ_sum0, occ_n0 = self._occ_sum, self._occ_steps
        preempt0 = self.scheduler.preemptions
        new_tokens = 0
        with self.tracer.span("serve.run"):
            while not self.scheduler.idle:
                new_tokens += self.step()
        wall = time.time() - t0
        done = self.scheduler.completed[done_before:]
        occ_n = self._occ_steps - occ_n0
        # histogram over this run's ITLs: quantile() matches
        # np.percentile bit-for-bit (obs/metrics.py), so swapping the
        # ad-hoc percentile math for the histogram changed no numbers
        itl_hist = Histogram("serve.itl_seconds")
        for r in done:
            for dt in r.itl:
                itl_hist.observe(dt)
        return {
            "requests": len(done),
            "finish_reasons": [r.finish_reason for r in done],
            "new_tokens": new_tokens,
            "wall_seconds": wall,
            "tok_per_s": new_tokens / max(wall, 1e-9),
            "ttft_s": [r.ttft for r in done],
            "itl_mean_s": (itl_hist.sum / itl_hist.count
                           if itl_hist.count else 0.0),
            "itl_p50_s": itl_hist.quantile(0.5) if itl_hist.count else 0.0,
            "itl_p99_s": itl_hist.quantile(0.99) if itl_hist.count else 0.0,
            "decode_steps": self.steps - steps_before,
            "preemptions": self.scheduler.preemptions - preempt0,
            "cache_blocks": self.allocator.num_blocks,
            "cache_peak_blocks": self.allocator.peak_in_use,
            "cache_peak_occupancy": (self.allocator.peak_in_use
                                     / self.allocator.num_blocks),
            "mean_live_occupancy": ((self._occ_sum - occ_sum0) / occ_n
                                    if occ_n else 0.0),
            "cache_bytes": paged_cache_bytes(
                self.cfg, self.plan, self.serve_cfg.num_blocks,
                self.serve_cfg.block_size),
        }

    # -- observability -------------------------------------------------------

    def metrics_snapshot(self) -> Dict[str, object]:
        """Cheap host-side health snapshot for `ft.Heartbeat`: what an
        operator tailing the watchdog file needs to see mid-run. No
        device state is touched."""
        live = sum(blocks_for(int(self._pos[s]), self.serve_cfg.block_size)
                   for s in range(self.serve_cfg.max_slots)
                   if self._pos[s] >= 0)
        return {
            "retired": len(self.scheduler.completed),
            "queued": len(self.scheduler.queue),
            "running": len(self.scheduler.running),
            "live_occupancy": live / self.allocator.num_blocks,
            "preemptions": self.scheduler.preemptions,
            "decode_steps": self.steps,
        }

    # -- convenience ---------------------------------------------------------

    def generate(self, prompts, max_new_tokens: int = 32, **kw
                 ) -> List[np.ndarray]:
        """Submit `prompts` (list of 1-D int arrays) in order, drain, and
        return each request's tokens in submission order."""
        reqs = [self.submit(p, max_new_tokens=max_new_tokens, **kw)
                for p in prompts]
        self.run()
        return [np.asarray(r.out_tokens, np.int32) for r in reqs]


def recover_runtime(params, cfg, plan, journal_dir: str,
                    serve_cfg: ServeConfig = None, injector=None,
                    fsync: bool = True, tracer=None, metrics=None,
                    mesh=None):
    """Crash-recovery entry point: rebuild a Runtime from a request
    journal after a process death. Retired requests are never re-run
    (their tokens live in the journal); every in-flight request is
    re-submitted exactly once under its original rid/seed/settings, so
    draining the returned runtime replays each stream token-identically
    to the uninterrupted run. Returns ``(runtime, journal_state)`` —
    `journal_state.completed` holds the pre-crash outputs."""
    state = Journal.replay(journal_dir)
    journal = Journal(journal_dir, fsync=fsync)
    rt = Runtime(params, cfg, plan, serve_cfg, journal=journal,
                 injector=injector, tracer=tracer, metrics=metrics,
                 mesh=mesh)
    rt.scheduler.advance_rids(state.max_rid)
    for rid in sorted(state.inflight):
        rec = state.inflight[rid]
        req = Request(prompt=np.asarray(rec["prompt"], np.int32),
                      max_new_tokens=rec["max_new_tokens"],
                      temperature=rec["temperature"],
                      top_k=rec["top_k"], top_p=rec["top_p"],
                      stop_tokens=tuple(rec["stop_tokens"]),
                      priority=rec["priority"], seed=rec["seed"])
        rt.scheduler.resubmit(req, rid)
        journal.record_replayed(rid)
    return rt, state
