"""Deterministic synthetic token streams.

A seeded Markov-ish mixture over the vocab: each document samples a topic
vector that biases token transitions, so the stream has learnable structure
(losses drop below uniform entropy — used by the integration tests and the
quantization-quality benchmarks as the task signal).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

Array = np.ndarray


class CalibrationDataError(ValueError):
    """A calibration batch failed up-front validation (empty, wrong
    rank/dtype, out-of-range ids, non-finite features) — raised with a
    clear message instead of a shape/NaN blowup deep inside the Gram
    accumulation (DESIGN.md §8.2)."""


class SyntheticLM:
    def __init__(self, vocab_size: int, seed: int = 0, n_topics: int = 16,
                 order_bias: float = 0.8):
        self.vocab = vocab_size
        self.rng = np.random.RandomState(seed)
        self.n_topics = n_topics
        self.order_bias = order_bias
        # per-topic preferred successor offsets (small = learnable)
        self.offsets = self.rng.randint(1, 17, size=(n_topics,))

    def sample(self, batch: int, seq_len: int, step: int = 0) -> Dict[str, Array]:
        if batch <= 0 or seq_len <= 0:
            raise CalibrationDataError(
                f"sample(batch={batch}, seq_len={seq_len}): both must be "
                "positive")
        rng = np.random.RandomState((hash((step, batch, seq_len)) & 0x7FFFFFFF))
        topics = rng.randint(0, self.n_topics, size=(batch,))
        # two levels of learnable structure: a restricted active vocabulary
        # (unigram skew — learned within tens of steps) and topic-dependent
        # successor offsets (bigram structure — learned more slowly)
        active = max(4, self.vocab // 8)
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.randint(0, active, size=(batch,))
        offs = self.offsets[topics]
        for t in range(1, seq_len + 1):
            follow = (toks[:, t - 1] + offs) % active
            rand = rng.randint(0, active, size=(batch,))
            use_follow = rng.rand(batch) < self.order_bias
            toks[:, t] = np.where(use_follow, follow, rand)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batches(vocab_size: int, batch: int, seq_len: int, *, seed: int = 0,
            start_step: int = 0) -> Iterator[Dict[str, Array]]:
    """Infinite deterministic batch stream; resumable via start_step."""
    gen = SyntheticLM(vocab_size, seed)
    step = start_step
    while True:
        yield gen.sample(batch, seq_len, step)
        step += 1
