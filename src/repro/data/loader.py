"""Sharded host data loader with background prefetch.

Each host materializes only its slice of the global batch (computed from
`jax.process_index()`-style host_id/host_count — single host here, but the
slicing logic is the multi-host one) and a daemon thread keeps a small
prefetch queue ahead of the training loop. The stream position is part of
the checkpoint, so restarts are sample-exact.
"""
from __future__ import annotations

import queue
import threading
import warnings
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.synthetic import CalibrationDataError, SyntheticLM


# ---------------------------------------------------------------------------
# up-front calibration validation (DESIGN.md §8.2)
# ---------------------------------------------------------------------------

def validate_calib_tokens(tokens, vocab_size: Optional[int] = None):
    """Check a (B, T) calibration token batch up front — non-empty, rank
    2, integer dtype, ids inside the vocab — raising CalibrationDataError
    with a clear message instead of a shape blowup deep in the Gram
    accumulation. Returns `tokens` unchanged (never copies/casts)."""
    if tokens is None:
        raise CalibrationDataError("calibration tokens are None")
    arr = np.asarray(tokens)
    if arr.size == 0:
        raise CalibrationDataError(
            f"calibration token batch is empty (shape {arr.shape})")
    if arr.ndim != 2:
        raise CalibrationDataError(
            f"calibration tokens must be rank 2 (batch, seq), got shape "
            f"{tuple(arr.shape)}")
    if not np.issubdtype(arr.dtype, np.integer):
        raise CalibrationDataError(
            f"calibration tokens must be integer ids, got dtype "
            f"{arr.dtype}")
    lo, hi = int(arr.min()), int(arr.max())
    if lo < 0 or (vocab_size is not None and hi >= vocab_size):
        raise CalibrationDataError(
            f"calibration token ids out of range [{lo}, {hi}] for vocab "
            f"size {vocab_size}")
    return tokens


def validate_calib_features(x, name: str = "vision_embeds"):
    """Check a floating calibration feature batch (e.g. VLM vision
    embeddings): non-empty, floating, all-finite. NaN/Inf *input*
    calibration is a data bug and raises here; NaN that appears inside
    the activation stream is the numeric guards' job (core/guards)."""
    if x is None:
        raise CalibrationDataError(f"{name} is None")
    arr = np.asarray(x)
    if arr.size == 0:
        raise CalibrationDataError(f"{name} is empty (shape {arr.shape})")
    if not (np.issubdtype(arr.dtype, np.floating)
            or arr.dtype.name == "bfloat16"):
        raise CalibrationDataError(
            f"{name} must be floating, got dtype {arr.dtype}")
    finite = np.isfinite(arr.astype(np.float32))
    if not finite.all():
        raise CalibrationDataError(
            f"{name} contains {int((~finite).sum())} non-finite entries")
    return x


def check_calib_coverage(n_tokens: int, leaf_dims: Dict[str, int]) -> bool:
    """Warn when the calibration token count is below the input dimension
    of any leaf class — the Gram XᵀX is then guaranteed rank-deficient
    and the solve leans on the damping/dead-column guards. Returns True
    when coverage is sufficient."""
    short = {k: d for k, d in leaf_dims.items() if n_tokens < d}
    if short:
        worst = max(short.values())
        warnings.warn(
            f"calibration has {n_tokens} tokens but leaf input dims up "
            f"to {worst} ({', '.join(f'{k}={d}' for k, d in sorted(short.items()))}): "
            "the Gram is rank-deficient; expect dead-column/damping "
            "guard events (use a larger calibration batch)", stacklevel=3)
    return not short


class ShardedLoader:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int, *,
                 seed: int = 0, host_id: int = 0, host_count: int = 1,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % host_count == 0
        self.local_batch = global_batch // host_count
        self.host_id = host_id
        self.seq_len = seq_len
        self.gen = SyntheticLM(vocab_size, seed)
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _produce(self, step: int) -> Dict[str, np.ndarray]:
        full = self.gen.sample(self.local_batch * 1, self.seq_len, step)
        # host slice: deterministic function of (step, host_id)
        return full

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._produce(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
