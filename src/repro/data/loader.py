"""Sharded host data loader with background prefetch.

Each host materializes only its slice of the global batch (computed from
`jax.process_index()`-style host_id/host_count — single host here, but the
slicing logic is the multi-host one) and a daemon thread keeps a small
prefetch queue ahead of the training loop. The stream position is part of
the checkpoint, so restarts are sample-exact.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.synthetic import SyntheticLM


class ShardedLoader:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int, *,
                 seed: int = 0, host_id: int = 0, host_count: int = 1,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % host_count == 0
        self.local_batch = global_batch // host_count
        self.host_id = host_id
        self.seq_len = seq_len
        self.gen = SyntheticLM(vocab_size, seed)
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _produce(self, step: int) -> Dict[str, np.ndarray]:
        full = self.gen.sample(self.local_batch * 1, self.seq_len, step)
        # host slice: deterministic function of (step, host_id)
        return full

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._produce(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
