from repro.data.loader import (ShardedLoader, check_calib_coverage,  # noqa: F401,E501
                               validate_calib_features,
                               validate_calib_tokens)
from repro.data.synthetic import (CalibrationDataError, SyntheticLM,  # noqa: F401,E501
                                  batches)
