from repro.data.loader import ShardedLoader  # noqa: F401
from repro.data.synthetic import SyntheticLM, batches  # noqa: F401
