"""Training step: microbatched gradient accumulation + AdamW.

The microbatch loop is a `lax.scan` (one rolled body in HLO); gradients
accumulate in f32 master-param space. With `RunConfig.grad_compression=
"int8_ef"` the cross-shard gradient mean runs through
`dist.collectives.compressed_psum` — int8 codes on a shared absmax grid
move over the wire instead of f32 values, and each shard's quantization
residual is carried in the train state (`grad_err`, threaded by
`init_train_state`) so compression error never accumulates. That path
needs a named mesh axis, so the step must run under `shard_map` with
`axis_name=` passed to `make_train_step`; the default "none" path stays
mesh-agnostic (jit/GSPMD handles the reduction implicitly). Remat policy
is owned by the model's BuildPlan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import lm_loss
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine)

PyTree = Any


def init_train_state(params: PyTree, adamw_cfg: AdamWConfig,
                     run_cfg=None) -> Dict:
    state = {"params": params, "opt": adamw_init(params, adamw_cfg)}
    if run_cfg is not None and run_cfg.grad_compression == "int8_ef":
        from repro.dist.collectives import init_error_state
        state["grad_err"] = init_error_state(params)
    return state


def make_train_step(cfg, plan, run_cfg, adamw_cfg: AdamWConfig,
                    axis_name: Optional[str] = None):
    nm = max(1, run_cfg.microbatches)
    compress = run_cfg.grad_compression == "int8_ef"
    if run_cfg.grad_compression not in ("none", "int8_ef"):
        raise ValueError(
            f"unknown grad_compression {run_cfg.grad_compression!r}")
    if compress and axis_name is None:
        raise ValueError(
            "grad_compression='int8_ef' all-reduces int8 codes over a named "
            "mesh axis: run the step under shard_map and pass axis_name=")

    def loss_fn(params, mb):
        return lm_loss(params, cfg, plan, mb)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params, opt = state["params"], state["opt"]
        step = opt["step"]
        lr = warmup_cosine(step, base_lr=run_cfg.learning_rate,
                           warmup_steps=run_cfg.warmup_steps,
                           total_steps=run_cfg.total_steps)

        # one bf16 working copy per step: FSDP gathers / backward flow in
        # bf16 (half the traffic and temp footprint of f32 master params);
        # the f32 master is touched only by the optimizer update.
        cast = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 else p, params)

        def split_mb(x):
            if x.ndim == 0:
                return x
            b = x.shape[0]
            return x.reshape(nm, b // nm, *x.shape[1:])

        mbs = jax.tree_util.tree_map(split_mb, batch)
        gacc0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def mb_step(gacc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(cast, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return gacc, loss

        if nm > 1:
            gacc, losses = jax.lax.scan(mb_step, gacc0, mbs)
            loss = jnp.mean(losses)
        else:
            mb = jax.tree_util.tree_map(lambda x: x[0] if x.ndim else x, mbs)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(cast, mb)
            gacc = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        grads = jax.tree_util.tree_map(lambda g: g / nm, gacc)
        new_state = {}
        if compress:
            # int8-EF all-reduce of the *local* gradient mean; the carried
            # residual rides in the state so no mass is ever lost
            from repro.dist.collectives import compressed_psum
            n_shards = jax.lax.psum(1, axis_name)
            grads, new_err = compressed_psum(grads, axis_name,
                                             state["grad_err"], n_shards)
            new_state["grad_err"] = new_err
            loss = jax.lax.pmean(loss, axis_name)
        grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        new_params, new_opt = adamw_update(grads, opt, params, adamw_cfg, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": new_opt["step"]}
        new_state.update({"params": new_params, "opt": new_opt})
        return new_state, metrics

    return train_step
