"""Training step: microbatched gradient accumulation + AdamW.

The microbatch loop is a `lax.scan` (one rolled body in HLO); gradients
accumulate in f32 master-param space; optional int8 error-feedback gradient
compression runs inside an explicitly shard_map'd variant (see
dist/collectives.py). Remat policy is owned by the model's BuildPlan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import lm_loss
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine)

PyTree = Any


def init_train_state(params: PyTree, adamw_cfg: AdamWConfig) -> Dict:
    return {"params": params, "opt": adamw_init(params, adamw_cfg)}


def make_train_step(cfg, plan, run_cfg, adamw_cfg: AdamWConfig):
    nm = max(1, run_cfg.microbatches)

    def loss_fn(params, mb):
        return lm_loss(params, cfg, plan, mb)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params, opt = state["params"], state["opt"]
        step = opt["step"]
        lr = warmup_cosine(step, base_lr=run_cfg.learning_rate,
                           warmup_steps=run_cfg.warmup_steps,
                           total_steps=run_cfg.total_steps)

        # one bf16 working copy per step: FSDP gathers / backward flow in
        # bf16 (half the traffic and temp footprint of f32 master params);
        # the f32 master is touched only by the optimizer update.
        cast = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 else p, params)

        def split_mb(x):
            if x.ndim == 0:
                return x
            b = x.shape[0]
            return x.reshape(nm, b // nm, *x.shape[1:])

        mbs = jax.tree_util.tree_map(split_mb, batch)
        gacc0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def mb_step(gacc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(cast, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return gacc, loss

        if nm > 1:
            gacc, losses = jax.lax.scan(mb_step, gacc0, mbs)
            loss = jnp.mean(losses)
        else:
            mb = jax.tree_util.tree_map(lambda x: x[0] if x.ndim else x, mbs)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(cast, mb)
            gacc = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        grads = jax.tree_util.tree_map(lambda g: g / nm, gacc)
        grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        new_params, new_opt = adamw_update(grads, opt, params, adamw_cfg, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": new_opt["step"]}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
