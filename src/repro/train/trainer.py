"""The training loop: data, step, checkpoints, watchdog, restart.

`Trainer.run()` executes `total_steps` with: sharded batches, microbatched
train_step, periodic async checkpoints (params + optimizer + loader
position), heartbeats, straggler events, and an injectable failure hook
(used by the fault-tolerance tests). `resume()` restores the latest
committed checkpoint — including onto a different device count (elastic).
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.data import ShardedLoader
from repro.ft import Heartbeat, Watchdog
from repro.models.model import init_params
from repro.optim import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


class Trainer:
    def __init__(self, cfg, plan, run_cfg, *, adamw_cfg: AdamWConfig = None,
                 host_id: int = 0, failure_hook: Optional[Callable] = None,
                 shard_state_fn: Optional[Callable] = None):
        self.cfg = cfg
        self.plan = plan
        self.run = run_cfg
        self.adamw_cfg = adamw_cfg or AdamWConfig(
            weight_decay=run_cfg.weight_decay)
        self.ckpt = CheckpointManager(run_cfg.ckpt_dir, keep=run_cfg.keep_ckpts)
        self.watchdog = Watchdog()
        self.heartbeat = Heartbeat(os.path.join(run_cfg.ckpt_dir, "hb"),
                                   host_id)
        self.failure_hook = failure_hook
        self.shard_state_fn = shard_state_fn   # elastic re-shard on restore
        if run_cfg.grad_compression == "int8_ef":
            # compressed_psum needs a named mesh axis: run the step under a
            # shard_map over a 1-shard "data" axis — the single-process
            # Trainer's whole batch is one shard, so this exercises the
            # int8-EF quantize/carry path end-to-end; multi-shard
            # deployments wire their own shard_map (see train_step.py)
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.dist import data_mesh
            step = make_train_step(cfg, plan, run_cfg, self.adamw_cfg,
                                   axis_name="data")
            self.step_fn = jax.jit(
                shard_map(step, mesh=data_mesh(1), in_specs=(P(), P()),
                          out_specs=(P(), P()), check_rep=False),
                donate_argnums=(0,))
        else:
            self.step_fn = jax.jit(
                make_train_step(cfg, plan, run_cfg, self.adamw_cfg),
                donate_argnums=(0,))
        self.metrics_log = []

    def init_state(self):
        params = init_params(jax.random.PRNGKey(self.run.seed), self.cfg,
                             self.plan)
        return init_train_state(params, self.adamw_cfg, self.run)

    def resume_or_init(self):
        latest = self.ckpt.latest_step()
        state = self.init_state()
        start_step = 0
        if latest is not None:
            shardings = (self.shard_state_fn(state)
                         if self.shard_state_fn else None)
            state, meta = self.ckpt.restore(latest, state, shardings)
            start_step = meta["step"]
        return state, start_step

    def run_loop(self, total_steps: Optional[int] = None,
                 seq_len: Optional[int] = None,
                 global_batch: Optional[int] = None) -> Dict[str, Any]:
        total = total_steps or self.run.total_steps
        state, start = self.resume_or_init()
        loader = ShardedLoader(self.cfg.vocab_size,
                               global_batch or 8,
                               seq_len or 128,
                               seed=self.run.seed, start_step=start)
        step = start
        try:
            while step < total:
                batch = next(loader)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                self.watchdog.step_start()
                state, metrics = self.step_fn(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                ev = self.watchdog.step_end(step)
                if ev is not None:
                    metrics["straggler"] = ev.seconds
                self.metrics_log.append(metrics)
                step += 1
                self.heartbeat.beat(step)
                if self.failure_hook is not None:
                    self.failure_hook(step)   # may raise (injected failure)
                if step % self.run.ckpt_every == 0 or step == total:
                    self.ckpt.save(step, state,
                                   extra={"loader": loader.state()},
                                   blocking=not self.run.async_ckpt)
        finally:
            loader.close()
            self.ckpt.wait()
        return {"final_step": step, "state": state,
                "metrics": self.metrics_log}
