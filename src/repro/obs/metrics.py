"""Metrics registry: counters, gauges, histograms + two sinks.

`MetricsRegistry` is a name → instrument map with get-or-create
semantics (`registry.counter("serve.preemptions")`).  Instruments are
deliberately dumb host-side accumulators — a counter is one float add,
a histogram is one list append — so they are legal inside the lint-
enforced hot zones as long as the *values* handed to them are already
host scalars (the sync-free accumulation rule, DESIGN.md §10.3: device
quantities stay device-side and are observed once at end-of-run).

Histograms keep the raw observations.  `quantile(q)` delegates to the
same linear-interpolation definition as `numpy.percentile`, so code
that previously computed `np.percentile(itls, 99)` can switch to
`hist.quantile(0.99)` and produce bit-identical numbers; bucketing only
happens at Prometheus export time.

Sinks:
* `dump_jsonl(path)`   — one JSON object per instrument (event stream
  consumed by `repro.obs.report` and test assertions);
* `dump_prometheus(path)` — text exposition format (`# TYPE` lines,
  `_bucket{le=...}` / `_sum` / `_count` for histograms).

`NULL_METRICS` is the shared disabled registry: every instrument it
hands out is a no-op singleton, so `metrics or NULL_METRICS` makes all
call sites unconditionally safe and free when observability is off.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

# default Prometheus bucket boundaries (seconds-flavoured; export-only)
_DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def add(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Raw-value histogram: exact quantiles, buckets only at export."""
    __slots__ = ("name", "values", "buckets")
    kind = "histogram"

    def __init__(self, name: str,
                 buckets: Sequence[float] = _DEFAULT_BUCKETS):
        self.name = name
        self.values: List[float] = []
        self.buckets = tuple(buckets)

    def observe(self, v: float) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile, identical to
        `numpy.percentile(values, q*100)` (numpy's default method)."""
        if not self.values:
            return float("nan")
        xs = sorted(self.values)
        if len(xs) == 1:
            return float(xs[0])
        pos = q * (len(xs) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class _NullInstrument:
    """One object plays disabled counter, gauge, and histogram."""
    __slots__ = ()
    name = "null"
    value = 0.0
    values: List[float] = []
    count = 0
    sum = 0.0

    def inc(self, n: float = 1.0) -> None:
        return None

    def set(self, v: float) -> None:
        return None

    def add(self, n: float = 1.0) -> None:
        return None

    def observe(self, v: float) -> None:
        return None

    def quantile(self, q: float) -> float:
        return float("nan")


_NULL_INSTRUMENT = _NullInstrument()


class _NullMetrics:
    __slots__ = ()
    enabled = False

    def counter(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=None) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> Dict[str, Any]:
        return {}

    def dump_jsonl(self, path: str) -> None:
        return None

    def dump_prometheus(self, path: str) -> None:
        return None


NULL_METRICS = _NullMetrics()


class MetricsRegistry:
    """Get-or-create instrument registry; thread-safe at creation."""
    enabled = True

    def __init__(self, run: str = "run"):
        self.run = run
        self._instruments: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, factory):
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = factory()
                    self._instruments[name] = inst
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, lambda: Gauge(name))

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(
            name, lambda: Histogram(name, buckets or _DEFAULT_BUCKETS))

    # -- introspection -------------------------------------------------
    def instruments(self) -> Dict[str, Any]:
        with self._lock:
            return dict(self._instruments)

    def snapshot(self) -> Dict[str, Any]:
        """Flat JSON-able view: counters/gauges → value, histograms →
        {count, sum, p50, p99}.  Used by `ft.Heartbeat` snapshots."""
        out: Dict[str, Any] = {}
        for name, inst in sorted(self.instruments().items()):
            if inst.kind == "histogram":
                out[name] = {"count": inst.count, "sum": inst.sum,
                             "p50": inst.quantile(0.5),
                             "p99": inst.quantile(0.99)}
            else:
                out[name] = inst.value
        return out

    # -- sinks ---------------------------------------------------------
    def dump_jsonl(self, path: str) -> None:
        """One record per instrument; histograms carry raw values so the
        report CLI can recompute any quantile."""
        _ensure_dir(path)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            for name, inst in sorted(self.instruments().items()):
                rec: Dict[str, Any] = {"name": name, "kind": inst.kind,
                                       "run": self.run}
                if inst.kind == "histogram":
                    rec["count"] = inst.count
                    rec["sum"] = inst.sum
                    rec["values"] = [float(v) for v in inst.values]
                else:
                    rec["value"] = inst.value
                f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def dump_prometheus(self, path: str) -> None:
        _ensure_dir(path)
        lines: List[str] = []
        for name, inst in sorted(self.instruments().items()):
            pname = _prom_name(name)
            lines.append(f"# TYPE {pname} {inst.kind}")
            if inst.kind == "histogram":
                acc = 0
                for le in inst.buckets:
                    acc = sum(1 for v in inst.values if v <= le)
                    lines.append(f'{pname}_bucket{{le="{le}"}} {acc}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {inst.count}')
                lines.append(f"{pname}_sum {inst.sum}")
                lines.append(f"{pname}_count {inst.count}")
            else:
                lines.append(f"{pname} {inst.value}")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write("\n".join(lines) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)


def _prom_name(name: str) -> str:
    """`serve.itl_seconds` → `serve_itl_seconds` (Prometheus charset)."""
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
