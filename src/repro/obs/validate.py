"""Pure-python Chrome-trace/Perfetto schema checker (no jax, no numpy).

CI runs this over every trace the smoke steps emit; it is deliberately
strict about the subset of the Trace Event Format this repo produces:

* top level is an object with a `traceEvents` list;
* every event has `name` (str), `ph` in {"X", "i", "B", "E", "M"},
  numeric `ts`, and integer `pid`/`tid`;
* "X" events additionally need a numeric non-negative `dur`;
* "i" events need scope `s` in {"g", "p", "t"};
* `args`, when present, must be a JSON object.

Returns a list of problem strings; [] means the trace is loadable by
chrome://tracing and Perfetto.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

_PHASES = ("X", "i", "B", "E", "M")
_SCOPES = ("g", "p", "t")


def validate_event(ev: Any, idx: int) -> List[str]:
    probs: List[str] = []
    where = f"traceEvents[{idx}]"
    if not isinstance(ev, dict):
        return [f"{where}: not an object"]
    name = ev.get("name")
    if not isinstance(name, str) or not name:
        probs.append(f"{where}: missing/empty name")
    ph = ev.get("ph")
    if ph not in _PHASES:
        probs.append(f"{where}: bad phase {ph!r} (want one of {_PHASES})")
    ts = ev.get("ts")
    if not isinstance(ts, (int, float)) or isinstance(ts, bool):
        probs.append(f"{where}: ts must be numeric, got {type(ts).__name__}")
    for key in ("pid", "tid"):
        v = ev.get(key)
        if not isinstance(v, int) or isinstance(v, bool):
            probs.append(f"{where}: {key} must be an int, "
                         f"got {type(v).__name__}")
    if ph == "X":
        dur = ev.get("dur")
        if (not isinstance(dur, (int, float)) or isinstance(dur, bool)
                or dur < 0):
            probs.append(f"{where}: X event needs non-negative numeric dur")
    if ph == "i" and ev.get("s") not in _SCOPES:
        probs.append(f"{where}: i event scope s={ev.get('s')!r} "
                     f"not in {_SCOPES}")
    args = ev.get("args")
    if args is not None and not isinstance(args, dict):
        probs.append(f"{where}: args must be an object")
    return probs


def validate_trace(doc: Any) -> List[str]:
    """Validate a parsed trace document; [] means clean."""
    if not isinstance(doc, dict):
        return ["top level must be an object with a traceEvents list"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    probs: List[str] = []
    for i, ev in enumerate(evs):
        probs.extend(validate_event(ev, i))
    return probs


def validate_trace_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable trace ({e})"]
    return [f"{path}: {p}" for p in validate_trace(doc)]


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.validate",
        description="Chrome-trace schema checker")
    ap.add_argument("paths", nargs="+", help="trace JSON files")
    ap.add_argument("--timelines", action="store_true",
                    help="additionally reconstruct per-request timelines "
                         "from the (merged, rid-dedup'd) request events "
                         "and fail on any incomplete/inconsistent one")
    ap.add_argument("--require-preempt", action="store_true",
                    help="with --timelines: fail unless at least one "
                         "request was preempted AND resumed (the CI "
                         "smoke's preemption-coverage guarantee)")
    args = ap.parse_args(argv)
    bad = 0
    merged: List[Dict[str, Any]] = []
    for path in args.paths:
        probs = validate_trace_file(path)
        for p in probs:
            print(p)
        if probs:
            bad += 1
        else:
            with open(path) as f:
                evs = json.load(f).get("traceEvents", [])
            merged.extend(evs)
            print(f"{path}: OK ({len(evs)} events)")
    if args.timelines and not bad:
        from repro.obs.timeline import reconstruct_timelines, \
            validate_timeline
        tls = reconstruct_timelines(merged)
        preempted = 0
        for rid in sorted(tls):
            tl = tls[rid]
            probs = [f"rid {rid}: {p}" for p in validate_timeline(tl)]
            for p in probs:
                print(p)
            bad += bool(probs)
            if tl.preempts and tl.resumes:
                preempted += 1
        print(f"timelines: {len(tls)} request(s), "
              f"{preempted} preempted+resumed")
        if args.require_preempt and not preempted:
            print("timelines: no preempted+resumed request "
                  "(--require-preempt)")
            bad += 1
    return bad


if __name__ == "__main__":
    import sys
    sys.exit(main())
