"""`python -m repro.obs.report DIR` — run summary from obs sinks.

Reads whatever a `--trace DIR` / `--metrics DIR` run left behind:

* `*.trace.json`   — Chrome-trace files (all generations of a
  crash-replay run merge); the serve `request` events reconstruct
  per-request timelines, span events aggregate per-name totals;
* `metrics.jsonl`  — the registry event stream (counters/gauges print
  as-is, histograms recompute p50/p99 from raw values).

Output is a plain table on stdout — no deps beyond the stdlib — so it
works in CI logs and over ssh.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Any, Dict, List

from repro.obs.timeline import reconstruct_timelines, validate_timeline


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if math.isnan(v):
            return "nan"
        if v and abs(v) < 0.01:
            return f"{v:.2e}"
        return f"{v:.4g}"
    return str(v)


def _table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(r[i]) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(header), sep] + [line(r) for r in rows])


def _quantile(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    ys = sorted(xs)
    if len(ys) == 1:
        return float(ys[0])
    pos = q * (len(ys) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(ys) - 1)
    frac = pos - lo
    return float(ys[lo] * (1.0 - frac) + ys[hi] * frac)


def load_events(run_dir: str) -> List[Dict[str, Any]]:
    events: List[Dict[str, Any]] = []
    for path in sorted(glob.glob(os.path.join(run_dir, "*.trace.json"))):
        with open(path) as f:
            events.extend(json.load(f).get("traceEvents", []))
    return events


def span_summary(events: List[Dict[str, Any]]) -> str:
    agg: Dict[str, List[float]] = {}
    for e in events:
        if e.get("ph") == "X" and e.get("cat") == "span":
            agg.setdefault(e["name"], []).append(e.get("dur", 0.0))
    if not agg:
        return ""
    rows = []
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        durs = agg[name]
        rows.append([name, str(len(durs)),
                     _fmt(sum(durs) / 1e6), _fmt(_quantile(durs, 0.5) / 1e6),
                     _fmt(max(durs) / 1e6)])
    return _table(rows, ["span", "count", "total_s", "p50_s", "max_s"])


def request_summary(events: List[Dict[str, Any]]) -> str:
    tls = reconstruct_timelines(events)
    if not tls:
        return ""
    rows = []
    problems: List[str] = []
    for rid in sorted(tls):
        tl = tls[rid]
        problems += validate_timeline(tl)
        rows.append([str(rid), str(tl.prompt_len), str(tl.new_tokens),
                     _fmt(tl.ttft_s if tl.ttft_s is not None
                          else float("nan")),
                     _fmt(tl.wall_s if tl.wall_s is not None
                          else float("nan")),
                     str(len(tl.preempts)), str(len(tl.resumes)),
                     tl.finish_reason or "-"])
    out = _table(rows, ["rid", "prompt", "tokens", "ttft_s", "wall_s",
                        "preempts", "resumes", "finish"])
    if problems:
        out += "\n\ntimeline problems:\n" + "\n".join(
            f"  {p}" for p in problems)
    return out


def metrics_summary(run_dir: str) -> str:
    path = os.path.join(run_dir, "metrics.jsonl")
    if not os.path.exists(path):
        return ""
    rows = []
    with open(path) as f:
        for ln in f:
            ln = ln.strip()
            if not ln:
                continue
            rec = json.loads(ln)
            if rec.get("kind") == "histogram":
                vals = rec.get("values", [])
                rows.append([rec["name"], "histogram",
                             f"n={rec.get('count', len(vals))} "
                             f"p50={_fmt(_quantile(vals, 0.5))} "
                             f"p99={_fmt(_quantile(vals, 0.99))}"])
            else:
                rows.append([rec["name"], rec.get("kind", "?"),
                             _fmt(rec.get("value"))])
    if not rows:
        return ""
    return _table(rows, ["metric", "kind", "value"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="render a run summary table from --trace/--metrics "
                    "sink directories")
    ap.add_argument("run_dir", help="directory holding *.trace.json "
                                    "and/or metrics.jsonl")
    args = ap.parse_args(argv)

    events = load_events(args.run_dir)
    sections = [("spans", span_summary(events)),
                ("requests", request_summary(events)),
                ("metrics", metrics_summary(args.run_dir))]
    printed = False
    for title, body in sections:
        if body:
            print(f"== {title} ==")
            print(body)
            print()
            printed = True
    if not printed:
        print(f"no obs artifacts found under {args.run_dir}")
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
