"""Unified observability layer (DESIGN.md §10).

One subsystem, three concerns, shared across quantize + serve:

* `obs/trace.py`   — `Tracer`: nestable host spans + request lifecycle
  events, emitted as Chrome-trace/Perfetto JSON; `jax.profiler`
  TraceAnnotation bridging so host spans line up with device timelines.
* `obs/metrics.py` — `MetricsRegistry`: counters / gauges / histograms
  with a JSONL event-stream sink and Prometheus text exposition.
* `obs/timeline.py` — per-request serve timelines (submit → admit →
  first_token → decode tokens → preempt/resume → retire) reconstructed
  from the tracer's request events, rid-dedup'd across crash-replay
  restarts.
* `obs/validate.py` — pure-python Chrome-trace schema checker (the CI
  smoke gate on every emitted trace).
* `obs/report.py`  — `python -m repro.obs.report DIR` renders a run
  summary table from the sinks.

The cardinal rule (DESIGN.md §10.3): instrumentation is **zero-cost when
disabled and host-sync-free in hot zones**. Disabled tracers/registries
are shared null singletons whose hooks return immediately; enabled ones
only append host dicts — quantities that live on device stay there until
the run's one end-of-run pull.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, NULL_METRICS)
from repro.obs.timeline import (RequestTimeline, dedup_events,  # noqa: F401
                                reconstruct_timelines, request_events,
                                validate_timeline)
from repro.obs.trace import (NULL_TRACER, Span, Tracer,  # noqa: F401
                             next_trace_path)
from repro.obs.validate import (validate_trace,  # noqa: F401
                                validate_trace_file)
