"""Structured tracing: nestable host spans → Chrome-trace JSON.

`Tracer` collects two kinds of events (DESIGN.md §10.1):

* **spans** — `with tracer.span("leaf_solve", layer=3, name="wq"):`
  records a Chrome-trace complete ("X") event with epoch-µs start and a
  perf_counter-derived duration.  Spans nest; each thread gets its own
  `tid` lane so nesting renders correctly in Perfetto/chrome://tracing.
* **request events** — `tracer.request_event("submit", rid=4, ...)`
  records an instant ("i") event in the `request` category; these are
  the raw material `obs/timeline.py` reconstructs per-request serve
  timelines from (and dedups by rid across crash-replay restarts).

Device bridging: when a span is opened with `device=True` the tracer
also enters `jax.profiler.TraceAnnotation(label)`, which is a cheap
TraceMe when no profiler is attached and annotates the device timeline
when one is — so host spans and XLA slices line up in one viewer.

Timestamps are epoch microseconds (`time.time()*1e6`) so traces written
by different processes — e.g. restart generations of a crash-replay run
— merge and order correctly; durations come from `perf_counter` deltas
so they are monotonic within a span.

Zero-cost-disabled rule: callers hold `tracer or NULL_TRACER`.  The null
tracer's `span()` returns one shared no-op context manager and its event
hooks return immediately — no allocation, no branching in callees.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared no-op span: `with NULL_TRACER.span(...)` costs two calls."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _NullTracer:
    """Disabled tracer: every hook is a no-op returning a shared object."""
    __slots__ = ()
    enabled = False

    def span(self, name: str, **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args: Any) -> None:
        return None

    def request_event(self, kind: str, rid: int, **args: Any) -> None:
        return None

    def token_event(self, rid: int, i: int, token: int,
                    ts_us: float) -> None:
        return None

    def save(self, path: str) -> None:   # pragma: no cover - never called
        return None


NULL_TRACER = _NullTracer()


class Span:
    """An open span; closing it appends one Chrome-trace "X" event."""
    __slots__ = ("_tracer", "name", "args", "_t0_epoch_us", "_t0_perf",
                 "_annotation")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any],
                 annotation: Any = None):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._annotation = annotation
        self._t0_epoch_us = time.time() * 1e6
        self._t0_perf = time.perf_counter()

    def __enter__(self) -> "Span":
        if self._annotation is not None:
            self._annotation.__enter__()
        return self

    def __exit__(self, *exc) -> bool:
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
        dur_us = (time.perf_counter() - self._t0_perf) * 1e6
        self._tracer._events.append(
            ("X", self.name, self._t0_epoch_us, dur_us,
             threading.get_ident(), self.args))
        return False

    @property
    def elapsed_s(self) -> float:
        """Seconds since the span opened (usable before close)."""
        return time.perf_counter() - self._t0_perf


class Tracer:
    """Collects trace events in memory; `save()` writes Chrome-trace JSON.

    Hot-path discipline (the §10.3 overhead budget): emit appends ONE
    compact tuple — no Chrome-trace dict is built until `events`/`save`
    materializes them, off the hot path. `list.append` is atomic under
    the GIL, so concurrent emitters need no lock; `events` snapshots via
    `list(...)` for the same reason.
    """
    enabled = True

    def __init__(self, run: str = "run", pid: Optional[int] = None):
        self.run = run
        self.pid = os.getpid() if pid is None else pid
        # raw entries: ("X", name, ts_us, dur_us, tid, args) for spans,
        # ("i", name, cat, ts_us, tid, args) for instants
        self._events: List[tuple] = []

    # -- emission ------------------------------------------------------
    def span(self, name: str, *, device: bool = False, **args: Any) -> Span:
        """Open a nestable span. `device=True` additionally enters a
        `jax.profiler.TraceAnnotation` so the label shows up on the
        device timeline when a profiler is attached."""
        annotation = None
        if device:
            annotation = _trace_annotation(name)
        return Span(self, name, args, annotation)

    def instant(self, name: str, **args: Any) -> None:
        self._events.append(("i", name, "instant", time.time() * 1e6,
                             threading.get_ident(), args))

    def request_event(self, kind: str, rid: int, **args: Any) -> None:
        """Instant event in the `request` category; the per-request
        timeline reconstruction keys off (kind, rid, args)."""
        a = {"rid": rid}
        a.update(args)
        self._events.append(("i", kind, "request", time.time() * 1e6,
                             threading.get_ident(), a))

    def token_event(self, rid: int, i: int, token: int,
                    ts_us: float) -> None:
        """Specialized `request_event("token", ...)` for the decode
        loop's once-per-token hot call: the caller passes the step's
        already-taken timestamp so N live slots share one clock read,
        and the kwargs plumbing is skipped."""
        self._events.append(("i", "token", "request", ts_us,
                             threading.get_ident(),
                             {"rid": rid, "i": i, "token": token}))

    # -- access / persistence -----------------------------------------
    @property
    def events(self) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for ev in list(self._events):
            if ev[0] == "X":
                _, name, ts, dur, tid, args = ev
                out.append({"name": name, "ph": "X", "cat": "span",
                            "ts": ts, "dur": dur, "pid": self.pid,
                            "tid": tid, "args": args})
            else:
                _, name, cat, ts, tid, args = ev
                out.append({"name": name, "ph": "i", "cat": cat, "s": "t",
                            "ts": ts, "pid": self.pid, "tid": tid,
                            "args": args})
        return out

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": self.events,
                "displayTimeUnit": "ms",
                "otherData": {"run": self.run}}

    def save(self, path: str) -> None:
        """Write `{"traceEvents": [...]}` JSON. Appends never happen —
        each save is a full, self-contained snapshot (crash-replay
        restarts write distinct generation files and `obs/timeline.py`
        merges them)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome_trace(), f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)


def next_trace_path(directory: str, prefix: str) -> str:
    """Generation-unique trace filename `<prefix>.g<N>.trace.json` — each
    restart generation of a crash-replay run writes its own file and
    `obs/timeline.py` merges + dedups them by rid."""
    os.makedirs(directory, exist_ok=True)
    n = len([f for f in os.listdir(directory)
             if f.startswith(prefix + ".g") and f.endswith(".trace.json")])
    return os.path.join(directory, f"{prefix}.g{n}.trace.json")


def _trace_annotation(label: str):
    """Lazy `jax.profiler.TraceAnnotation` — imported at span-open so
    building a Tracer never drags in jax (the validator/report CLIs are
    pure python)."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:       # pragma: no cover - jax always present in CI
        return None
    return TraceAnnotation(label)
