"""Per-request serve timelines reconstructed from tracer request events.

The serve runtime emits instant events in the `request` category
(DESIGN.md §10.2 event taxonomy):

    submit       {rid, prompt_len, max_new_tokens, priority}
    admit        {rid, slot, resumed, prefill_len}
    first_token  {rid, token}
    token        {rid, i, token}          (one per decoded token)
    preempt      {rid, n_preempts}
    resume       {rid, slot}              (admit with resumed=True also
                                           counts as a resume marker)
    retire       {rid, reason, new_tokens}

`reconstruct_timelines(events)` turns a merged event stream — possibly
from several crash-replay restart generations — into one
`RequestTimeline` per rid.  Dedup rules (crash-replay semantics, PR 6:
replayed requests re-emit their token stream bit-identically):

* `submit` / `first_token` / `retire` — keep-first by rid;
* `token` — keep-first by (rid, i): replays re-deliver the same prefix;
* `admit` / `preempt` / `resume` — kept as occurrences (a request may
  legitimately be admitted/preempted many times), except exact
  duplicates (same rid, kind, and args) from a replayed generation
  collapse to the earliest occurrence.

`validate_timeline` checks lifecycle completeness: a retired request
must have submit ≤ admit ≤ first_token ≤ retire and a token count
matching its retire record.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

_KEEP_FIRST = ("submit", "first_token", "retire")
_LIFECYCLE = ("submit", "admit", "first_token", "token",
              "preempt", "resume", "retire")


def request_events(events: Sequence[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Filter a Chrome-trace event list down to `request`-category
    instants, sorted by timestamp (stable for ties)."""
    evs = [e for e in events
           if e.get("cat") == "request" and e.get("ph") == "i"]
    evs.sort(key=lambda e: e.get("ts", 0.0))
    return evs


def dedup_events(events: Sequence[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Collapse crash-replay duplicates; see module docstring for rules."""
    out: List[Dict[str, Any]] = []
    seen_once: set = set()          # (kind, rid) for keep-first kinds
    seen_tok: set = set()           # (rid, i) for token events
    seen_exact: set = set()         # (kind, rid, frozen args) for the rest
    for e in request_events(events):
        kind = e.get("name")
        args = e.get("args", {})
        rid = args.get("rid")
        if kind in _KEEP_FIRST:
            k = (kind, rid)
            if k in seen_once:
                continue
            seen_once.add(k)
        elif kind == "token":
            k = (rid, args.get("i"))
            if k in seen_tok:
                continue
            seen_tok.add(k)
        else:
            k = (kind, rid, tuple(sorted(
                (a, v) for a, v in args.items() if a != "rid")))
            if k in seen_exact:
                continue
            seen_exact.add(k)
        out.append(e)
    return out


@dataclass
class RequestTimeline:
    """One request's lifecycle, reconstructed from the event stream."""
    rid: int
    t_submit: Optional[float] = None       # epoch µs
    t_first_token: Optional[float] = None
    t_retire: Optional[float] = None
    admits: List[float] = field(default_factory=list)
    preempts: List[float] = field(default_factory=list)
    resumes: List[float] = field(default_factory=list)
    tokens: List[Tuple[int, int]] = field(default_factory=list)  # (i, tok)
    finish_reason: str = ""
    new_tokens: int = 0
    prompt_len: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_first_token is None:
            return None
        return (self.t_first_token - self.t_submit) / 1e6

    @property
    def wall_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_retire is None:
            return None
        return (self.t_retire - self.t_submit) / 1e6

    @property
    def complete(self) -> bool:
        return (self.t_submit is not None and bool(self.admits)
                and self.t_first_token is not None
                and self.t_retire is not None)


def reconstruct_timelines(
        events: Sequence[Dict[str, Any]]) -> Dict[int, RequestTimeline]:
    """Merged (+deduped) event stream → {rid: RequestTimeline}."""
    tls: Dict[int, RequestTimeline] = {}
    for e in dedup_events(events):
        kind = e.get("name")
        if kind not in _LIFECYCLE:
            continue
        args = e.get("args", {})
        rid = args.get("rid")
        ts = e.get("ts", 0.0)
        tl = tls.get(rid)
        if tl is None:
            tl = tls[rid] = RequestTimeline(rid=rid)
        if kind == "submit":
            tl.t_submit = ts
            tl.prompt_len = int(args.get("prompt_len", 0))
        elif kind == "admit":
            tl.admits.append(ts)
            if args.get("resumed"):
                tl.resumes.append(ts)
        elif kind == "first_token":
            tl.t_first_token = ts
        elif kind == "token":
            tl.tokens.append((int(args.get("i", -1)),
                              int(args.get("token", -1))))
        elif kind == "preempt":
            tl.preempts.append(ts)
        elif kind == "resume":
            tl.resumes.append(ts)
        elif kind == "retire":
            tl.t_retire = ts
            tl.finish_reason = str(args.get("reason", ""))
            tl.new_tokens = int(args.get("new_tokens", 0))
    for tl in tls.values():
        tl.tokens.sort(key=lambda it: it[0])
    return tls


def validate_timeline(tl: RequestTimeline) -> List[str]:
    """Lifecycle completeness/order checks; [] means clean."""
    probs: List[str] = []
    if tl.t_submit is None:
        probs.append(f"rid={tl.rid}: no submit event")
    if not tl.admits:
        probs.append(f"rid={tl.rid}: never admitted")
    if tl.t_retire is not None:
        if tl.t_first_token is None and tl.new_tokens > 0:
            probs.append(f"rid={tl.rid}: retired with tokens but no "
                         "first_token event")
        if (tl.t_submit is not None and tl.t_first_token is not None
                and not (tl.t_submit <= tl.t_first_token <= tl.t_retire)):
            probs.append(f"rid={tl.rid}: timestamps out of order "
                         f"(submit={tl.t_submit}, first={tl.t_first_token},"
                         f" retire={tl.t_retire})")
        if tl.tokens and len(tl.tokens) != tl.new_tokens:
            probs.append(f"rid={tl.rid}: {len(tl.tokens)} token events vs "
                         f"retire new_tokens={tl.new_tokens}")
        idxs = [i for i, _ in tl.tokens]
        if idxs and idxs != list(range(len(idxs))):
            probs.append(f"rid={tl.rid}: token indices not contiguous "
                         f"({idxs[:8]}...)")
    if len(tl.preempts) > 0 and len(tl.resumes) + 1 < len(tl.preempts):
        # a request preempted N times must have been resumed at least
        # N-1 times before it could be preempted again
        probs.append(f"rid={tl.rid}: {len(tl.preempts)} preempts but only "
                     f"{len(tl.resumes)} resumes")
    return probs
