"""Bytes-per-decode-token model for the paged serving runtime.

Analytic companion to the HLO cost parser (`roofline/analysis.py`): where
`hlo_cost` measures what a *compiled* decode program touches, this module
predicts the same per-step HBM traffic from first principles — so the
`roofline/kv_bytes_predicted_vs_measured` bench row can gate that the two
agree, and DESIGN.md §11's accounting table has a source of truth.

The byte model mirrors the parser's write-once discipline:

* weights stream from HBM once per decode step (decode is weight-bound at
  batch ~slots: every matmul re-reads its weight panel);
* the paged pool's page *codes* and per-(layer, page, kv_head) scales are
  the only KV read traffic — dequantization folds into the attention
  (in-kernel on the Pallas path, a fused convert on the gather fallback),
  so quantized pages cut the KV term by 8/kv_bits vs the bf16 pool, which
  is the whole point of the tentpole;
* the decode append rewrites the touched page (the quantized insert
  rescales the page in-register: one page read + one page write per
  layer/slot; the bf16 insert only writes the new row);
* the gather fallback ("xla" mode) walks every block-table slot — MAXB
  pages per slot regardless of live length — while the Pallas kernel
  ("pallas") DMAs only the pages the slot's length covers.

Activations are deliberately excluded: at decode (T=1) they are VMEM/
register-resident between the HBM-counted tensors in the TPU-shaped
program, and the one materialized output (logits) is counted explicitly.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp


def pool_elem_bytes(plan) -> float:
    """Bytes per stored K/V element: code width under `plan.kv_bits`,
    cache dtype width otherwise."""
    kv_bits = int(getattr(plan, "kv_bits", 0) or 0)
    if kv_bits:
        return kv_bits / 8.0
    return float(jnp.dtype(plan.cache_dtype).itemsize)


def weight_stream_bytes(params) -> int:
    """Per-step weight traffic: every leaf streams once. Works on real
    arrays or `jax.eval_shape` structs."""
    return int(sum(math.prod(x.shape) * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree_util.tree_leaves(params)))


def decode_kv_bytes(cfg, plan, *, max_slots: int, block_size: int,
                    max_blocks_per_slot: int, num_blocks: int = 0,
                    mode: str = "xla",
                    live_tokens: Optional[int] = None) -> Dict[str, float]:
    """Per-decode-step KV traffic (bytes), by term.

    "pallas" is the TPU-shaped truth: the kernel DMAs only the live pages'
    codes + scales (bounded by `live_tokens`), and the append touches one
    page per slot. "xla" counts what the gather-fallback program
    *materializes* under the write-once cost model — the same accounting
    `hlo_cost` applies to the compiled decode step, which is what the
    predicted-vs-measured bench row compares against: gather outputs at
    storage width for every table slot, the compute-width attention
    operand the dequant/convert produces, and the insert scatter's
    full-buffer output (XLA scatter writes the whole result tensor;
    `num_blocks` sizes it — required for "xla" mode)."""
    kv_bits = int(getattr(plan, "kv_bits", 0) or 0)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    B, BS, maxb = max_slots, block_size, max_blocks_per_slot
    eb = pool_elem_bytes(plan)
    if mode == "pallas":
        pages = maxb
        if live_tokens is not None:
            pages = min(maxb, max(1, math.ceil(live_tokens / BS)))
        codes = 2.0 * L * B * pages * BS * KV * hd * eb
        scales = 2.0 * L * B * pages * KV * 4.0 if kv_bits else 0.0
        if kv_bits:
            # the quantized append rescales the slot's tail page
            # in-register: page read + page write + its scale row
            append = 2.0 * L * B * 2.0 * (BS * KV * hd * eb + KV * 4.0)
        else:
            append = 2.0 * L * B * KV * hd * eb   # one row per slot
        materialize = 0.0                          # stays in VMEM
    else:
        if not num_blocks:
            raise ValueError("xla mode needs num_blocks (scatter output)")
        # gather output: every table slot, at storage width
        codes = 2.0 * L * B * maxb * BS * KV * hd * eb
        scales = 2.0 * L * B * maxb * KV * 4.0 if kv_bits else 0.0
        # dense attention consumes a compute-width K/V copy (the fused
        # dequant/convert's materialized output)
        cw = 4.0
        materialize = 2.0 * L * B * maxb * BS * KV * hd * cw
        # insert scatter writes the whole pool buffer per layer
        append = 2.0 * L * num_blocks * BS * KV * hd * eb
        if kv_bits:
            append += 2.0 * L * num_blocks * KV * 4.0
    if mode == "xla":
        # the layer scan carries the pool as loop state: the compiled
        # while loop materializes a copy of the carried buffers once per
        # step (visible as copy ops in the lowered program)
        carry = 2.0 * L * num_blocks * BS * KV * hd * eb
        if kv_bits:
            carry += 2.0 * L * num_blocks * KV * 4.0
    else:
        carry = 0.0                                # donated, in-place
    total = codes + scales + append + materialize + carry
    return {"codes": codes, "scales": scales, "append": append,
            "materialize": materialize, "carry": carry, "kv_total": total}


def decode_step_bytes(params, cfg, plan, *, max_slots: int, block_size: int,
                      max_blocks_per_slot: int, num_blocks: int = 0,
                      mode: str = "xla",
                      live_tokens: Optional[int] = None) -> Dict[str, float]:
    """Predicted total HBM bytes for one decode step (all slots), plus the
    per-token figure the roofline quotes."""
    kv = decode_kv_bytes(cfg, plan, max_slots=max_slots,
                         block_size=block_size,
                         max_blocks_per_slot=max_blocks_per_slot,
                         num_blocks=num_blocks, mode=mode,
                         live_tokens=live_tokens)
    weights = float(weight_stream_bytes(params))
    logits = float(max_slots * cfg.vocab_size * 4)
    total = weights + kv["kv_total"] + logits
    out = dict(kv)
    out.update({"weights": weights, "logits": logits, "total": total,
                "per_token": total / max_slots})
    return out


def measured_decode_bytes(rt) -> float:
    """HLO-measured bytes of a runtime's decode program (write-once cost
    model, `roofline.analysis.hlo_cost`). Pass a *fresh* Runtime — this
    lowers+compiles the decode step, which spends its one-trace budget."""
    from repro.roofline.analysis import hlo_cost
    B = rt.serve_cfg.max_slots
    args = (rt.params, rt.pool, jnp.zeros((B, rt.maxb), jnp.int32),
            jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32))
    compiled = rt._decode.lower(*args).compile()
    return float(hlo_cost(compiled.as_text()).bytes_accessed)


def predicted_vs_measured_ratio(params, cfg, plan_bf16, plan_quant, *,
                                max_slots: int, block_size: int,
                                max_blocks_per_slot: int, num_blocks: int,
                                make_runtime) -> Dict[str, float]:
    """The bench gate: predicted vs HLO-measured int8(or 4-bit)-vs-bf16
    decode-step bytes ratio. `make_runtime(plan)` must return a fresh
    Runtime for the given plan (the caller owns ServeConfig choices)."""
    kw = dict(max_slots=max_slots, block_size=block_size,
              max_blocks_per_slot=max_blocks_per_slot,
              num_blocks=num_blocks)
    pred_b = decode_step_bytes(params, cfg, plan_bf16, **kw)["total"]
    pred_q = decode_step_bytes(params, cfg, plan_quant, **kw)["total"]
    meas_b = measured_decode_bytes(make_runtime(plan_bf16))
    meas_q = measured_decode_bytes(make_runtime(plan_quant))
    predicted = pred_b / pred_q
    measured = meas_b / meas_q
    return {"predicted": predicted, "measured": measured,
            "pred_bytes_bf16": pred_b, "pred_bytes_quant": pred_q,
            "meas_bytes_bf16": meas_b, "meas_bytes_quant": meas_q,
            "ratio_of_ratios": predicted / measured}
