"""Trip-count-aware HLO cost model for the roofline analysis.

XLA's built-in `compiled.cost_analysis()` counts while-loop bodies ONCE
(verified experimentally — a 10-step scan reports 1/10 the flops of its
unrolled twin), which would under-report every scanned layer stack by the
layer count. The HLO-text parsing itself lives in `repro.analysis.hlo`
(shared with the compile-contract passes — one parser, two consumers;
`parse_hlo`/`Instr`/`Computation` are re-exported here for back-compat).
This module walks the *optimized post-SPMD per-device* parse, computing
per-computation:

  * dot/convolution flops (2 × output elements × contraction size)
  * bytes accessed (operand + output bytes of memory-relevant ops)
  * collective bytes per primitive (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), using *shard* bytes

and multiplies `while` bodies by their `known_trip_count` backend_config
(emitted by jax.lax.scan/fori_loop), recursing through fusion/call/
conditional. Validated against unrolled references in tests/test_roofline.

Byte model ("write-once"): every top-level (non-fused) tensor counts its
output bytes once; dot/convolution/collective operands add their read
bytes (weights and contraction inputs genuinely re-stream from HBM). Bytes
*inside* fusions never count — on TPU those stay in VMEM/registers. This
deliberately ignores CPU-HLO's smaller fusion granularity, which would
otherwise inflate the memory term with boundaries a TPU compile would fuse.

Hardware model (TPU v5e-class, per assignment):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI (~6 links).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.analysis.hlo import (COLLECTIVES, Computation, Instr,  # noqa: F401
                                _SHAPE_RE, parse_hlo)

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-direction, one link)

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(inst: Instr, comp: Computation) -> float:
    """2 × out_elements × contraction_size (from lhs operand shape)."""
    cm = _CONTRACT_RE.search(inst.text)
    if not cm or not inst.operands:
        return 2.0 * inst.out_elements
    lhs = comp.by_name.get(inst.operands[0])
    if lhs is None:
        return 2.0 * inst.out_elements
    dims_m = _SHAPE_RE.search(_op_shape_text(lhs))
    if not dims_m:
        return 2.0 * inst.out_elements
    dims = [int(d) for d in dims_m.group(2).split(",") if d]
    csize = 1
    for di in cm.group(1).split(","):
        if di != "" and int(di) < len(dims):
            csize *= dims[int(di)]
    return 2.0 * inst.out_elements * csize


def _op_shape_text(inst: Instr) -> str:
    m = re.match(r"([\w\[\]\{\},\d]+)", inst.text)
    return m.group(1) if m else ""


_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs", "floor",
    "round-nearest-even", "round-nearest-afz", "compare", "select", "clamp",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "cbrt",
}


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = \
                self.collective_bytes.get(k, 0.0) + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_MOVE_OPS = {"copy", "transpose", "reshape", "broadcast", "concatenate",
             "slice", "dynamic-slice", "dynamic-update-slice", "gather",
             "pad", "reverse", "convert", "reduce", "scatter", "bitcast",
             "reduce-window", "select-and-scatter", "sort"}


def _operand_bytes(inst: Instr, comp: Computation) -> float:
    return float(sum(comp.by_name[o].out_bytes for o in inst.operands
                     if o in comp.by_name))


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, CostTotals], fused: bool = False
               ) -> CostTotals:
    """fused=True: flops only (internal values never touch HBM)."""
    key = (comp.name, fused)
    if key in memo:
        return memo[key]
    total = CostTotals()
    for inst in comp.instrs:
        if inst.op == "while":
            mult = float(inst.trip_count)
            for cname in inst.called:
                if cname in comps:
                    total.add(_comp_cost(comps[cname], comps, memo, fused),
                              mult)
            continue
        if inst.op in ("call", "conditional", "map", "async-start"):
            for cname in inst.called:
                if cname in comps:
                    total.add(_comp_cost(comps[cname], comps, memo, fused))
            continue
        if inst.op == "fusion":
            for cname in inst.called:
                if cname in comps:
                    total.add(_comp_cost(comps[cname], comps, memo, True))
            if not fused:
                total.bytes_accessed += inst.out_bytes   # write-once model
            continue
        if inst.op == "dot":
            total.flops += _dot_flops(inst, comp)
            if not fused:
                total.bytes_accessed += \
                    inst.out_bytes + _operand_bytes(inst, comp)
        elif inst.op == "convolution":
            total.flops += 2.0 * inst.out_elements
            if not fused:
                total.bytes_accessed += \
                    inst.out_bytes + _operand_bytes(inst, comp)
        elif any(inst.op.startswith(c) for c in COLLECTIVES):
            opname = next(c for c in COLLECTIVES if inst.op.startswith(c))
            in_bytes = _operand_bytes(inst, comp)
            size = max(in_bytes, inst.out_bytes)
            total.collective_bytes[opname] = \
                total.collective_bytes.get(opname, 0.0) + size
            if not fused:
                total.bytes_accessed += in_bytes + inst.out_bytes
        elif inst.op in _ELEMENTWISE_FLOP_OPS:
            total.flops += float(inst.out_elements)
            if not fused:
                total.bytes_accessed += inst.out_bytes
        elif inst.op == "dynamic-update-slice":
            # in-place semantics: traffic = the update slice, not the buffer
            if not fused:
                upd = (comp.by_name[inst.operands[1]].out_bytes
                       if len(inst.operands) > 1 and
                       inst.operands[1] in comp.by_name else inst.out_bytes)
                total.bytes_accessed += upd
        elif inst.op in _MOVE_OPS:
            if not fused:
                total.bytes_accessed += inst.out_bytes
    memo[key] = total
    return total


def hlo_cost(text: str) -> CostTotals:
    comps, entry = parse_hlo(text)
    memo: Dict = {}
    return _comp_cost(comps[entry], comps, memo)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(cost: CostTotals, *, n_chips: int,
                   ici_links: int = 4) -> Dict[str, float]:
    """Seconds per step per the assignment's three-term model. FLOPs/bytes
    from the parsed HLO are *per device* (post-SPMD partitioning)."""
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.bytes_accessed / HBM_BW
    collective_s = cost.total_collective_bytes / (ICI_BW * ici_links)
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }


def analyze_compiled(compiled) -> Dict:
    text = compiled.as_text()
    cost = hlo_cost(text)
    xla = compiled.cost_analysis() or {}
    return {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes_accessed,
        "collective_bytes": {k: v for k, v in cost.collective_bytes.items()},
        "collective_bytes_total": cost.total_collective_bytes,
        "xla_flops_raw": xla.get("flops"),
    }
