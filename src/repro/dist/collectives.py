"""Communication-reducing collectives (used inside `shard_map`-ped code).

* `psum_gram` — the single (m, m) all-reduce that data-parallel COMQ
  calibration needs per tap (DESIGN.md §4.2).
* `compressed_psum` — int8 error-feedback gradient all-reduce: each shard
  quantizes (grad + carried error) onto a shared absmax grid, the psum
  moves int32 code sums instead of f32 values, and the local quantization
  residual is carried into the next step's state so compression error
  never accumulates (1-bit-Adam-style EF; `RunConfig.grad_compression`).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def psum_gram(x: Array, axis_name: str = "data") -> Array:
    """Local features (rows, m) -> replicated Gram H = Σ XᵀX over the axis."""
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return jax.lax.psum(x2.T @ x2, axis_name)


def init_error_state(tree: PyTree) -> PyTree:
    """Zero error-feedback residuals, one per gradient leaf (f32)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compressed_psum(tree: PyTree, axis_name: str, error: PyTree,
                    axis_size: int, bits: int = 8) -> Tuple[PyTree, PyTree]:
    """Mean-reduce `tree` over `axis_name` with int `bits` compression and
    error feedback. Returns (mean_tree, new_error_tree).

    Per leaf: v = g + e is quantized onto a *shared* grid (scale = pmax of
    local absmax / qmax) so the code sums are exact in int32; the mean is
    sum(codes)·scale / axis_size and the local residual v − q·scale is the
    new carried error. On one shard: out + new_e == g exactly (up to f32
    rounding) — compression never loses mass, only delays it.
    """
    qmax = float(2 ** (bits - 1) - 1)

    def one(g: Array, e: Array):
        v = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis_name)
        scale = jnp.maximum(amax / qmax, 1e-30)
        q = jnp.clip(jnp.round(v / scale), -qmax, qmax)
        deq = q * scale
        new_e = v - deq
        out = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(
            jnp.float32) * scale / axis_size
        return out, new_e

    flat, treedef = jax.tree_util.tree_flatten(tree)
    eflat = jax.tree_util.tree_leaves(error)
    outs, errs = zip(*(one(g, e) for g, e in zip(flat, eflat)))
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, errs))
