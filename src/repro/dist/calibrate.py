"""Data-parallel COMQ calibration (DESIGN.md §4.2).

The calibration batch is sharded over the mesh's "data" axis; every tap
forward then runs SPMD on the local shard, and the only communication the
whole pipeline needs is one `psum` of each (m, m) Gram block — solves run
replicated on the maintained-P blocked solver (ROADMAP constraint).

Communication accounting per transformer layer (dense family): 4 taps →
4 Gram all-reduces of m·m f32 ≈ 4·d² + (Hp·hd)² + f² bytes·4, independent
of the number of calibration tokens. Compare the data it replaces: an
all-gather of the (N, m) features would move N·m·4 bytes per tap.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.collectives import psum_gram

Array = jax.Array


def data_mesh(n: Optional[int] = None) -> Mesh:
    """1-axis ("data",) mesh over the first n (default: all) local devices.
    Under XLA_FLAGS=--xla_force_host_platform_device_count=K this is the
    forced-host smoke mesh the multi-device CI job runs on."""
    devices = jax.devices()
    n = n or len(devices)
    return Mesh(np.asarray(devices[:n]).reshape(n), ("data",))


def shard_batch(mesh: Mesh, x: Array) -> Array:
    """Place x with its leading (batch) axis sharded over the "data" axis."""
    ndata = mesh.shape["data"]
    if x.shape[0] % ndata:
        raise ValueError(
            f"batch {x.shape[0]} not divisible by data axis {ndata}")
    return jax.device_put(x, NamedSharding(mesh, P("data")))


@functools.lru_cache(maxsize=8)
def _gram_fn(mesh: Mesh):
    """Jitted shard_map'd Gram, cached per mesh (and per shape via jit):
    the calibration walk calls this once per tap per layer — without the
    cache every call would re-trace the shard_map."""
    return jax.jit(shard_map(lambda t: psum_gram(t, "data"), mesh=mesh,
                             in_specs=P("data"), out_specs=P()))


@functools.lru_cache(maxsize=8)
def _batched_gram_fn(mesh: Mesh):
    def local(t):
        t = t.astype(jnp.float32)
        return jax.lax.psum(jnp.einsum("ecd,ecf->edf", t, t), "data")
    return jax.jit(shard_map(local, mesh=mesh, in_specs=P(None, "data"),
                             out_specs=P()))


def sharded_gram(mesh: Mesh, tap: Array) -> Array:
    """(B, T, d) tap (batch-sharded or not) -> replicated (d, d) Gram.

    shard_map computes the local-shard XᵀX and all-reduces it with a single
    psum — the only cross-device traffic of the calibration walk."""
    if tap.shape[0] % mesh.shape["data"]:
        # batch doesn't divide the axis (e.g. routed expert buffers):
        # fall back to the replicated Gram
        from repro.core.calibrate import gram_from_tap
        return gram_from_tap(tap)
    return _gram_fn(mesh)(tap)


def sharded_batched_gram(mesh: Mesh, tap: Array) -> Array:
    """(E, C, d) stacked-expert tap with the capacity axis sharded ->
    replicated (E, d, d) per-expert Grams, one psum."""
    if tap.shape[1] % mesh.shape["data"]:
        from repro.core.calibrate import batched_gram
        return batched_gram(tap)
    return _batched_gram_fn(mesh)(tap)
