"""Data-parallel COMQ calibration + column-sharded solves (DESIGN.md §4.2/§4.3).

The calibration batch is sharded over the mesh's "data" axis; every tap
forward then runs SPMD on the local shard, and the only communication the
whole pipeline needs is one `psum` of each (m, m) Gram block — solves run
on the maintained-P blocked solver (ROADMAP constraint), either replicated
or, with a nontrivial "model" axis, with W's output columns sharded over
"model" (`sharded_solve`): H is replicated, every per-column operand is
partitioned, and the solve issues zero collectives (the shared greedy
order — the only column-coupled quantity — is precomputed on the full W
and passed in replicated).

Communication accounting per transformer layer (dense family): 4 taps →
4 Gram all-reduces of m·m f32 ≈ 4·d² + (Hp·hd)² + f² bytes·4, independent
of the number of calibration tokens. Compare the data it replaces: an
all-gather of the (N, m) features would move N·m·4 bytes per tap.

Both wire invariants are *checked against compiled HLO*, not just
documented: the analysis gate (`repro.analysis.registry`) holds the
`dist.gram` contract to exactly one all-reduce and the `dist.solve`
contract to zero collectives, and tests/test_dist.py re-asserts them via
`repro.analysis.check_lowered` on the local mesh.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.collectives import psum_gram

Array = jax.Array

# obs hook (DESIGN.md §10): fires once per Gram psum with the all-reduced
# byte count, derived from static shapes on the host — no device sync and
# no cost when unset. The pipeline installs a metrics-counter callback
# here for the run's duration (`dist.bytes_all_reduced`).
_allreduce_observer = None


def set_allreduce_observer(cb):
    """Install `cb(n_bytes)` (or None to clear); returns the previous
    observer so callers can restore it."""
    global _allreduce_observer
    prev = _allreduce_observer
    _allreduce_observer = cb
    return prev


def data_mesh(n: Optional[int] = None) -> Mesh:
    """1-axis ("data",) mesh over the first n (default: all) local devices.
    Under XLA_FLAGS=--xla_force_host_platform_device_count=K this is the
    forced-host smoke mesh the multi-device CI job runs on."""
    devices = jax.devices()
    n = n or len(devices)
    return Mesh(np.asarray(devices[:n]).reshape(n), ("data",))


def calib_mesh(model: int = 1, data: Optional[int] = None) -> Mesh:
    """("data", "model") calibration mesh: the batch (and Gram psum) use
    "data"; solve columns shard over "model" (`sharded_solve`). With
    data=None the data axis takes all devices the model axis leaves."""
    devices = jax.devices()
    n = len(devices)
    if model < 1 or n % model:
        raise ValueError(f"model axis {model} must divide {n} devices")
    data = n // model if data is None else data
    if data < 1 or data * model > n:
        raise ValueError(f"mesh ({data}, {model}) needs {data * model} "
                         f"devices, have {n}")
    return Mesh(np.asarray(devices[:data * model]).reshape(data, model),
                ("data", "model"))


def model_size(mesh: Optional[Mesh]) -> int:
    return 1 if mesh is None else int(mesh.shape.get("model", 1))


def shard_batch(mesh: Mesh, x: Array) -> Array:
    """Place x with its leading (batch) axis sharded over the "data" axis."""
    ndata = mesh.shape["data"]
    if x.shape[0] % ndata:
        raise ValueError(
            f"batch {x.shape[0]} not divisible by data axis {ndata}")
    return jax.device_put(x, NamedSharding(mesh, P("data")))


@functools.lru_cache(maxsize=8)
def _gram_fn(mesh: Mesh):
    """Jitted shard_map'd Gram, cached per mesh (and per shape via jit):
    the calibration walk calls this once per tap per layer — without the
    cache every call would re-trace the shard_map."""
    return jax.jit(shard_map(lambda t: psum_gram(t, "data"), mesh=mesh,
                             in_specs=P("data"), out_specs=P()))


@functools.lru_cache(maxsize=8)
def _batched_gram_fn(mesh: Mesh):
    def local(t):
        t = t.astype(jnp.float32)
        return jax.lax.psum(jnp.einsum("ecd,ecf->edf", t, t), "data")
    return jax.jit(shard_map(local, mesh=mesh, in_specs=P(None, "data"),
                             out_specs=P()))


def sharded_gram(mesh: Mesh, tap: Array) -> Array:
    """(B, T, d) tap (batch-sharded or not) -> replicated (d, d) Gram.

    shard_map computes the local-shard XᵀX and all-reduces it with a single
    psum — the only cross-device traffic of the calibration walk."""
    if tap.shape[0] % mesh.shape["data"]:
        # batch doesn't divide the axis: fall back to the replicated Gram
        warnings.warn(
            f"sharded_gram: tap batch {tap.shape[0]} does not divide the "
            f"data axis {mesh.shape['data']}; falling back to the "
            "replicated Gram (no psum) for this tap", stacklevel=2)
        from repro.core.calibrate import gram_from_tap
        return gram_from_tap(tap)
    h = _gram_fn(mesh)(tap)
    if _allreduce_observer is not None:
        _allreduce_observer(int(h.shape[0]) * int(h.shape[1]) * 4)
    return h


def sharded_batched_gram(mesh: Mesh, tap: Array) -> Array:
    """(E, C, d) stacked-expert tap with the capacity axis sharded ->
    replicated (E, d, d) per-expert Grams, one psum.

    The capacity axis must divide the data axis — `quantize_model` aligns
    MoE routing capacity via BuildPlan.moe_capacity_multiple precisely so
    expert taps never take the replicated fallback; if one still does
    (e.g. a hand-built tap), warn rather than silently leaving the psum
    path."""
    if tap.shape[1] % mesh.shape["data"]:
        warnings.warn(
            f"sharded_batched_gram: expert capacity {tap.shape[1]} does not "
            f"divide the data axis {mesh.shape['data']}; falling back to "
            "the replicated per-expert Gram (no psum). Align the routing "
            "capacity (BuildPlan.moe_capacity_multiple) to stay on the "
            "psum path.", stacklevel=2)
        from repro.core.calibrate import batched_gram
        return batched_gram(tap)
    hs = _batched_gram_fn(mesh)(tap)
    if _allreduce_observer is not None:
        _allreduce_observer(int(hs.shape[0]) * int(hs.shape[1])
                            * int(hs.shape[2]) * 4)
    return hs


# ---------------------------------------------------------------------------
# column-sharded solves (DESIGN.md §4.3)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _solve_fn(mesh: Mesh, spec, method: str, block: int):
    """Jitted shard_map'd column-sharded solve, cached per
    (mesh, spec, method, block); jit caches per operand shape.

    The local function runs the *unmodified* solver on this shard's column
    slice — bit-identical to the replicated solve because every operand it
    touches is column-offset-invariant (the shared visit order arrives
    precomputed via `perm`). It also computes the per-column squared
    errors for reporting (one local H·R matmul each for the RTN init and
    the final codes), so nothing downstream needs the solver's scalar
    error trajectory — the shard_map body contains zero collectives."""
    from repro.core.baselines import rtn_quantize
    from repro.core.comq_hessian import comq_quantize_blocked
    from repro.core.pipeline import _col_err2
    from repro.dist.sharding import solver_specs

    def local(h, w, perm):
        if method == "comq_blocked":
            r = comq_quantize_blocked(h, w, spec, block=block, perm=perm)
        elif method == "rtn":
            r = rtn_quantize(w, spec, h=h)
        else:
            raise ValueError(f"method {method!r} is not column-shardable")
        wq = r.q.astype(jnp.float32) * r.delta
        e2_after = _col_err2(h, w, wq)
        rt = rtn_quantize(w, spec)
        e2_before = _col_err2(h, w, rt.q.astype(jnp.float32) * rt.delta)
        return r.q, r.delta, r.z_lo, e2_before, e2_after

    s = solver_specs(mesh)
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(s["h"], s["w"], s["perm"]),
        out_specs=(s["q"], s["delta"], s["z"], s["col_err2"],
                   s["col_err2"]),
        check_rep=False))


def sharded_solve(mesh: Mesh, h: Array, w2d: Array, spec, method: str,
                  block: int = 256):
    """Column-sharded COMQ solve: W's output columns partition over the
    "model" axis; H and the shared visit order are replicated; the solve
    issues no collectives (asserted in tests on the compiled HLO).

    Returns (q, delta, z_lo, e2_before, e2_after) with the column-
    partitioned outputs still sharded — callers slice them per leaf
    exactly like the fused replicated path. Columns are zero-padded up to
    a multiple of the model axis (trailing pad; column independence makes
    the shard assignment irrelevant to bit-identity) and stripped before
    returning.

    Per-leaf mixed-precision policies pass each leaf's *resolved* spec
    here (the pipeline no longer binds one global spec): _solve_fn caches
    one compiled shard_map per distinct (mesh, spec, method, block), so a
    first/bulk/last bit mix costs a handful of cache entries, and every
    leaf's sharded solve stays bit-identical to its replicated solve at
    its own width (tested on the forced (2, 4) mesh).
    """
    from repro.core.comq_hessian import shared_order
    from repro.models.common import pad_to_multiple

    tp = model_size(mesh)
    h = h.astype(jnp.float32)
    w2d = w2d.astype(jnp.float32)
    n = w2d.shape[1]
    n_pad = pad_to_multiple(n, tp)
    wp = (jnp.pad(w2d, ((0, 0), (0, n_pad - n))) if n_pad != n else w2d)
    if method == "comq_blocked":
        # the one column-coupled quantity, computed once on the full W —
        # from the *unpadded* columns so the order (and therefore every
        # code) matches the replicated solve exactly
        perm = shared_order(h, w2d, spec)
    else:
        perm = jnp.arange(h.shape[0], dtype=jnp.int32)
    q, delta, z_lo, e2b, e2a = _solve_fn(mesh, spec, method, block)(
        h, wp, perm)
    if n_pad != n:
        q, delta, z_lo = q[:, :n], delta[:n], z_lo[:n]
        e2b, e2a = e2b[:n], e2a[:n]
    return q, delta, z_lo, e2b, e2a
