"""Mesh partition-spec assignment for params, inputs, and caches.

Layout (see DESIGN.md §4): Megatron-TP over the "model" axis (q/o heads,
FFN hidden, vocab, MoE experts) + FSDP over the "data" axis on the
remaining large dim; the multi-pod mesh adds a leading "pod" axis that
only ever carries batch. Every sharded dim is divisibility-checked —
a dim the axis doesn't divide is replicated instead, so padded smoke
configs lower on any mesh.

The models never import this module: the launcher injects the activation
constraints through `BuildPlan.constrain` (`make_constrain`).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def tp_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def dp_size(mesh: Mesh) -> int:
    n = int(mesh.shape.get("data", 1))
    return n * int(mesh.shape.get("pod", 1))


def batch_axes(mesh: Mesh):
    """The mesh axes a batch dim shards over: ("pod","data") or "data"."""
    if "pod" in mesh.shape:
        return ("pod", "data")
    return "data"


def batch_dim_spec(mesh: Mesh, global_batch: int):
    """PartitionSpec *entry* for a batch dim (None when it doesn't divide)."""
    b = batch_axes(mesh)
    return b if global_batch % dp_size(mesh) == 0 else None


def solver_specs(mesh: Mesh) -> Dict[str, P]:
    """PartitionSpecs for the column-sharded COMQ solve (DESIGN.md §4.3).

    Per-channel COMQ is column-separable given H: every per-column operand
    — W, the residual R, the maintained product P = H·R, HW, codes Q, and
    the per-column grids (δ, z_lo, z_hi) — partitions over the "model" axis
    along the output-column dim, while H (m, m) and the shared visit order
    stay replicated. The solve itself then needs zero communication: the
    only collective in the whole calibration path remains the Gram psum
    over "data"."""
    return {
        "h": P(),                    # (m, m) Gram — replicated
        "perm": P(),                 # (m,) shared visit order — replicated
        "w": P(None, "model"),       # (m, n) weight columns
        "q": P(None, "model"),       # (m, n) bit-codes
        "delta": P("model"),         # (n,) per-column scales
        "z": P("model"),             # (n,) per-column zero-points
        "col_err2": P("model"),      # (n,) per-column squared errors
    }


def named(mesh: Mesh, specs: PyTree) -> PyTree:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def _axis_if(dim: int, axis, size: int):
    return axis if size > 1 and dim % size == 0 else None


# per-leaf TP rules: leaf name -> (tp_dim_from_end, fsdp_dim_from_end).
# Dims count from the *end* so leading layer-stack dims stay replicated.
# wq (d, Hp, hd): heads on TP, d on FSDP. wo (Hp, hd, d): heads TP, d FSDP.
# FFN up-projections shard the hidden f on TP, d on FSDP; down-projections
# the mirror. MoE experts shard E on TP (EP); RWKV/SSM follow the same
# up/down pattern. wk/wv stay TP-replicated (n_kv_heads < model axis —
# see models/attention.py "KV replication").
_TP_RULES: Dict[str, Tuple[int, int]] = {
    "wq": (2, 3), "wo": (3, 1),
    "w_gate": (1, 2), "w_up": (1, 2), "w_down": (2, 1),
    "w_r": (1, 2), "w_k": (1, 2), "w_v": (2, 1), "w_g": (1, 2),
    "w_o": (1, 2), "w_in": (1, 2), "w_out": (1, 2),
    "unembed": (1, 2), "cls_head": (1, 2), "vision_proj": (1, 2),
}
_MOE_RULES: Dict[str, Tuple[int, int]] = {
    "w_gate": (3, 2), "w_up": (3, 2), "w_down": (3, 2),
}


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh) -> P:
    tp, dp = tp_size(mesh), int(mesh.shape.get("data", 1))
    name = path[-1] if path else ""
    ndim = len(shape)
    spec = [None] * ndim
    in_moe = "moe" in path
    rules = _MOE_RULES if in_moe and name in _MOE_RULES else _TP_RULES
    if name == "embed" and ndim >= 2:
        # vocab rows on TP (padded to 256-multiples), d on FSDP
        spec[-2] = _axis_if(shape[-2], "model", tp)
        spec[-1] = _axis_if(shape[-1], "data", dp)
        return P(*spec)
    if name in rules and ndim >= rules[name][0]:
        tdim, fdim = rules[name]
        spec[-tdim] = _axis_if(shape[-tdim], "model", tp)
        if ndim >= fdim and fdim != tdim:
            spec[-fdim] = _axis_if(shape[-fdim], "data", dp)
        return P(*spec)
    # fallback: FSDP-shard the last dim of anything big, replicate the rest
    if ndim >= 1 and shape[-1] >= 1024:
        spec[-1] = _axis_if(shape[-1], "data", dp)
    return P(*spec)


def _walk_specs(tree, mesh, path=()):
    if isinstance(tree, dict):
        return {k: _walk_specs(v, mesh, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_walk_specs(v, mesh, path) for v in tree)
    return _leaf_spec(path, tuple(tree.shape), mesh)


def param_specs(params_shape: PyTree, mesh: Mesh) -> PyTree:
    """Megatron-TP + FSDP PartitionSpecs for a param pytree (by leaf name,
    divisibility-checked; layer-stack leading dims replicated)."""
    return _walk_specs(params_shape, mesh)


def input_batch_specs(specs: PyTree, mesh: Mesh,
                      global_batch: int) -> PyTree:
    """Shard every input's leading batch dim over the batch axes."""
    b = batch_dim_spec(mesh, global_batch)

    def one(s):
        if s.ndim == 0:
            return P()
        return P(*((b,) + (None,) * (s.ndim - 1)))

    return jax.tree_util.tree_map(one, specs)


def cache_specs(cache_shape: PyTree, mesh: Mesh, global_batch: int) -> PyTree:
    """Decode/prefill cache specs: the batch dim (located by size — caches
    carry leading layer-stack dims) shards over the batch axes; the head
    dim shards over "model" when the kv-head count itself doesn't divide
    (RoPE uses adjacent pairs precisely so head_dim can split — see
    models/common.py)."""
    b = batch_dim_spec(mesh, global_batch)
    tp = tp_size(mesh)

    def one(s):
        spec = [None] * s.ndim
        for i, d in enumerate(s.shape):
            if d == global_batch and b is not None:
                spec[i] = b
                break
        if s.ndim >= 2:
            # (..., KV, hd) tail: prefer KV on model, else split head_dim
            kv, hd = s.shape[-2], s.shape[-1]
            if kv % tp == 0 and tp > 1 and spec[-2] is None:
                spec[-2] = "model"
            elif hd % tp == 0 and tp > 1 and hd >= 2 * tp:
                spec[-1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map(one, cache_shape)


def paged_runtime_specs(pool: PyTree, mesh: Mesh, max_slots: int,
                        num_blocks: int) -> Dict[str, Any]:
    """Specs for the TP-sharded paged serving runtime (DESIGN.md §11).

    Slot+page parallelism over "model": the pool's page dim (dim 1 of
    every leaf — codes (L, NB, BS, KV, hd) and scales (L, NB, KV) alike)
    shards together with the batch dim of every per-slot operand, and the
    partitioned `BlockAllocator` only ever hands a slot pages from its own
    partition. Each shard therefore decodes its own slots against its own
    pages: the decode step is pure local compute — zero collectives, pool
    donated — which is what the `serve.decode_step` contract gate checks.
    (Head-TP decode could not satisfy that: the wo contraction over
    sharded heads forces a psum every step.)"""
    tp = tp_size(mesh)
    if num_blocks % tp != 0 or max_slots % tp != 0:
        raise ValueError(
            f"TP paged runtime needs num_blocks ({num_blocks}) and "
            f"max_slots ({max_slots}) divisible by the model axis ({tp})")

    def one(x):
        return P(*([None, "model"] + [None] * (x.ndim - 2)))

    return {
        "pool": jax.tree_util.tree_map(one, pool),
        "bt": P("model", None),       # (max_slots, maxb) block tables
        "tok": P("model", None),      # (max_slots, 1) last tokens
        "pos": P("model"),            # (max_slots,) write positions
        "logits": P("model", None),   # (max_slots, V) decode outputs
    }


def make_constrain(mesh: Mesh, global_batch: int, *, seq_shard: bool = False,
                   block_gather: bool = False, ffn_shard: bool = False):
    """Activation-sharding callback for `BuildPlan.constrain`.

    kinds: "residual" (B,T,d) — batch over data, seq over model under SP;
    "block_in" — the Megatron-SP gather point entering a block (seq
    replicated unless block_gather keeps it sharded); "logits" (B,T,V) —
    vocab over model; "ffn_hidden" (B,T,f) — hidden over model when
    ffn_shard; "kv_cache" — cache pytree via `cache_specs`.
    """
    b = batch_dim_spec(mesh, global_batch)
    tp = tp_size(mesh)

    def cst(x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def constrain(x, kind: str):
        if kind == "kv_cache":
            return jax.tree_util.tree_map(
                cst, x, cache_specs(jax.eval_shape(lambda: x), mesh,
                                    global_batch))
        if kind == "residual":
            seq = "model" if seq_shard and x.shape[1] % tp == 0 else None
            return cst(x, P(b, seq, None))
        if kind == "block_in":
            if seq_shard and not block_gather:
                return cst(x, P(b, None, None))     # SP gather
            return x
        if kind == "logits":
            return cst(x, P(b, None, _axis_if(x.shape[-1], "model", tp)))
        if kind == "ffn_hidden":
            if ffn_shard:
                return cst(x, P(b, None, _axis_if(x.shape[-1], "model", tp)))
            return x
        return x

    return constrain
