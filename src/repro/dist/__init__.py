"""Distributed pieces: collectives, data-parallel calibration, and the
mesh partition-spec helpers used by the launchers (DESIGN.md §4).

Everything here is mesh-mechanics only — the math stays in `core/` and the
models stay mesh-agnostic (they only see a `BuildPlan.constrain` callback).
"""
from repro.dist.calibrate import (calib_mesh, data_mesh,  # noqa: F401
                                  model_size, shard_batch,
                                  sharded_batched_gram, sharded_gram,
                                  sharded_solve)
from repro.dist.collectives import (compressed_psum,  # noqa: F401
                                    init_error_state, psum_gram)
