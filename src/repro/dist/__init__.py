"""Distributed pieces: collectives, data-parallel calibration, and the
mesh partition-spec helpers used by the launchers (DESIGN.md §4).

Everything here is mesh-mechanics only — the math stays in `core/` and the
models stay mesh-agnostic (they only see a `BuildPlan.constrain` callback).
"""
from repro.dist.calibrate import (data_mesh, shard_batch,  # noqa: F401
                                  sharded_batched_gram, sharded_gram)
from repro.dist.collectives import (compressed_psum,  # noqa: F401
                                    init_error_state, psum_gram)
