"""Feed-forward blocks: gated (llama-style), plain (musicgen/ViT), and the
RWKV squared-relu channel mix lives in rwkv.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Array, act_fn, dense_init


def init_mlp(key: Array, cfg, stack=()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu_mlp":   # plain 2-matrix MLP
        return {"w_up": dense_init(ks[0], (*stack, d, f)),
                "w_down": dense_init(ks[1], (*stack, f, d))}
    return {"w_gate": dense_init(ks[0], (*stack, d, f)),
            "w_up": dense_init(ks[1], (*stack, d, f)),
            "w_down": dense_init(ks[2], (*stack, f, d))}


def apply_mlp(p: dict, x: Array, cfg, taps=None, constrain=None,
              quantize_cb=None) -> Array:
    cd = x.dtype
    act = act_fn(cfg.act)
    if taps is not None:
        taps["mlp_in"] = x        # feeds w_gate / w_up
        if quantize_cb is not None:
            p = {**p, **quantize_cb("mlp_in")}
    if "w_gate" in p:
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(cd))
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(cd))
        h = act(g) * u
    else:
        h = act(jnp.einsum("btd,df->btf", x, p["w_up"].astype(cd)))
    if constrain is not None:
        h = constrain(h, "ffn_hidden")
    if taps is not None:
        taps["down_in"] = h       # feeds w_down
        if quantize_cb is not None:
            p = {**p, **quantize_cb("down_in")}
    return jnp.einsum("btf,fd->btd", h, p["w_down"].astype(cd))
