"""Feed-forward blocks: gated (llama-style), plain (musicgen/ViT), and the
RWKV squared-relu channel mix lives in rwkv.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Array, act_fn, dense_init


def init_mlp(key: Array, cfg, stack=()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu_mlp":   # plain 2-matrix MLP
        return {"w_up": dense_init(ks[0], (*stack, d, f)),
                "w_down": dense_init(ks[1], (*stack, f, d))}
    return {"w_gate": dense_init(ks[0], (*stack, d, f)),
            "w_up": dense_init(ks[1], (*stack, d, f)),
            "w_down": dense_init(ks[2], (*stack, f, d))}


def _ff(w, x: Array, cd) -> Array:
    """(B, T, a) · w(a, b) -> (B, T, b); dense einsum or, for a fused-layout
    QT leaf (2D codes, per-column scale), the dequant-fused GEMM."""
    from repro.core.apply import is_qt, qt_linear
    if is_qt(w):
        B, T, a = x.shape
        return qt_linear(w, x.reshape(B * T, a), out_dtype=cd).reshape(
            B, T, -1)
    return jnp.einsum("btd,df->btf", x, w.astype(cd))


def apply_mlp(p: dict, x: Array, cfg, taps=None, constrain=None,
              quantize_cb=None) -> Array:
    cd = x.dtype
    act = act_fn(cfg.act)
    if taps is not None:
        taps["mlp_in"] = x        # feeds w_gate / w_up
        if quantize_cb is not None:
            p = {**p, **quantize_cb("mlp_in")}
    if "w_gate" in p:
        g = _ff(p["w_gate"], x, cd)
        u = _ff(p["w_up"], x, cd)
        h = act(g) * u
    else:
        h = act(_ff(p["w_up"], x, cd))
    if constrain is not None:
        h = constrain(h, "ffn_hidden")
    if taps is not None:
        taps["down_in"] = h       # feeds w_down
        if quantize_cb is not None:
            p = {**p, **quantize_cb("down_in")}
    return _ff(p["w_down"], h, cd)
