"""Grouped-query attention with TPU-friendly structure.

Key design points (see DESIGN.md §4):

* **Head padding.** The production mesh has a 16-way model axis; q-heads are
  padded up to a multiple of the TP degree (qwen2 28->32, hymba 25->32,
  granite 24->32). Padded heads use zeroed projections and map to kv head 0.
  The waste shows up honestly in the HLO-flops/model-flops ratio.
* **KV replication.** n_kv_heads is 4-8 for most archs — smaller than the
  model axis — so K/V projections are computed replicated across the model
  axis (their weights are FSDP-sharded on the data axis only). GQA expansion
  is a static gather `k[:, :, head_to_kv, :]`, which SPMD keeps local.
* **Block-causal flash attention** implemented as a `lax.scan` over the
  *static list of lower-triangular (q-block, kv-block) pairs* with an online
  softmax carry. Unlike a dense mask, no flops are spent above the diagonal,
  so HLO flops match the true causal cost; unlike a nested q/kv scan there
  is one rolled loop (small HLO). Sliding-window archs restrict the pair
  list to the diagonal band — again at zero masked-block cost.
* **Decode** attends over the full (or ring-buffer) cache with a position
  mask; softmax/contract reductions over the sequence-sharded cache dim
  lower to small per-head collectives under SPMD.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (Array, apply_rope, dense_init, zeros_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_param_shapes(cfg, n_heads_padded: int, q_in: Optional[int] = None,
                      kv_in: Optional[int] = None) -> dict:
    d, hd, kv = cfg.d_model, cfg.resolved_head_dim, cfg.n_kv_heads
    q_in = q_in or d
    kv_in = kv_in or d
    shapes = {
        "wq": (q_in, n_heads_padded, hd),
        "wk": (kv_in, kv, hd),
        "wv": (kv_in, kv, hd),
        "wo": (n_heads_padded, hd, d),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (n_heads_padded, hd), "bk": (kv, hd),
                       "bv": (kv, hd)})
    return shapes


def init_attn(key: Array, cfg, n_heads_padded: int, stack: Tuple[int, ...] = (),
              q_in: Optional[int] = None, kv_in: Optional[int] = None) -> dict:
    shapes = attn_param_shapes(cfg, n_heads_padded, q_in, kv_in)
    keys = jax.random.split(key, len(shapes))
    out = {}
    for (name, shp), k in zip(sorted(shapes.items()), keys):
        if name.startswith("b"):
            out[name] = zeros_init(k, (*stack, *shp))
        else:
            out[name] = dense_init(k, (*stack, *shp))
    return out


def head_to_kv_map(n_heads: int, n_heads_padded: int, n_kv: int) -> Array:
    """Static q-head -> kv-head index map.

    When the padded head count divides evenly into kv groups we use the
    uniform grouping h -> h // (Hp/KV): this makes the GQA contraction a
    reshape + grouped einsum (no materialized K/V expansion). Otherwise
    (hymba: 32 padded q heads over 5 kv) fall back to floor mapping with
    padded heads parked on kv 0."""
    if n_heads_padded % n_kv == 0:
        return jnp.arange(n_heads_padded) // (n_heads_padded // n_kv)
    q_per_kv = max(n_heads // n_kv, 1)
    idx = jnp.arange(n_heads_padded) // q_per_kv
    idx = jnp.where(jnp.arange(n_heads_padded) < n_heads,
                    jnp.minimum(idx, n_kv - 1), 0)
    return idx


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def _project_in(w, x: Array, cd) -> Array:
    """(B, T, d) · w -> (B, T, H, hd); w dense (d, H, hd) or a fused-layout
    QT whose codes are (d, H·hd) — routed through the dequant-fused GEMM
    (repro.kernels.ops.quant_matmul) so decode streams int4/int8 codes."""
    from repro.core.apply import is_qt, qt_linear, qt_out_dims
    if is_qt(w):
        B, T, d = x.shape
        y = qt_linear(w, x.reshape(B * T, d), out_dtype=cd)
        return y.reshape(B, T, *qt_out_dims(w))
    return jnp.einsum("btd,dhk->bthk", x, w.astype(cd))


def qkv_project(p: dict, x: Array, kv_x: Optional[Array] = None):
    """x: (B, T, d) -> q (B,T,Hp,hd), k/v (B,T,KV,hd)."""
    kv_x = x if kv_x is None else kv_x
    cd = x.dtype
    q = _project_in(p["wq"], x, cd)
    k = _project_in(p["wk"], kv_x, cd)
    v = _project_in(p["wv"], kv_x, cd)
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def out_project(p: dict, o: Array) -> Array:
    from repro.core.apply import is_qt, qt_linear, qt_out_dims
    w = p["wo"]
    if is_qt(w):
        B, T, H, hd = o.shape
        y = qt_linear(w, o.reshape(B * T, H * hd), out_dtype=o.dtype)
        return y.reshape(B, T, *qt_out_dims(w))
    return jnp.einsum("bthk,hkd->btd", o, w.astype(o.dtype))


# ---------------------------------------------------------------------------
# block-causal flash attention (training / prefill)
# ---------------------------------------------------------------------------

def _tril_pairs(n_blocks: int, band: Optional[int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static (i, j) lower-triangle block pairs; band limits |i-j| for SWA."""
    pi, pj = [], []
    for i in range(n_blocks):
        j0 = 0 if band is None else max(0, i - band)
        for j in range(j0, i + 1):
            pi.append(i)
            pj.append(j)
    return jnp.asarray(pi, jnp.int32), jnp.asarray(pj, jnp.int32)


def pick_block_size(seq_len: int, target: int = 512) -> int:
    c = min(target, seq_len)
    while seq_len % c:
        c //= 2
    return max(c, 1)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_size"))
def flash_attention(q: Array, k: Array, v: Array, head_map: Array, *,
                    causal: bool = True, window: int = 0,
                    block_size: int = 512) -> Array:
    """q: (B,T,Hp,hd); k,v: (B,T,KV,hd). Returns (B,T,Hp,hd).

    Scan over static lower-triangular block pairs with an online-softmax
    carry. `window > 0` enables sliding-window masking and prunes the pair
    list to the diagonal band. GQA: when Hp divides into KV groups the
    contraction is a grouped einsum (K/V never materialize per-q-head);
    otherwise a static gather expands K/V (hymba's 5-kv case).

    Jitted at definition (static mask config): eager callers — the staged
    calibration walk quantizes mid-forward and therefore runs un-jitted at
    the layer level — hit the jit cache instead of retracing the pair scan
    per call; jitted callers inline it as before.
    """
    B, T, H, hd = q.shape
    KV = k.shape[2]
    if not causal:
        return _dense_attention(q, k, v, head_map, causal=False, window=0)
    C = pick_block_size(T, block_size)
    n = T // C
    band = None if window <= 0 else (window + C - 1) // C
    pi, pj = _tril_pairs(n, band)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    grouped = (H % KV == 0)
    if grouped:
        G = H // KV
        qb = q.reshape(B, n, C, KV, G, hd)
        kb = k.reshape(B, n, C, KV, hd)
        vb = v.reshape(B, n, C, KV, hd)
        return _flash_grouped(qb, kb, vb, pi, pj, scale, C, window, B, T, H,
                              hd, q.dtype)
    k = k[:, :, head_map, :]                      # (B, T, Hp, hd) gqa-expand
    v = v[:, :, head_map, :]
    qb = q.reshape(B, n, C, H, hd)
    kb = k.reshape(B, n, C, H, hd)
    vb = v.reshape(B, n, C, H, hd)

    o0 = jnp.zeros((B, n, C, H, hd), jnp.float32)
    m0 = jnp.full((B, n, H, C), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, n, H, C), jnp.float32)

    def step(carry, ij):
        o, m, l = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        s = jnp.einsum("bchk,bshk->bhcs", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        # 2D additive bias (pre-broadcast) so loop-invariant hoisting stays
        # (n_pairs, C, C) instead of materializing (n_pairs, B, H, C, C)
        qpos = i * C + jnp.arange(C)
        kpos = j * C + jnp.arange(C)
        mask = qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= qpos[:, None] - kpos[None, :] < window
        bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
        s = s + bias[None, None]

        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)  # (B,H,C)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, i, 1, keepdims=False)  # (B,C,H,hd)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])                 # (B,H,C,S)
        corr = jnp.exp(mi - m_new)                        # (B,H,C)
        l_new = li * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhcs,bshk->bchk", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        o_new = oi * corr.transpose(0, 2, 1)[..., None] + pv
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (o, m, l), None

    # remat the step: the backward recomputes scores/p per block instead of
    # saving (n_pairs × B × H × C × C) f32 residuals — the flash-attention
    # backward memory policy.
    step = jax.checkpoint(step)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), (pi, pj))
    l = jnp.maximum(l, 1e-20)
    out = o / l.transpose(0, 1, 3, 2)[..., None]
    return out.reshape(B, T, H, hd).astype(q.dtype)


def _flash_grouped(qb, kb, vb, pi, pj, scale, C, window, B, T, H, hd, dtype):
    """Grouped-GQA flash pair-scan: qb (B,n,C,KV,G,hd); kb/vb (B,n,C,KV,hd)."""
    n = qb.shape[1]
    KV, G = qb.shape[3], qb.shape[4]
    o0 = jnp.zeros((B, n, C, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, n, KV, G, C), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, n, KV, G, C), jnp.float32)

    def step(carry, ij):
        o, m, l = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        s = jnp.einsum("bckgh,bskh->bkgcs", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        qpos = i * C + jnp.arange(C)
        kpos = j * C + jnp.arange(C)
        mask = qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= qpos[:, None] - kpos[None, :] < window
        bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
        s = s + bias[None, None, None]

        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))           # (B,KV,G,C)
        p = jnp.exp(s - m_new[..., None])                 # (B,KV,G,C,S)
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgcs,bskh->bckgh", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        o_new = oi * corr.transpose(0, 3, 1, 2)[..., None] + pv
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (o, m, l), None

    step = jax.checkpoint(step)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), (pi, pj))
    l = jnp.maximum(l, 1e-20)
    out = o / l.transpose(0, 1, 4, 2, 3)[..., None]
    return out.reshape(B, T, H, hd).astype(dtype)


def _dense_attention(q: Array, k: Array, v: Array, head_map: Array, *,
                     causal: bool, window: int,
                     q_positions: Optional[Array] = None,
                     kv_positions: Optional[Array] = None,
                     kv_valid: Optional[Array] = None) -> Array:
    """Reference/dense path: encoders, cross-attn, decode-over-cache.

    kv_positions/kv_valid: (B, S) absolute positions + validity for masking
    (ring buffers); q_positions: (B, Tq). Grouped GQA einsum when possible
    (no K/V expansion in memory)."""
    B, Tq, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    grouped = H % KV == 0
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    if grouped:
        G = H // KV
        qg = q.reshape(B, Tq, KV, G, hd)
        s = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                       preferred_element_type=jnp.float32) * scale
    else:
        k = k[:, :, head_map, :]
        v = v[:, :, head_map, :]
        s = jnp.einsum("bthk,bshk->bhts", q, k,
                       preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((B, 1, Tq, S), bool)
    if causal:
        qp = (q_positions if q_positions is not None
              else jnp.broadcast_to(jnp.arange(Tq), (B, Tq)))
        kp = (kv_positions if kv_positions is not None
              else jnp.broadcast_to(jnp.arange(S), (B, S)))
        mask &= qp[:, None, :, None] >= kp[:, None, None, :]
        if window > 0:
            mask &= qp[:, None, :, None] - kp[:, None, None, :] < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, :]
    if grouped:
        s = jnp.where(mask[:, :, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgts,bskh->btkgh", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, Tq, H, hd).astype(q.dtype)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bshk->bthk", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (supports full caches and SWA ring buffers)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array          # (B, S_cache, KV, hd) — rope pre-applied
    v: Array          # (B, S_cache, KV, hd)
    pos: Array        # (B, S_cache) absolute positions, -1 = empty
    # int8 cache mode: k/v hold int8 codes, scales are per-entry absmax/127
    k_scale: Optional[Array] = None   # (B, S_cache, KV)
    v_scale: Optional[Array] = None


def init_kv_cache(batch: int, cache_len: int, n_kv: int, hd: int,
                  dtype=jnp.bfloat16, quantized: bool = False) -> KVCache:
    if quantized:
        return KVCache(
            k=jnp.zeros((batch, cache_len, n_kv, hd), jnp.int8),
            v=jnp.zeros((batch, cache_len, n_kv, hd), jnp.int8),
            pos=jnp.full((batch, cache_len), -1, jnp.int32),
            k_scale=jnp.zeros((batch, cache_len, n_kv), jnp.float32),
            v_scale=jnp.zeros((batch, cache_len, n_kv), jnp.float32),
        )
    return KVCache(
        k=jnp.zeros((batch, cache_len, n_kv, hd), dtype),
        v=jnp.zeros((batch, cache_len, n_kv, hd), dtype),
        pos=jnp.full((batch, cache_len), -1, jnp.int32),
    )


def _q8_kv(x: Array):
    """(..., hd) -> int8 codes + per-vector scale."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8_kv(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_insert(cache: KVCache, k_new: Array, v_new: Array,
                 pos: Array) -> KVCache:
    """Insert one token (B, 1, KV, hd) at absolute position `pos` (scalar).

    Ring semantics: slot = pos % cache_len. Implemented as a masked write so
    SPMD keeps sequence-sharded caches local (each shard writes iff the slot
    lands in its range)."""
    S = cache.k.shape[1]
    slot = pos % S
    onehot = (jnp.arange(S) == slot)[None, :, None, None]
    if cache.k_scale is not None:
        kq, ks = _q8_kv(k_new)
        vq, vs = _q8_kv(v_new)
        k = jnp.where(onehot, kq, cache.k)
        v = jnp.where(onehot, vq, cache.v)
        ksc = jnp.where(onehot[..., 0], ks, cache.k_scale)
        vsc = jnp.where(onehot[..., 0], vs, cache.v_scale)
        p = jnp.where(onehot[..., 0, 0], pos.astype(jnp.int32), cache.pos)
        return KVCache(k, v, p, ksc, vsc)
    k = jnp.where(onehot, k_new.astype(cache.k.dtype), cache.k)
    v = jnp.where(onehot, v_new.astype(cache.v.dtype), cache.v)
    p = jnp.where(onehot[..., 0, 0], pos.astype(jnp.int32), cache.pos)
    return KVCache(k, v, p)


def cache_prefill(cache: KVCache, k: Array, v: Array) -> KVCache:
    """Write a full prefix (B, T, KV, hd) into the cache (T <= S ring-aware)."""
    B, T = k.shape[0], k.shape[1]
    S = cache.k.shape[1]
    if cache.k_scale is not None:
        kq, ks = _q8_kv(k)
        vq, vs = _q8_kv(v)
        if T <= S:
            kc = jax.lax.dynamic_update_slice(cache.k, kq, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache.v, vq, (0, 0, 0, 0))
            ksc = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, 0, 0))
            vsc = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, 0, 0))
            pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
            pc = jax.lax.dynamic_update_slice(cache.pos, pos, (0, 0))
            return KVCache(kc, vc, pc, ksc, vsc)
        shift = (T - S) % S
        pc = jnp.roll(jnp.broadcast_to(jnp.arange(T - S, T), (B, S))
                      .astype(jnp.int32), shift, axis=1)
        return KVCache(jnp.roll(kq[:, -S:], shift, 1),
                       jnp.roll(vq[:, -S:], shift, 1), pc,
                       jnp.roll(ks[:, -S:], shift, 1),
                       jnp.roll(vs[:, -S:], shift, 1))
    if T <= S:
        kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, 0, 0, 0))
        pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
        pc = jax.lax.dynamic_update_slice(cache.pos, pos, (0, 0))
        return KVCache(kc, vc, pc)
    # ring: keep the last S positions
    kc = k[:, -S:].astype(cache.k.dtype)
    vc = v[:, -S:].astype(cache.v.dtype)
    pc = jnp.broadcast_to(jnp.arange(T - S, T), (B, S)).astype(jnp.int32)
    # rotate so that slot = pos % S
    shift = (T - S) % S
    kc = jnp.roll(kc, shift, axis=1)
    vc = jnp.roll(vc, shift, axis=1)
    pc = jnp.roll(pc, shift, axis=1)
    return KVCache(kc, vc, pc)


def decode_attend(q: Array, cache: KVCache, head_map: Array, *,
                  pos: Array, window: int = 0) -> Array:
    """q: (B, 1, Hp, hd) at absolute position `pos` (scalar int32)."""
    B = q.shape[0]
    qp = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
    valid = cache.pos >= 0
    k, v = cache.k, cache.v
    if cache.k_scale is not None:   # int8 cache: HBM streams codes
        k = _dq8_kv(k, cache.k_scale, q.dtype)
        v = _dq8_kv(v, cache.v_scale, q.dtype)
    return _dense_attention(q, k, v, head_map, causal=True,
                            window=window, q_positions=qp,
                            kv_positions=cache.pos, kv_valid=valid)


# ---------------------------------------------------------------------------
# paged KV cache (serve/kv_cache.py owns the pool + block tables; these are
# the per-layer device ops the decode scan body runs)
# ---------------------------------------------------------------------------

def paged_insert(k_pool: Array, v_pool: Array, k_new: Array, v_new: Array,
                 block_tables: Array, pos: Array):
    """Write one token per slot into the paged pool.

    k_pool/v_pool: (NB, BS, KV, hd); k_new/v_new: (B, 1, KV, hd);
    block_tables: (B, MAXB) physical block ids; pos: (B,) absolute write
    position, -1 = inactive slot (write dropped). Slots own disjoint blocks
    so the B scattered rows never collide."""
    NB, BS = k_pool.shape[0], k_pool.shape[1]
    safe = jnp.maximum(pos, 0)
    phys = jnp.take_along_axis(block_tables, (safe // BS)[:, None],
                               axis=1)[:, 0]
    dest = jnp.where(pos >= 0, phys * BS + safe % BS, NB * BS)  # OOB -> drop
    kf = k_pool.reshape(NB * BS, *k_pool.shape[2:])
    vf = v_pool.reshape(NB * BS, *v_pool.shape[2:])
    kf = kf.at[dest].set(k_new[:, 0].astype(kf.dtype), mode="drop")
    vf = vf.at[dest].set(v_new[:, 0].astype(vf.dtype), mode="drop")
    return kf.reshape(k_pool.shape), vf.reshape(v_pool.shape)


def paged_gather(pool: Array, block_tables: Array) -> Array:
    """(NB, BS, KV, hd) + (B, MAXB) -> (B, MAXB·BS, KV, hd): a slot's pages
    in logical order (row i holds position i)."""
    NB, BS = pool.shape[0], pool.shape[1]
    B, MAXB = block_tables.shape
    idx = (block_tables[:, :, None] * BS
           + jnp.arange(BS, dtype=jnp.int32)[None, None])
    return pool.reshape(NB * BS, *pool.shape[2:])[idx.reshape(B, MAXB * BS)]


def paged_decode_attend(q: Array, k_pool: Array, v_pool: Array,
                        block_tables: Array, lengths: Array,
                        head_map: Array, *, window: int = 0,
                        mode: Optional[str] = None) -> Array:
    """q: (B, 1, Hp, hd); lengths: (B,) valid tokens per slot (0 inactive).

    Backend dispatch mirrors kernels/ops.py: on TPU (or forced interpret)
    the Pallas paged kernel DMAs pages via scalar-prefetched block tables;
    the default XLA path gathers the slot's pages into logical order and
    runs the same `_dense_attention` the dense decode path uses — so paged
    and dense decode agree bitwise for equal cache extents."""
    H, KV = q.shape[2], k_pool.shape[2]
    if mode is None:
        from repro.kernels.ops import resolve_mode
        mode = resolve_mode(None)
    if mode in ("pallas", "interpret") and H % KV == 0:
        from repro.kernels import ops
        o = ops.paged_attention(q[:, 0], k_pool, v_pool, block_tables,
                                lengths, window=window, mode=mode)
        return o[:, None].astype(q.dtype)
    kg = paged_gather(k_pool, block_tables).astype(q.dtype)
    vg = paged_gather(v_pool, block_tables).astype(q.dtype)
    B, S = kg.shape[0], kg.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    valid = kpos < lengths[:, None]
    qp = jnp.maximum(lengths - 1, 0)[:, None].astype(jnp.int32)
    return _dense_attention(q, kg, vg, head_map, causal=True, window=window,
                            q_positions=qp, kv_positions=kpos,
                            kv_valid=valid)


def paged_insert_quant(k_pool: Array, v_pool: Array, k_scale: Array,
                       v_scale: Array, k_new: Array, v_new: Array,
                       block_tables: Array, pos: Array, *, kv_bits: int):
    """Write one token per slot into a *quantized* pool (decode append).

    k_pool/v_pool: (NB, BS, KV, hd/cpb) integer codes; k_scale/v_scale:
    (NB, KV) f32 per-(page, kv_head) scales; k_new/v_new: (B, 1, KV, hd)
    float; pos: (B,), -1 = inactive (write dropped).

    The page scale is a running max: appending a token with a larger
    absmax raises the page scale, and the page's existing codes rescale
    in-register by old/new (exact identity when the scale is unchanged —
    the common case — and at most one code unit of double-rounding when it
    grows). A token landing at page offset 0 starts a fresh page: the old
    scale/codes belong to a freed request and are overwritten, not
    maxed."""
    from repro.serve.kv_cache import _kv_qmax, kv_encode, kv_scale_of
    NB, BS = k_pool.shape[0], k_pool.shape[1]
    B = pos.shape[0]
    qmax = _kv_qmax(kv_bits)
    safe = jnp.maximum(pos, 0)
    phys = jnp.take_along_axis(block_tables, (safe // BS)[:, None],
                               axis=1)[:, 0]
    off = safe % BS
    dest = jnp.where(pos >= 0, phys, NB)             # OOB page -> drop
    fresh = (off == 0)[:, None]                      # (B, 1)
    out = []
    for pool, scale, new in ((k_pool, k_scale, k_new),
                             (v_pool, v_scale, v_new)):
        row = new[:, 0].astype(jnp.float32)          # (B, KV, hd)
        s_tok = kv_scale_of(jnp.max(jnp.abs(row), axis=-1), kv_bits)
        old = scale[phys]                            # (B, KV)
        s_new = jnp.where(fresh, s_tok, jnp.maximum(old, s_tok))
        # rescale the page's existing codes to the (possibly) raised
        # scale; ratio 0 wipes a fresh page's stale codes outright
        ratio = jnp.where(fresh | (s_new <= 0), 0.0,
                          old / jnp.where(s_new > 0, s_new, 1.0))
        page = pool[phys]                            # (B, BS, KV, hd/cpb)
        if kv_bits == 8:
            pq = page.astype(jnp.float32) * ratio[:, None, :, None]
            page2 = jnp.clip(jnp.round(pq), -qmax, qmax).astype(jnp.int8)
        else:
            from repro.core.quantizer import pack_int4, unpack_int4
            pq = (unpack_int4(page).astype(jnp.float32) - 8.0) \
                * ratio[:, None, :, None]
            pq = jnp.clip(jnp.round(pq), -qmax, qmax)
            page2 = pack_int4((pq + 8.0).astype(jnp.uint8))
        tok = kv_encode(row, s_new, kv_bits)         # (B, KV, hd/cpb)
        at_off = jnp.arange(BS)[None, :, None, None] \
            == off[:, None, None, None]
        page2 = jnp.where(at_off, tok[:, None], page2)
        out.append(pool.at[dest].set(page2, mode="drop"))
        out.append(scale.at[dest].set(s_new, mode="drop"))
    return tuple(out)  # (k_pool, k_scale, v_pool, v_scale)


def paged_decode_attend_quant(q: Array, k_pool: Array, v_pool: Array,
                              k_scale: Array, v_scale: Array,
                              block_tables: Array, lengths: Array,
                              head_map: Array, *, window: int = 0,
                              kv_bits: int = 8,
                              mode: Optional[str] = None) -> Array:
    """Quantized-pool decode attention. Pallas/interpret streams the codes
    and folds the per-page scales inside the kernel; the XLA fallback
    gathers codes + per-row scales, dequantizes, and runs the same
    `_dense_attention` as the bf16 fallback — elementwise it is exactly
    the bf16 fallback applied to the dequantized pool."""
    from repro.serve.kv_cache import kv_decode
    H, KV = q.shape[2], k_pool.shape[2]
    if mode is None:
        from repro.kernels.ops import resolve_mode
        mode = resolve_mode(None)
    if mode in ("pallas", "interpret") and H % KV == 0:
        from repro.kernels import ops
        o = ops.paged_attention_quant(q[:, 0], k_pool, v_pool, k_scale,
                                      v_scale, block_tables, lengths,
                                      window=window, kv_bits=kv_bits,
                                      mode=mode)
        return o[:, None].astype(q.dtype)
    BS = k_pool.shape[1]
    B, MAXB = block_tables.shape
    # per-row scales in logical order: page scale repeated over the page
    ks_rows = jnp.repeat(k_scale[block_tables], BS,
                         axis=1)                     # (B, MAXB*BS, KV)
    vs_rows = jnp.repeat(v_scale[block_tables], BS, axis=1)
    kg = kv_decode(paged_gather(k_pool, block_tables), ks_rows, kv_bits,
                   dtype=q.dtype)
    vg = kv_decode(paged_gather(v_pool, block_tables), vs_rows, kv_bits,
                   dtype=q.dtype)
    S = kg.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    valid = kpos < lengths[:, None]
    qp = jnp.maximum(lengths - 1, 0)[:, None].astype(jnp.int32)
    return _dense_attention(q, kg, vg, head_map, causal=True, window=window,
                            q_positions=qp, kv_positions=kpos,
                            kv_valid=valid)
