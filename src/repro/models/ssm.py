"""Mamba-style selective SSM head (hymba's parallel-SSM branch).

Training/prefill uses a *chunked* linear recurrence: an outer `lax.scan`
over token chunks carries the (d_inner, state) hidden state, and within a
chunk the diagonal recurrence h_t = a_t*h_{t-1} + b_t is solved with
`lax.associative_scan` (log-depth, parallel — TPU friendly). Decode is the
single-step recurrence.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Array, dense_init, ones_init, zeros_init


class SSMState(NamedTuple):
    h: Array        # (B, d_inner, N)
    conv: Array     # (B, conv_w-1, d_inner) trailing inputs for causal conv


def _dims(cfg):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    dt_rank = cfg.ssm.dt_rank or max(1, math.ceil(d / 16))
    return d, di, cfg.ssm.state_dim, dt_rank, cfg.ssm.conv_width


def init_ssm(key: Array, cfg, stack=()) -> dict:
    d, di, n, dt_rank, cw = _dims(cfg)
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), (di, 1))
    a = jnp.broadcast_to(a, (*stack, di, n))
    return {
        "w_in": dense_init(ks[0], (*stack, d, 2 * di)),
        "conv_w": dense_init(ks[1], (*stack, cw, di), scale=1.0 / math.sqrt(cw)),
        "conv_b": zeros_init(ks[2], (*stack, di)),
        "w_xproj": dense_init(ks[3], (*stack, di, dt_rank + 2 * n)),
        "w_dt": dense_init(ks[4], (*stack, dt_rank, di)),
        "b_dt": ones_init(ks[5], (*stack, di)) * -4.6,   # softplus^-1(0.01)
        "a_log": a,
        "d_skip": ones_init(ks[6], (*stack, di)),
        "w_out": dense_init(ks[7], (*stack, di, d)),
    }


def init_ssm_state(batch: int, cfg, dtype=jnp.float32) -> SSMState:
    d, di, n, _, cw = _dims(cfg)
    return SSMState(h=jnp.zeros((batch, di, n), dtype),
                    conv=jnp.zeros((batch, cw - 1, di), dtype))


def _causal_conv(p: dict, xi: Array, conv_state: Array) -> Tuple[Array, Array]:
    """Depthwise causal conv over T via static shifts. xi: (B, T, di)."""
    cw = p["conv_w"].shape[0]
    ext = jnp.concatenate([conv_state.astype(xi.dtype), xi], axis=1)
    out = jnp.zeros_like(xi)
    T = xi.shape[1]
    for w in range(cw):
        out = out + ext[:, w:w + T, :] * p["conv_w"][w].astype(xi.dtype)
    out = out + p["conv_b"].astype(xi.dtype)
    new_state = ext[:, -(cw - 1):, :].astype(conv_state.dtype)
    return out, new_state


def _selective_terms(p: dict, xi: Array, cfg):
    """xi: (B, T, di) post-conv. Returns a_t, b_t: (B, T, di, N), skip y0."""
    d, di, n, dt_rank, _ = _dims(cfg)
    xdbc = jnp.einsum("btd,dr->btr", xi, p["w_xproj"].astype(xi.dtype))
    dt_raw, b_in, c_in = jnp.split(xdbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_raw, p["w_dt"].astype(xi.dtype))
        .astype(jnp.float32) + p["b_dt"].astype(jnp.float32))        # (B,T,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (di, N)
    a_t = jnp.exp(dt[..., None] * a)                                 # (B,T,di,N)
    bx = (dt * xi.astype(jnp.float32))[..., None] * \
        b_in.astype(jnp.float32)[:, :, None, :]                      # (B,T,di,N)
    return a_t, bx, c_in.astype(jnp.float32)


def _scan_chunk(a: Array, b: Array, h0: Array):
    """Solve h_t = a_t h_{t-1} + b_t over axis 1 given h0. Returns (h, h_T)."""
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by
    a_acc, b_acc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_acc * h0[:, None] + b_acc
    return h, h[:, -1]


@functools.partial(jax.jit, static_argnames=("cfg", "chunk"))
def _ssm_recurrence(sel: dict, xi: Array, h0: Array, *, cfg, chunk: int):
    """Chunked selective recurrence. xi: (B, T, di) post-conv/silu. Returns
    (y (B, T, di), h_end). Jitted at definition so eager callers (the
    staged calibration walk runs layers un-jitted) hit the cache instead of
    retracing the chunk scan per call."""
    B, T, di = xi.shape
    n_chunks = T // chunk

    def step(h, args):
        xi_c, = args
        a_t, b_t, c_in = _selective_terms(sel, xi_c, cfg)
        h_seq, h_new = _scan_chunk(a_t, b_t, h)
        y = jnp.einsum("btdn,btn->btd", h_seq, c_in)                 # (B,C,di)
        return h_new, y

    if T > 1:   # remat chunks: don't stack (B,C,di,N) terms across chunks
        step = jax.checkpoint(step)
    xi_chunks = xi.reshape(B, n_chunks, chunk, di).transpose(1, 0, 2, 3)
    h_final, ys = jax.lax.scan(step, h0, (xi_chunks,))
    return ys.transpose(1, 0, 2, 3).reshape(B, T, di), h_final


def apply_ssm(p: dict, x: Array, cfg, state: SSMState,
              chunk: int = 1024, taps=None,
              quantize_cb=None) -> Tuple[Array, SSMState]:
    """x: (B, T, d) -> (y (B, T, d), new_state)."""
    d, di, n, _, _ = _dims(cfg)
    B, T, _ = x.shape
    cd = x.dtype
    if taps is not None:
        taps["ssm_in"] = x
        if quantize_cb is not None:
            p = {**p, **quantize_cb("ssm_in")}
    xz = jnp.einsum("btd,de->bte", x, p["w_in"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(p, xi, state.conv)
    xi = jax.nn.silu(xi)

    C = min(chunk, T)
    while T % C:
        C //= 2
    sel = {k: p[k] for k in ("w_xproj", "w_dt", "b_dt", "a_log")}
    y, h_final = _ssm_recurrence(sel, xi, state.h.astype(jnp.float32),
                                 cfg=cfg, chunk=C)
    y = y + p["d_skip"].astype(jnp.float32) * xi.astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z))
    if taps is not None:
        taps["ssm_out_in"] = y
        if quantize_cb is not None:
            p = {**p, **quantize_cb("ssm_out_in")}
    out = jnp.einsum("bte,ed->btd", y, p["w_out"].astype(cd))
    return out, SSMState(h=h_final.astype(state.h.dtype), conv=conv_state)


def decode_ssm(p: dict, x: Array, cfg, state: SSMState) -> Tuple[Array, SSMState]:
    """Single-token step. x: (B, 1, d)."""
    y, new_state = apply_ssm(p, x, cfg, state, chunk=1)
    return y, new_state
