"""Shared model building blocks: initializers, norms, RoPE, activations.

All models are pure-functional pytrees (nested dicts of jnp arrays). Layer
stacks are *stacked along a leading L axis* and executed with `lax.scan`,
which keeps HLO size independent of depth (critical for the 88-95 layer
dry-run configs compiled on a single CPU core).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def dtype_of(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# initializers (create stacked params directly: leading dims = layer axes)
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape: Sequence[int], scale: float | None = None,
               dtype=jnp.float32) -> Array:
    """Truncated-normal fan-in init. `shape[:-2]` are stacking dims."""
    fan_in = shape[-2]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key: Array, shape: Sequence[int], dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32) -> Array:
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32) -> Array:
    return jnp.ones(shape, dtype)


def split_keys(key: Array, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, weight: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layernorm(x: Array, weight: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_params(key, cfg, shape_prefix=()) -> dict:
    d = cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"scale": ones_init(key, (*shape_prefix, d))}
    return {"scale": ones_init(key, (*shape_prefix, d)),
            "bias": zeros_init(key, (*shape_prefix, d))}


def apply_norm(params: dict, x: Array, cfg) -> Array:
    if "bias" in params:
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rmsnorm(x, params["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE — interleaved-pair formulation.
#
# We use the interleaved (GPT-NeoX "rotate pairs (2i, 2i+1)") layout rather
# than the rotate-half layout: pairs are *adjacent*, so the head_dim axis can
# be sharded into contiguous chunks (any multiple of 2) without crossing
# shard boundaries. This is what lets the KV cache shard on head_dim when
# n_kv_heads < model-axis size (see dist/sharding.py).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name in ("silu", "swish"):
        return jax.nn.silu
    if name in ("gelu", "gelu_mlp"):
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
