"""RWKV6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

The wkv recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                    o_t = r_t (diag(u) k_t v_t^T + S_{t-1})
is evaluated in *chunked matrix form*: within a chunk of size 16 the
pairwise decay factors exp(L_{t-1} - L_s) are factored into r̃ = r*exp(L)
and k̃ = k*exp(-L) (safe in f32 because chunk length × |log w| is bounded —
log-decay is clamped to [-5, 0], which only affects decays that zero the
state within one chunk anyway). Cross-chunk state is carried by `lax.scan`.
This turns the sequential recurrence into MXU matmuls — the TPU adaptation
of the CUDA wkv kernel (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Array, dense_init, zeros_init

WKV_CHUNK = 16
LOGW_MIN = -5.0


class RWKVState(NamedTuple):
    x_tm: Array      # (B, 1, d) previous token for time-mix shift
    x_cm: Array      # (B, 1, d) previous token for channel-mix shift
    s: Array         # (B, H, hd, hd) wkv state (k-major, v-minor)


def _dims(cfg):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    h = d // hd
    return d, h, hd


def init_time_mix(key: Array, cfg, stack=()) -> dict:
    d, h, hd = _dims(cfg)
    r = cfg.rwkv
    ks = jax.random.split(key, 12)
    return {
        "mu_base": zeros_init(ks[0], (*stack, d)) + 0.5,
        "w1_ts": dense_init(ks[1], (*stack, d, 5 * r.token_shift_lora)),
        "w2_ts": dense_init(ks[2], (*stack, 5, r.token_shift_lora, d)),
        "mu_rkvwg": zeros_init(ks[3], (*stack, 5, d)) + 0.5,
        "w_r": dense_init(ks[4], (*stack, d, d)),
        "w_k": dense_init(ks[5], (*stack, d, d)),
        "w_v": dense_init(ks[6], (*stack, d, d)),
        "w_g": dense_init(ks[7], (*stack, d, d)),
        "w0_decay": zeros_init(ks[8], (*stack, d)) - 4.0,
        "w1_decay": dense_init(ks[9], (*stack, d, r.decay_lora)),
        "w2_decay": dense_init(ks[10], (*stack, r.decay_lora, d)),
        "u_bonus": zeros_init(ks[11], (*stack, d)),
        "ln_w": zeros_init(key, (*stack, d)) + 1.0,      # per-head groupnorm
        "w_o": dense_init(key, (*stack, d, d)),
    }


def init_channel_mix(key: Array, cfg, stack=()) -> dict:
    d = cfg.d_model
    f = cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mu_k": zeros_init(ks[0], (*stack, d)) + 0.5,
        "mu_r": zeros_init(ks[1], (*stack, d)) + 0.5,
        "w_k": dense_init(ks[2], (*stack, d, f)),
        "w_v": dense_init(ks[3], (*stack, f, d)),
        "w_r": dense_init(key, (*stack, d, d)),
    }


def init_rwkv_state(batch: int, cfg, dtype=jnp.float32) -> RWKVState:
    d, h, hd = _dims(cfg)
    return RWKVState(x_tm=jnp.zeros((batch, 1, d), dtype),
                     x_cm=jnp.zeros((batch, 1, d), dtype),
                     s=jnp.zeros((batch, h, hd, hd), dtype))


def _token_shift(x: Array, x_prev: Array) -> Array:
    """shifted[t] = x[t-1], with x_prev filling slot 0. x: (B, T, d)."""
    return jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: Array, xx: Array):
    """Data-dependent lerp -> the five mixed inputs (r,k,v,w,g)."""
    B, T, d = x.shape
    base = x + xx * p["mu_base"].astype(x.dtype)
    h1 = jnp.einsum("btd,df->btf", base, p["w1_ts"].astype(x.dtype))
    h1 = jnp.tanh(h1).reshape(B, T, 5, -1)
    lora = jnp.einsum("btgf,gfd->btgd", h1, p["w2_ts"].astype(x.dtype))
    mix = p["mu_rkvwg"].astype(x.dtype)[None, None] + lora       # (B,T,5,d)
    return [x + xx * mix[:, :, i] for i in range(5)]


def _wkv_chunk(r: Array, k: Array, v: Array, logw: Array, u: Array,
               s0: Array):
    """One chunk. r/k/v/logw: (B, C, H, hd) f32; u: (H, hd); s0: (B,H,hd,hd).
    Returns (out (B,C,H,hd), s_end)."""
    B, C, H, hd = r.shape
    L = jnp.cumsum(logw, axis=1)                       # inclusive
    Lprev = L - logw                                   # exclusive
    r_t = r * jnp.exp(Lprev)
    k_t = k * jnp.exp(-L)
    att = jnp.einsum("bchk,bshk->bhcs", r_t, k_t)      # (B,H,C,C)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
    att = jnp.where(tri[None, None], att, 0.0)
    diag = jnp.einsum("bchk,bchk->bhc", r, u[None, None] * k)
    out = jnp.einsum("bhcs,bshk->bchk", att, v)
    out = out + diag.transpose(0, 2, 1)[..., None] * v
    out = out + jnp.einsum("bchk,bhkv->bchv", r_t, s0)
    k_end = k * jnp.exp(L[:, -1:] - L)                 # decay to chunk end
    s_end = jnp.exp(L[:, -1])[..., None] * s0 + \
        jnp.einsum("bchk,bchv->bhkv", k_end, v)
    return out, s_end


@functools.partial(jax.jit, static_argnames=("chunk",))
def _wkv_scan(r: Array, k: Array, v: Array, logw: Array, u: Array,
              s0: Array, *, chunk: int):
    """Chunked wkv recurrence. r/k/v/logw: (B, T, H, hd) f32. Returns
    (out (B, T, H·hd), s_end). Jitted at definition so eager callers (the
    staged calibration walk runs layers un-jitted) hit the cache instead of
    retracing the chunk scan per call."""
    B, T, H, hd = r.shape
    n_chunks = T // chunk

    def step(s, args):
        rc, kc, vc, wc = args
        out, s_new = _wkv_chunk(rc, kc, vc, wc, u, s)
        return s_new, out

    if T > 1:   # remat chunks (don't stack intra-chunk decay matrices)
        step = jax.checkpoint(step)

    def chunked(a):
        return a.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    s_fin, outs = jax.lax.scan(step, s0, (chunked(r), chunked(k),
                                          chunked(v), chunked(logw)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H * hd), s_fin


def apply_time_mix(p: dict, x: Array, cfg, state: RWKVState, taps=None,
                   quantize_cb=None) -> Tuple[Array, Array, Array]:
    """x: (B, T, d) -> (out, new_x_prev, new_s)."""
    d, H, hd = _dims(cfg)
    B, T, _ = x.shape
    cd = x.dtype
    x_prev = state.x_tm
    xx = _token_shift(x, x_prev) - x
    xr, xk, xv, xw, xg = _ddlerp(p, x, xx)
    if taps is not None:
        taps["tm_r_in"], taps["tm_k_in"] = xr, xk
        taps["tm_v_in"], taps["tm_g_in"] = xv, xg
        if quantize_cb is not None:
            p = {**p, **quantize_cb("tm_r_in"), **quantize_cb("tm_k_in"),
                 **quantize_cb("tm_v_in"), **quantize_cb("tm_g_in")}
    r = jnp.einsum("btd,de->bte", xr, p["w_r"].astype(cd))
    k = jnp.einsum("btd,de->bte", xk, p["w_k"].astype(cd))
    v = jnp.einsum("btd,de->bte", xv, p["w_v"].astype(cd))
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["w_g"].astype(cd)))
    decay_lora = jnp.einsum("btf,fd->btd",
                            jnp.tanh(jnp.einsum("btd,df->btf", xw,
                                                p["w1_decay"].astype(cd))),
                            p["w2_decay"].astype(cd))
    logw = -jnp.exp(jnp.clip(
        p["w0_decay"].astype(jnp.float32) + decay_lora.astype(jnp.float32),
        -8.0, 1.61))                                   # log-decay in (-5, 0)
    logw = jnp.clip(logw, LOGW_MIN, -1e-6)

    def heads(a):
        return a.reshape(B, T, H, hd).astype(jnp.float32)

    r, k, v, logw = heads(r), heads(k), heads(v), heads(logw)
    u = p["u_bonus"].reshape(H, hd).astype(jnp.float32)

    C = WKV_CHUNK if T % WKV_CHUNK == 0 and T >= WKV_CHUNK else 1
    out, s_fin = _wkv_scan(r, k, v, logw, u, state.s.astype(jnp.float32),
                           chunk=C)

    # per-head group norm, gate, out-projection
    oh = out.reshape(B, T, H, hd)
    var = jnp.var(oh, axis=-1, keepdims=True)
    mean = jnp.mean(oh, axis=-1, keepdims=True)
    oh = (oh - mean) * jax.lax.rsqrt(var + 1e-5)
    out = (oh.reshape(B, T, d) * p["ln_w"].astype(jnp.float32))
    out = (out.astype(cd) * g)
    if taps is not None:
        taps["tm_o_in"] = out
        if quantize_cb is not None:
            p = {**p, **quantize_cb("tm_o_in")}
    out = jnp.einsum("btd,de->bte", out, p["w_o"].astype(cd))
    new_x_prev = x[:, -1:].astype(state.x_tm.dtype)
    return out, new_x_prev, s_fin.astype(state.s.dtype)


def apply_channel_mix(p: dict, x: Array, cfg, x_prev: Array, taps=None,
                      quantize_cb=None) -> Tuple[Array, Array]:
    cd = x.dtype
    xx = _token_shift(x, x_prev) - x
    xk = x + xx * p["mu_k"].astype(cd)
    xr = x + xx * p["mu_r"].astype(cd)
    if taps is not None:
        taps["cm_k_in"], taps["cm_r_in"] = xk, xr
        if quantize_cb is not None:
            p = {**p, **quantize_cb("cm_k_in"), **quantize_cb("cm_r_in")}
    k = jnp.einsum("btd,df->btf", xk, p["w_k"].astype(cd))
    ksq = jnp.square(jax.nn.relu(k))
    if taps is not None:
        taps["cm_v_in"] = ksq
        if quantize_cb is not None:
            p = {**p, **quantize_cb("cm_v_in")}
    v = jnp.einsum("btf,fd->btd", ksq, p["w_v"].astype(cd))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["w_r"].astype(cd)))
    return r * v, x[:, -1:].astype(x_prev.dtype)
