"""Decoder/encoder stack assembly.

All homogeneous stacks are `lax.scan` over layer-stacked params (HLO size is
depth-independent). The VLM stack (llama-3.2-vision) scans over *groups* of
(`every`-1 self layers + 1 gated cross-attn layer), with an inner scan over
the self layers — params are stacked (G, every-1, ...) and (G, ...).

A `BuildPlan` carries mesh-derived static facts (TP padding) and an optional
`constrain(x, kind)` callback used by the launcher to pin intermediate
shardings (residual stream, logits, caches) without the model importing any
mesh code.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (KVCache, cache_insert, cache_prefill,
                                    decode_attend, flash_attention,
                                    head_to_kv_map, init_kv_cache,
                                    out_project, paged_decode_attend,
                                    paged_insert, qkv_project)
from repro.models.common import (Array, apply_norm, apply_rope, dense_init,
                                 norm_params, pad_to_multiple, zeros_init)


def _ident_constrain(x, kind):
    return x


@dataclass(frozen=True)
class BuildPlan:
    tp: int = 1
    attn_block_size: int = 512
    moe_token_chunk: int = 4096
    # round MoE routing capacity up to this multiple: quantize_model sets
    # it to the mesh "data" axis so (E, C, d) expert taps always divide it
    # and calibration Grams stay on the psum path (dist.calibrate)
    moe_capacity_multiple: int = 1
    remat: bool = True
    cache_dtype: Any = jnp.bfloat16
    cache_quant: bool = False    # int8 KV cache (per-entry absmax scales)
    # paged-pool KV quantization (serve runtime): 0 = bf16 pages, 8/4 =
    # integer codes with per-(layer, page, kv_head) scales (DESIGN.md §11)
    kv_bits: int = 0
    # prefill cache capacity (0 -> prompt length); serving engines set
    # prompt+max_new so decode can continue without ring eviction
    prefill_cache_len: int = 0
    constrain: Callable[[Array, str], Array] = _ident_constrain

    def heads_padded(self, cfg) -> int:
        return pad_to_multiple(cfg.n_heads, self.tp)

    def experts_padded(self, cfg) -> int:
        if cfg.moe is None:
            return 0
        return pad_to_multiple(cfg.moe.n_experts, self.tp)

    def vocab_padded(self, cfg) -> int:
        """Vocab rows padded so TP sharding divides (and int8-moment blocks
        align); padded logit columns are masked to -inf in unembed()."""
        if self.tp <= 1:
            return cfg.vocab_size
        return pad_to_multiple(cfg.vocab_size, 256)

    def replace(self, **kw) -> "BuildPlan":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def init_layer(key: Array, cfg, plan: BuildPlan, stack=()) -> dict:
    ks = jax.random.split(key, 6)
    hp = plan.heads_padded(cfg)
    p: Dict[str, Any] = {"ln1": norm_params(ks[0], cfg, stack)}
    if cfg.attn_free:   # rwkv6
        p["tm"] = rwkv_mod.init_time_mix(ks[1], cfg, stack)
        p["ln2"] = norm_params(ks[2], cfg, stack)
        p["cm"] = rwkv_mod.init_channel_mix(ks[3], cfg, stack)
        return p
    p["attn"] = attn_mod.init_attn(ks[1], cfg, hp, stack)
    if cfg.parallel_ssm_heads:
        p["ssm"] = ssm_mod.init_ssm(ks[2], cfg, stack)
    p["ln2"] = norm_params(ks[3], cfg, stack)
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[4], cfg, plan.experts_padded(cfg), stack)
    else:
        p["mlp"] = mlp_mod.init_mlp(ks[4], cfg, stack)
    return p


def init_cross_layer(key: Array, cfg, plan: BuildPlan, stack=()) -> dict:
    ks = jax.random.split(key, 6)
    hp = plan.heads_padded(cfg)
    return {
        "ln1": norm_params(ks[0], cfg, stack),
        "xattn": attn_mod.init_attn(ks[1], cfg, hp, stack, kv_in=cfg.d_model),
        "gate_attn": zeros_init(ks[2], (*stack,)),
        "ln2": norm_params(ks[3], cfg, stack),
        "mlp": mlp_mod.init_mlp(ks[4], cfg, stack),
        "gate_mlp": zeros_init(ks[5], (*stack,)),
    }


# ---------------------------------------------------------------------------
# layer application (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _self_attention_full(p, x, cfg, plan, make_cache: bool, taps=None,
                         quantize_cb=None):
    hp = plan.heads_padded(cfg)
    hmap = head_to_kv_map(cfg.n_heads, hp, cfg.n_kv_heads)
    ap = p["attn"]
    if taps is not None:
        taps["attn_in"] = x                   # feeds wq / wk / wv
        if quantize_cb is not None:
            ap = {**ap, **quantize_cb("attn_in")}
    q, k, v = qkv_project(ap, x)
    if cfg.causal:
        B, T = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    o = flash_attention(q, k, v, hmap, causal=cfg.causal,
                        window=cfg.sliding_window,
                        block_size=plan.attn_block_size)
    if taps is not None:
        taps["wo_in"] = o.reshape(*o.shape[:2], -1)   # feeds wo (Hp*hd, d)
        if quantize_cb is not None:
            ap = {**ap, **quantize_cb("wo_in")}
    cache = None
    if make_cache:
        B, T = x.shape[:2]
        # SWA: allocate at least the full window so decode can continue
        # past the prompt without evicting in-window entries. A larger
        # prefill_cache_len (serving runtimes pad prompts to bucket
        # lengths) also wins: right-pad rows must never ring-evict real
        # in-window rows before the paged scatter drops them.
        if cfg.sliding_window:
            clen = max(cfg.sliding_window, plan.prefill_cache_len)
        else:
            clen = max(plan.prefill_cache_len, T)
        cache = init_kv_cache(B, clen, cfg.n_kv_heads,
                              cfg.resolved_head_dim, plan.cache_dtype,
                              quantized=plan.cache_quant)
        cache = cache_prefill(cache, k, v)
        cache = plan.constrain(cache, "kv_cache")
    return attn_mod.out_project(ap, o), cache


def layer_full(p: dict, x: Array, cfg, plan: BuildPlan, make_cache: bool,
               rwkv_state=None, ssm_state=None, taps=None, quantize_cb=None):
    """One layer over a full sequence. Returns (x, cache_out, aux, states).

    `quantize_cb` (calibration only, requires `taps`): called once per
    activation tap *right after the tap is recorded and before the weights
    it feeds are applied*; returns replacement (dequantized-quantized)
    leaves for the owning module, so the rest of this forward — including
    every downstream tap — is computed with the already-quantized upstream
    sub-blocks. This is the staged one-forward-per-layer calibration walk
    (core/pipeline.py, DESIGN.md §4.1).
    """
    aux = jnp.float32(0.0)
    x = plan.constrain(x, "block_in")   # Megatron-SP gather (no-op w/o SP)
    if cfg.attn_free:
        h, new_tm, new_s = rwkv_mod.apply_time_mix(
            p["tm"], apply_norm(p["ln1"], x, cfg), cfg, rwkv_state, taps=taps,
            quantize_cb=quantize_cb)
        x = x + h
        h, new_cm = rwkv_mod.apply_channel_mix(
            p["cm"], apply_norm(p["ln2"], x, cfg), cfg, rwkv_state.x_cm,
            taps=taps, quantize_cb=quantize_cb)
        x = x + h
        new_state = rwkv_mod.RWKVState(new_tm, new_cm, new_s)
        return x, None, aux, new_state

    xn = apply_norm(p["ln1"], x, cfg)
    a_out, cache = _self_attention_full(p, xn, cfg, plan, make_cache, taps,
                                        quantize_cb)
    new_ssm = None
    if cfg.parallel_ssm_heads:
        s_out, new_ssm = ssm_mod.apply_ssm(p["ssm"], xn, cfg, ssm_state,
                                           taps=taps, quantize_cb=quantize_cb)
        a_out = 0.5 * (a_out + s_out)
    x = x + a_out
    xn = apply_norm(p["ln2"], x, cfg)
    if cfg.moe is not None:
        m_out, aux = moe_mod.apply_moe(p["moe"], xn, cfg,
                                       plan.experts_padded(cfg),
                                       plan.moe_token_chunk, taps=taps,
                                       quantize_cb=quantize_cb,
                                       capacity_multiple=
                                       plan.moe_capacity_multiple)
    else:
        m_out = mlp_mod.apply_mlp(p["mlp"], xn, cfg, taps=taps,
                                  constrain=plan.constrain,
                                  quantize_cb=quantize_cb)
    x = x + m_out
    return x, cache, aux, new_ssm


def cross_layer_full(p: dict, x: Array, cfg, plan: BuildPlan,
                     vision_kv: Tuple[Array, Array], taps=None,
                     quantize_cb=None) -> Array:
    hp = plan.heads_padded(cfg)
    hmap = head_to_kv_map(cfg.n_heads, hp, cfg.n_kv_heads)
    xn = apply_norm(p["ln1"], x, cfg)
    cd = x.dtype
    xp = p["xattn"]
    if taps is not None:
        taps["xattn_q_in"] = xn
        if quantize_cb is not None:
            xp = {**xp, **quantize_cb("xattn_q_in")}
    q = jnp.einsum("btd,dhk->bthk", xn, xp["wq"].astype(cd))
    k, v = vision_kv
    o = attn_mod._dense_attention(q, k.astype(cd), v.astype(cd), hmap,
                                  causal=False, window=0)
    if taps is not None:
        taps["xattn_wo_in"] = o.reshape(*o.shape[:2], -1)
        if quantize_cb is not None:
            xp = {**xp, **quantize_cb("xattn_wo_in")}
    x = x + jnp.tanh(p["gate_attn"]).astype(cd) * attn_mod.out_project(
        xp, o)
    xn = apply_norm(p["ln2"], x, cfg)
    x = x + jnp.tanh(p["gate_mlp"]).astype(cd) * mlp_mod.apply_mlp(
        p["mlp"], xn, cfg, taps=taps, quantize_cb=quantize_cb)
    return x


def vision_kv_for_layer(p_cross: dict, vision_embeds: Array):
    """Precompute cross-attn K/V from projected vision embeddings."""
    cd = vision_embeds.dtype
    k = jnp.einsum("bnd,dhk->bnhk", vision_embeds, p_cross["xattn"]["wk"].astype(cd))
    v = jnp.einsum("bnd,dhk->bnhk", vision_embeds, p_cross["xattn"]["wv"].astype(cd))
    return k, v


# ---------------------------------------------------------------------------
# layer application (single-token decode)
# ---------------------------------------------------------------------------

def layer_decode(p: dict, x: Array, cfg, plan: BuildPlan, kv_cache, pos,
                 rwkv_state=None, ssm_state=None, vision_kv=None,
                 is_cross: bool = False):
    """x: (B, 1, d). Returns (x, new_kv_cache, new_rwkv, new_ssm)."""
    if cfg.attn_free:
        h, new_tm, new_s = rwkv_mod.apply_time_mix(
            p["tm"], apply_norm(p["ln1"], x, cfg), cfg, rwkv_state)
        x = x + h
        h, new_cm = rwkv_mod.apply_channel_mix(
            p["cm"], apply_norm(p["ln2"], x, cfg), cfg, rwkv_state.x_cm)
        x = x + h
        return x, None, rwkv_mod.RWKVState(new_tm, new_cm, new_s), None

    if is_cross:
        hp = plan.heads_padded(cfg)
        hmap = head_to_kv_map(cfg.n_heads, hp, cfg.n_kv_heads)
        xn = apply_norm(p["ln1"], x, cfg)
        cd = x.dtype
        q = jnp.einsum("btd,dhk->bthk", xn, p["xattn"]["wq"].astype(cd))
        k, v = vision_kv
        o = attn_mod._dense_attention(q, k.astype(cd), v.astype(cd), hmap,
                                      causal=False, window=0)
        x = x + jnp.tanh(p["gate_attn"]).astype(cd) * attn_mod.out_project(
            p["xattn"], o)
        xn = apply_norm(p["ln2"], x, cfg)
        x = x + jnp.tanh(p["gate_mlp"]).astype(cd) * mlp_mod.apply_mlp(
            p["mlp"], xn, cfg)
        return x, kv_cache, None, None

    hp = plan.heads_padded(cfg)
    hmap = head_to_kv_map(cfg.n_heads, hp, cfg.n_kv_heads)
    xn = apply_norm(p["ln1"], x, cfg)
    q, k, v = qkv_project(p["attn"], xn)
    B = x.shape[0]
    posb = jnp.broadcast_to(pos[None], (B, 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    kv_cache = cache_insert(kv_cache, k, v, pos)
    o = decode_attend(q, kv_cache, hmap, pos=pos, window=cfg.sliding_window)
    a_out = attn_mod.out_project(p["attn"], o)
    new_ssm = None
    if cfg.parallel_ssm_heads:
        s_out, new_ssm = ssm_mod.decode_ssm(p["ssm"], xn, cfg, ssm_state)
        a_out = 0.5 * (a_out + s_out)
    x = x + a_out
    x = x + _decode_ffn(p, x, cfg, plan)
    return x, kv_cache, None, new_ssm


def _decode_ffn(p: dict, x: Array, cfg, plan: BuildPlan) -> Array:
    xn = apply_norm(p["ln2"], x, cfg)
    if cfg.moe is not None:
        m_out, _ = moe_mod.apply_moe(p["moe"], xn, cfg,
                                     plan.experts_padded(cfg),
                                     plan.moe_token_chunk,
                                     capacity_multiple=
                                     plan.moe_capacity_multiple)
        return m_out
    return mlp_mod.apply_mlp(p["mlp"], xn, cfg)


def layer_decode_paged(p: dict, x: Array, cfg, plan: BuildPlan,
                       k_pool: Array, v_pool: Array, block_tables: Array,
                       pos: Array, k_scale: Optional[Array] = None,
                       v_scale: Optional[Array] = None):
    """One decode step against the paged KV pool (serve/kv_cache.py).

    x: (B, 1, d); k_pool/v_pool: this layer's (NB, BS, KV, hd) pages;
    block_tables: (B, MAXB) physical page ids per slot; pos: (B,) absolute
    write position per slot, -1 = inactive (write dropped, output garbage
    that the runtime masks). Unlike `layer_decode`, positions are per-slot
    vectors — slots sit at different sequence lengths (continuous batching).
    Returns (x, k_pool, v_pool).

    With `plan.kv_bits` set the pools hold integer codes and
    k_scale/v_scale (NB, KV) carry the per-(page, kv_head) scales: the
    append re-quantizes under a running-max page scale and attention
    dequantizes in-kernel (or in the gather fallback). Returns
    (x, k_pool, v_pool, k_scale, v_scale) in that case."""
    hp = plan.heads_padded(cfg)
    hmap = head_to_kv_map(cfg.n_heads, hp, cfg.n_kv_heads)
    xn = apply_norm(p["ln1"], x, cfg)
    q, k, v = qkv_project(p["attn"], xn)
    posb = jnp.maximum(pos, 0)[:, None]                   # (B, 1)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    lengths = jnp.maximum(pos + 1, 0)
    if plan.kv_bits:
        k_pool, k_scale, v_pool, v_scale = attn_mod.paged_insert_quant(
            k_pool, v_pool, k_scale, v_scale, k, v, block_tables, pos,
            kv_bits=plan.kv_bits)
        o = attn_mod.paged_decode_attend_quant(
            q, k_pool, v_pool, k_scale, v_scale, block_tables, lengths,
            hmap, window=cfg.sliding_window, kv_bits=plan.kv_bits)
        x = x + attn_mod.out_project(p["attn"], o)
        x = x + _decode_ffn(p, x, cfg, plan)
        return x, k_pool, v_pool, k_scale, v_scale
    k_pool, v_pool = paged_insert(k_pool, v_pool, k, v, block_tables, pos)
    o = paged_decode_attend(q, k_pool, v_pool, block_tables, lengths, hmap,
                            window=cfg.sliding_window)
    x = x + attn_mod.out_project(p["attn"], o)
    x = x + _decode_ffn(p, x, cfg, plan)
    return x, k_pool, v_pool
