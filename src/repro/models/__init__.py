from repro.models.transformer import BuildPlan  # noqa: F401
from repro.models.model import (count_params, count_params_analytic,  # noqa: F401
                                decode_step, decode_step_paged, forward,
                                init_cache, init_params, input_specs,
                                lm_loss, prefill)
