"""Mixture-of-experts layer with static shapes and expert parallelism.

Dispatch is GShard-style (one-hot capacity buckets) but *chunked over the
token axis with `lax.scan`* so the dispatch/combine tensors stay small; the
expert GEMMs are batched einsums over the (padded) expert axis, which shards
cleanly across the model mesh axis (EP). Tokens overflowing an expert's
capacity are dropped (contribute zero) — standard for static-shape MoE.

Expert count is padded to a multiple of the TP degree (granite 40 -> 48 on a
16-way axis); padded experts get -inf router logits so no token routes there.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Array, act_fn, dense_init, pad_to_multiple


def init_moe(key: Array, cfg, n_experts_padded: int, stack=()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    e = n_experts_padded
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (*stack, d, e), scale=0.02),
        "w_gate": dense_init(ks[1], (*stack, e, d, f)),
        "w_up": dense_init(ks[2], (*stack, e, d, f)),
        "w_down": dense_init(ks[3], (*stack, e, f, d)),
    }


def _route(logits: Array, n_real: int, top_k: int):
    """logits: (N, Ep). Returns (weights, ids): (N, k)."""
    e_pad = logits.shape[-1]
    if n_real < e_pad:
        neg = jnp.full((e_pad - n_real,), -1e30, logits.dtype)
        logits = logits.at[..., n_real:].set(neg) if hasattr(logits, "at") else logits
    w, ids = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(w.astype(jnp.float32), axis=-1)
    return w, ids


def _dispatch_chunk(x: Array, p: dict, cfg, n_real: int, capacity: int,
                    taps=None, quantize_cb=None) -> Tuple[Array, Array]:
    """x: (N, d) one token chunk -> (y (N, d), aux_loss scalar)."""
    cd = x.dtype
    N, d = x.shape
    e_pad = p["router"].shape[-1]
    k = cfg.moe.top_k
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    weights, ids = _route(logits, n_real, k)             # (N, k)

    # position of each (token, slot) within its expert's capacity bucket
    onehot = jax.nn.one_hot(ids, e_pad, dtype=jnp.int32)       # (N, k, E)
    flat = onehot.reshape(N * k, e_pad)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat                 # (N*k, E)
    pos = (pos_in_e * flat).sum(-1).reshape(N, k)              # (N, k)
    keep = pos < capacity

    # dispatch tensor (N, k, E, C) is never materialized: build (N, E*C)
    slot = ids * capacity + pos                                # (N, k)
    slot = jnp.where(keep, slot, e_pad * capacity)             # overflow bin
    disp = jax.nn.one_hot(slot, e_pad * capacity + 1, dtype=cd)[..., :-1]
    disp = disp.reshape(N, k, e_pad, capacity)

    xb = jnp.einsum("nkec,nd->ecd", disp, x)                   # (E, C, d)
    if taps is not None:
        taps["expert_in"] = xb          # (E, C, d): feeds w_gate/w_up
        if quantize_cb is not None:
            p = {**p, **quantize_cb("expert_in")}
    act = act_fn(cfg.act)
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", xb, p["w_gate"].astype(cd))
        u = jnp.einsum("ecd,edf->ecf", xb, p["w_up"].astype(cd))
        h = act(g) * u
    else:
        h = act(jnp.einsum("ecd,edf->ecf", xb, p["w_up"].astype(cd)))
    if taps is not None:
        taps["expert_down_in"] = h      # (E, C, f): feeds w_down
        if quantize_cb is not None:
            p = {**p, **quantize_cb("expert_down_in")}
    yb = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))  # (E, C, d)

    comb = disp * weights.astype(cd)[:, :, None, None]
    y = jnp.einsum("nkec,ecd->nd", comb, yb)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)          # (E,)
    ce = jnp.mean(onehot.sum(1).astype(jnp.float32), axis=0)
    aux = e_pad * jnp.sum(me * ce)
    return y, aux


def apply_moe(p: dict, x: Array, cfg, n_experts_padded: int,
              token_chunk: int = 4096, taps=None,
              quantize_cb=None, capacity_multiple: int = 1
              ) -> Tuple[Array, Array]:
    """x: (B, T, d) -> (y, aux_loss). Token axis chunked with lax.scan.

    `capacity_multiple` (BuildPlan.moe_capacity_multiple) rounds the
    routing capacity up so the (E, C, d) expert buffers divide the mesh
    "data" axis — calibration taps then always reduce via the Gram psum
    instead of the replicated fallback (dist.calibrate). Rounding up only
    *adds* capacity slots, so no token that would have been kept is
    dropped."""
    B, T, d = x.shape
    n_real = cfg.moe.n_experts
    flat = x.reshape(B * T, d)
    N = flat.shape[0]
    chunk = min(token_chunk, N)
    while N % chunk:
        chunk //= 2
    n_chunks = N // chunk
    capacity = pad_to_multiple(
        max(8, int(chunk * cfg.moe.top_k * cfg.moe.capacity_factor
                   / max(cfg.moe.n_experts, 1))), capacity_multiple)

    if taps is not None:
        # calibration path: single pass over the routed expert buffers; taps
        # (and the staged quantize_cb swaps) happen inside _dispatch_chunk
        taps["router_in"] = x
        y, a = _dispatch_chunk(flat, p, cfg, n_real,
                               pad_to_multiple(
                                   max(8, int(N * cfg.moe.top_k *
                                              cfg.moe.capacity_factor /
                                              max(cfg.moe.n_experts, 1))),
                                   capacity_multiple),
                               taps=taps, quantize_cb=quantize_cb)
        return y.reshape(B, T, d), a

    def step(aux, xc):
        y, a = _dispatch_chunk(xc, p, cfg, n_real, capacity)
        return aux + a, y

    # remat each chunk: the (chunk, k, E, C) dispatch one-hots would
    # otherwise be saved across all chunks for the backward pass
    step = jax.checkpoint(step)
    aux, ys = jax.lax.scan(step, jnp.float32(0.0),
                           flat.reshape(n_chunks, chunk, d))
    return ys.reshape(B, T, d), aux / n_chunks
