"""Top-level model API.

    params = init_params(key, cfg, plan)
    logits, aux = forward(params, cfg, plan, tokens, ...)
    loss, metrics = lm_loss(params, cfg, plan, batch)
    cache = init_cache(cfg, plan, batch, cache_len)
    logits, cache = prefill(params, cfg, plan, tokens, ...)
    logits, cache = decode_step(params, cfg, plan, cache, tokens, pos)

Everything is a pure function over pytrees; the launcher jits/shards these.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.attention import KVCache, init_kv_cache
from repro.models.common import (Array, dense_init, dtype_of, embed_init,
                                 norm_params, zeros_init)
from repro.models.transformer import BuildPlan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _vlm_group_counts(cfg):
    every = cfg.cross_attn.every
    assert cfg.n_layers % every == 0, "vlm layers must divide into groups"
    return cfg.n_layers // every, every - 1   # (n_groups, self_per_group)


def init_params(key: Array, cfg, plan: Optional[BuildPlan] = None) -> Params:
    plan = plan or BuildPlan()
    ks = jax.random.split(key, 8)
    d, v = cfg.d_model, plan.vocab_padded(cfg)
    p: Params = {}
    if cfg.family == "encoder":
        p["pos_embed"] = embed_init(ks[0], (4096, d))
        p["cls_head"] = dense_init(ks[1], (d, cfg.vocab_size))
    else:
        p["embed"] = embed_init(ks[0], (v, d))
        if not cfg.tie_embeddings:
            p["unembed"] = dense_init(ks[1], (d, v))
    if cfg.family == "vlm":
        g, spg = _vlm_group_counts(cfg)
        p["vision_proj"] = dense_init(ks[2], (cfg.cross_attn.vision_dim, d))
        p["groups"] = {
            "self": tfm.init_layer(ks[3], cfg, plan, stack=(g, spg)),
            "cross": tfm.init_cross_layer(ks[4], cfg, plan, stack=(g,)),
        }
    else:
        p["layers"] = tfm.init_layer(ks[3], cfg, plan, stack=(cfg.n_layers,))
    p["final_norm"] = norm_params(ks[5], cfg)
    return p


def count_params(cfg, plan: Optional[BuildPlan] = None) -> int:
    import math
    plan = plan or BuildPlan()
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, plan), jax.random.PRNGKey(0))
    return sum(math.prod(x.shape)
               for x in jax.tree_util.tree_leaves(shapes))


def count_params_analytic(cfg, active_only: bool = False) -> int:
    """Param count on the *logical* (unpadded, tp=1) architecture."""
    total = count_params(cfg, BuildPlan(tp=1))
    if active_only and cfg.moe is not None:
        # subtract inactive expert params
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        n_ff_mats = 2 if cfg.act == "gelu_mlp" else 3
        per_expert = n_ff_mats * cfg.d_model * cfg.d_ff
        total -= cfg.n_layers * (e - k) * per_expert
    return total


# ---------------------------------------------------------------------------
# embedding / unembedding (shard-friendly)
# ---------------------------------------------------------------------------

def embed_tokens(p: Params, cfg, plan: BuildPlan, tokens: Array) -> Array:
    cd = dtype_of(cfg.compute_dtype)
    emb = p["embed"]
    from repro.core.apply import QT, is_qt
    if is_qt(emb):
        # gather code rows first, dequantize only the touched rows
        from repro.core.quantizer import unpack_codes
        rows = unpack_codes(jnp.take(emb.codes, tokens, axis=0), emb.cpb)
        x = ((rows.astype(jnp.float32) + emb.z_lo.astype(jnp.float32))
             * emb.scale).astype(cd)
    else:
        x = jnp.take(emb, tokens, axis=0).astype(cd)
    return plan.constrain(x, "residual")


def unembed(p: Params, cfg, plan: BuildPlan, x: Array) -> Array:
    cd = x.dtype
    from repro.core.apply import is_qt
    w = p["unembed"] if not cfg.tie_embeddings else p["embed"].T
    if is_qt(w):
        w = w.dequant(cd)
    logits = jnp.einsum("btd,dv->btv", x, w.astype(cd))
    vp = logits.shape[-1]
    if vp > cfg.vocab_size:   # mask padded vocab columns
        logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return plan.constrain(logits, "logits")


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------

def scan_layers(body, x, layers, *per_layer_xs):
    """`jax.lax.scan(body, x, (layers, *per_layer_xs))`, segment-aware.

    `layers` is either a plain stacked layer tree (one scan — the
    historical path, byte-identical) or a `core.apply.SegmentedLayers`
    (mixed-bit serving_params): then one scan runs per homogeneous
    segment, each over its slice of the per-layer operands (KV caches,
    paged pools, states), and the stacked ys re-concatenate along the
    layer axis — so a mixed 4/8-bit tree keeps every segment's codes
    packed at their own width inside its own compiled scan."""
    from repro.core.apply import is_segmented
    if not is_segmented(layers):
        return jax.lax.scan(body, x, (layers, *per_layer_xs))
    lo = 0
    ys_parts = []
    for seg, n in zip(layers.segments, layers.sizes):
        xs = tuple(jax.tree_util.tree_map(lambda a: a[lo:lo + n], xa)
                   for xa in per_layer_xs)
        x, ys = jax.lax.scan(body, x, (seg, *xs))
        ys_parts.append(ys)
        lo += n
    ys = jax.tree_util.tree_map(lambda *parts: jnp.concatenate(parts,
                                                               axis=0),
                                *ys_parts)
    return x, ys


def _run_homogeneous(p: Params, cfg, plan, x, make_cache: bool,
                     init_states=None):
    """Scan over stacked layers. Returns (x, caches, aux, states)."""
    L = cfg.n_layers

    def body(x, xs):
        lp, st = xs
        from repro.core.apply import dequantize_qt_tree
        lp = dequantize_qt_tree(lp, dtype_of(cfg.compute_dtype))
        rwkv_state = st.get("rwkv") if st else None
        ssm_state = st.get("ssm") if st else None
        x, cache, aux, new_state = tfm.layer_full(
            lp, x, cfg, plan, make_cache,
            rwkv_state=rwkv_state, ssm_state=ssm_state)
        x = plan.constrain(x, "residual")
        return x, (cache, aux, new_state)

    if plan.remat:
        body = jax.checkpoint(body)

    def scan_fn(carry, xs):
        x2, ys = body(carry, xs)
        return x2, ys

    x, (caches, auxs, states) = scan_layers(scan_fn, x, p["layers"],
                                            init_states)
    return x, caches, jnp.sum(auxs), states


def _run_vlm(p: Params, cfg, plan, x, make_cache: bool, vision_embeds):
    g, spg = _vlm_group_counts(cfg)
    ve = jnp.einsum("bnv,vd->bnd", vision_embeds.astype(x.dtype),
                    p["vision_proj"].astype(x.dtype))

    def self_body(x, lp):
        x, cache, _, _ = tfm.layer_full(lp, x, cfg, plan, make_cache)
        return plan.constrain(x, "residual"), cache

    if plan.remat:
        self_body = jax.checkpoint(self_body)

    def group_body(x, xs):
        gp_self, gp_cross = xs
        x, caches = jax.lax.scan(self_body, x, gp_self)
        vkv = tfm.vision_kv_for_layer(gp_cross, ve)
        x = tfm.cross_layer_full(gp_cross, x, cfg, plan, vkv)
        x = plan.constrain(x, "residual")
        return x, (caches, vkv if make_cache else None)

    if plan.remat:
        group_body = jax.checkpoint(group_body)
    x, (caches, vkvs) = jax.lax.scan(group_body, x,
                                     (p["groups"]["self"], p["groups"]["cross"]))
    return x, caches, vkvs


def forward(p: Params, cfg, plan: BuildPlan, tokens: Array,
            vision_embeds: Optional[Array] = None,
            embeds: Optional[Array] = None,
            make_cache: bool = False):
    """Returns (logits, aux, cache_pytree_or_None)."""
    cd = dtype_of(cfg.compute_dtype)
    if cfg.family == "encoder":
        x = embeds.astype(cd)
        T = x.shape[1]
        x = x + p["pos_embed"][:T].astype(cd)
        x, _, aux, _ = _run_homogeneous(p, cfg, plan, x, False)
        from repro.models.common import apply_norm
        x = apply_norm(p["final_norm"], x, cfg)
        pooled = x.mean(axis=1)
        logits = jnp.einsum("bd,dc->bc", pooled, p["cls_head"].astype(cd))
        return logits.astype(jnp.float32), aux, None

    x = embed_tokens(p, cfg, plan, tokens)
    B, T = tokens.shape

    cache = None
    if cfg.family == "vlm":
        x, kv, vkv = _run_vlm(p, cfg, plan, x, make_cache, vision_embeds)
        aux = jnp.float32(0.0)
        if make_cache:
            cache = {"kv": kv, "xkv": vkv}
    else:
        init_states = None
        if cfg.attn_free:
            init_states = {"rwkv": _stacked_rwkv_state(cfg, B)}
        elif cfg.parallel_ssm_heads:
            init_states = {"ssm": _stacked_ssm_state(cfg, B)}
        x, kv, aux, states = _run_homogeneous(p, cfg, plan, x, make_cache,
                                              init_states)
        if make_cache:
            cache = {}
            if kv is not None:
                cache["kv"] = kv
            if cfg.attn_free:
                cache["rwkv"] = states
            elif cfg.parallel_ssm_heads:
                cache["ssm"] = states

    from repro.models.common import apply_norm
    x = apply_norm(p["final_norm"], x, cfg)
    logits = unembed(p, cfg, plan, x)
    return logits, aux, cache


def _stacked_rwkv_state(cfg, batch):
    L = cfg.n_layers
    s = rwkv_mod.init_rwkv_state(batch, cfg)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L, *a.shape)), s)


def _stacked_ssm_state(cfg, batch, layers=None):
    L = layers if layers is not None else cfg.n_layers
    s = ssm_mod.init_ssm_state(batch, cfg)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L, *a.shape)), s)


# ---------------------------------------------------------------------------
# loss (vocab-shard-friendly cross entropy with z-loss)
# ---------------------------------------------------------------------------

def lm_loss(p: Params, cfg, plan: BuildPlan, batch: Dict[str, Array],
            z_loss: float = 1e-4, aux_weight: float = 1e-2):
    if cfg.family == "encoder":
        logits, aux, _ = forward(p, cfg, plan, None, embeds=batch["embeds"])
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(lse - ll)
        return loss, {"loss": loss, "aux": aux}
    logits, aux, _ = forward(p, cfg, plan, batch["tokens"],
                             vision_embeds=batch.get("vision_embeds"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(logits * onehot, axis=-1)
    nll = lse - ll
    loss = jnp.mean(nll)
    zl = z_loss * jnp.mean(jnp.square(lse))
    total = loss + zl + aux_weight * aux
    return total, {"loss": loss, "z_loss": zl, "aux": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def cache_len_for(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg, plan: BuildPlan, batch: int, seq_len: int):
    """Allocate an empty cache pytree for decode at context length seq_len."""
    clen = cache_len_for(cfg, seq_len)
    hd = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    if cfg.attn_free:
        cache["rwkv"] = _stacked_rwkv_state(cfg, batch)
        return cache
    L = cfg.n_layers
    if cfg.family == "vlm":
        g, spg = _vlm_group_counts(cfg)
        kv = init_kv_cache(batch, clen, cfg.n_kv_heads, hd, plan.cache_dtype,
                           quantized=plan.cache_quant)
        cache["kv"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (g, spg, *a.shape)), kv)
        nv = cfg.cross_attn.n_vision_tokens
        cache["xkv"] = (
            jnp.zeros((g, batch, nv, cfg.n_kv_heads, hd), plan.cache_dtype),
            jnp.zeros((g, batch, nv, cfg.n_kv_heads, hd), plan.cache_dtype))
        return cache
    kv = init_kv_cache(batch, clen, cfg.n_kv_heads, hd, plan.cache_dtype,
                       quantized=plan.cache_quant)
    cache["kv"] = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L, *a.shape)), kv)
    if cfg.parallel_ssm_heads:
        cache["ssm"] = _stacked_ssm_state(cfg, batch)
    return cache


def prefill(p: Params, cfg, plan: BuildPlan, tokens: Array,
            vision_embeds: Optional[Array] = None):
    logits, _, cache = forward(p, cfg, plan, tokens,
                               vision_embeds=vision_embeds, make_cache=True)
    return logits[:, -1], cache


def decode_step(p: Params, cfg, plan: BuildPlan, cache, tokens: Array,
                pos: Array):
    """tokens: (B, 1) int32; pos: scalar int32 absolute position.

    Layer params may carry quantized (QT) leaves: they are dequantized
    *inside* the scan body, so HBM streams int4/int8 codes per layer."""
    from repro.core.apply import dequantize_qt_tree
    x = embed_tokens(p, cfg, plan, tokens)

    if cfg.attn_free:
        def body(x, xs):
            lp, st = xs
            lp = dequantize_qt_tree(lp, dtype_of(cfg.compute_dtype))
            x, _, new_rwkv, _ = tfm.layer_decode(lp, x, cfg, plan, None, pos,
                                                 rwkv_state=st)
            return plan.constrain(x, "residual"), new_rwkv
        x, new_states = scan_layers(body, x, p["layers"], cache["rwkv"])
        new_cache = {"rwkv": new_states}
    elif cfg.family == "vlm":
        def self_body(x, xs):
            lp, kv = xs
            lp = dequantize_qt_tree(lp, dtype_of(cfg.compute_dtype))
            x, kv, _, _ = tfm.layer_decode(lp, x, cfg, plan, kv, pos)
            return plan.constrain(x, "residual"), kv

        def group_body(x, xs):
            gp_self, gp_cross, kv, xkv = xs
            x, new_kv = jax.lax.scan(self_body, x, (gp_self, kv))
            x, _, _, _ = tfm.layer_decode(dequantize_qt_tree(gp_cross, dtype_of(cfg.compute_dtype)), x,
                                          cfg, plan, None, pos,
                                          vision_kv=xkv, is_cross=True)
            return plan.constrain(x, "residual"), new_kv

        x, new_kv = jax.lax.scan(
            group_body, x,
            (p["groups"]["self"], p["groups"]["cross"], cache["kv"],
             cache["xkv"]))
        new_cache = {"kv": new_kv, "xkv": cache["xkv"]}
    else:
        has_ssm = cfg.parallel_ssm_heads

        def body(x, xs):
            lp, kv, st = xs
            # keep_fused: COMQ-layout QT projections stay packed and route
            # through the dequant-fused quant_matmul (core/apply.qt_linear)
            lp = dequantize_qt_tree(lp, dtype_of(cfg.compute_dtype),
                                    keep_fused=True)
            x, kv, _, new_ssm = tfm.layer_decode(lp, x, cfg, plan, kv, pos,
                                                 ssm_state=st)
            return plan.constrain(x, "residual"), (kv, new_ssm)

        ssm_in = cache.get("ssm") if has_ssm else None
        x, (new_kv, new_ssm) = scan_layers(body, x, p["layers"],
                                           cache["kv"], ssm_in)
        new_cache = {"kv": new_kv}
        if has_ssm:
            new_cache["ssm"] = new_ssm

    from repro.models.common import apply_norm
    x = apply_norm(p["final_norm"], x, cfg)
    logits = unembed(p, cfg, plan, x)
    return logits[:, 0], new_cache


def decode_step_paged(p: Params, cfg, plan: BuildPlan, pool, block_tables,
                      tokens: Array, pos: Array):
    """One continuous-batching decode step against a paged KV pool.

    tokens: (B, 1) int32; pos: (B,) int32 absolute write positions per slot
    (-1 = inactive slot: K/V write dropped, logits garbage the runtime
    masks); pool: {"k","v"} of (L, NB, BS, KV, hd) pages (serve/kv_cache);
    block_tables: (B, MAXB) physical page ids.

    Unlike `decode_step`, every slot carries its own position — a mixed-
    length, staggered-arrival batch decodes in one jitted program. Covers
    the attention families (dense/MoE/GQA/SWA); attention-free, parallel-
    SSM and VLM archs keep the dense-cache path."""
    if cfg.attn_free or cfg.parallel_ssm_heads or cfg.family == "vlm":
        raise NotImplementedError(
            f"paged decode does not cover family={cfg.family!r} "
            "(attention-free / ssm / vlm use the dense-cache decode_step)")
    from repro.core.apply import dequantize_qt_tree
    x = embed_tokens(p, cfg, plan, tokens)
    cd = dtype_of(cfg.compute_dtype)

    if plan.kv_bits:
        # quantized pool: per-(layer, page, kv_head) scales ride the scan
        # as two extra per-layer operands (DESIGN.md §11)
        def body(x, xs):
            lp, kl, vl, ksl, vsl = xs
            lp = dequantize_qt_tree(lp, cd, keep_fused=True)
            x, kl, vl, ksl, vsl = tfm.layer_decode_paged(
                lp, x, cfg, plan, kl, vl, block_tables, pos, ksl, vsl)
            return plan.constrain(x, "residual"), (kl, vl, ksl, vsl)

        x, (nk, nv, nks, nvs) = scan_layers(
            body, x, p["layers"], pool["k"], pool["v"],
            pool["k_scale"], pool["v_scale"])
        new_pool = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    else:
        def body(x, xs):
            lp, kl, vl = xs
            lp = dequantize_qt_tree(lp, cd, keep_fused=True)
            x, kl, vl = tfm.layer_decode_paged(lp, x, cfg, plan, kl, vl,
                                               block_tables, pos)
            return plan.constrain(x, "residual"), (kl, vl)

        x, (nk, nv) = scan_layers(body, x, p["layers"], pool["k"],
                                  pool["v"])
        new_pool = {"k": nk, "v": nv}
    from repro.models.common import apply_norm
    x = apply_norm(p["final_norm"], x, cfg)
    logits = unembed(p, cfg, plan, x)
    return logits[:, 0], new_pool


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins for the dry-run; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape, plan: Optional[BuildPlan] = None) -> Dict[str, Any]:
    """Stand-ins for every model input of `shape` (a ShapeConfig)."""
    plan = plan or BuildPlan()
    gb, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "encoder":
        return {
            "embeds": jax.ShapeDtypeStruct((gb, 197, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((gb,), i32),
        }
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((gb, T), i32)
        specs["labels"] = jax.ShapeDtypeStruct((gb, T), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((gb, T), i32)
    else:  # decode: one new token against a cache of length T
        specs["tokens"] = jax.ShapeDtypeStruct((gb, 1), i32)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
        cache = jax.eval_shape(lambda: init_cache(cfg, plan, gb, T))
        specs["cache"] = cache
    if cfg.family == "vlm" and shape.kind != "decode":
        ca = cfg.cross_attn
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (gb, ca.n_vision_tokens, ca.vision_dim), jnp.bfloat16)
    return specs
