from repro.ckpt.checkpoint import CheckpointManager  # noqa: F401
from repro.ckpt.quantized import (pack_tree, strip_for_serving,  # noqa: F401
                                  tree_bytes, unpack_tree)
