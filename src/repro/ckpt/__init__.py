from repro.ckpt.checkpoint import CheckpointManager  # noqa: F401
from repro.ckpt.quantized import pack_tree, tree_bytes, unpack_tree  # noqa: F401
