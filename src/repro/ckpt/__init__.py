from repro.ckpt.checkpoint import CheckpointManager  # noqa: F401
from repro.ckpt.quantized import (pack_tree, policy_extra,  # noqa: F401
                                  restore_policy, strip_for_serving,
                                  tree_bytes, unpack_tree)
