from repro.ckpt.checkpoint import CheckpointManager  # noqa: F401
from repro.ckpt.quantized import (PackedCkptError, load_packed_ckpt,  # noqa: F401,E501
                                  pack_tree, policy_extra, restore_policy,
                                  save_packed_ckpt, strip_for_serving,
                                  tree_bytes, unpack_tree)
