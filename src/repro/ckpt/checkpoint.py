"""Checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/
             arrays.npz          flattened pytree leaves (key = path)
             treedef.json        structure + metadata (step, loader state)
             _COMMITTED          sentinel written last (atomicity marker)

* **Atomic**: writes go to `step_<N>.tmp/` and are `os.rename`d into place
  after the commit sentinel is written, so a crash mid-write never produces
  a checkpoint that `latest_step` will pick up.
* **Async**: `save(..., blocking=False)` snapshots leaves to host memory
  (device_get) synchronously — cheap relative to serialization — and runs
  the serialization/IO on a background thread (double-buffered; at most one
  in flight, the trainer never blocks on disk).
* **Elastic**: leaves are saved as *global* (fully-replicated host) arrays;
  `restore(..., shardings=...)` re-shards onto whatever mesh the restart
  runs with — a different device count than the save is fine (the elastic
  scaling path, tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

PyTree = Any
SENTINEL = "_COMMITTED"


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directories need an O_RDONLY
    fd; some platforms refuse to fsync one — best-effort there)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _flatten_with_paths(tree: PyTree) -> Dict[str, Any]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- discovery ----------------------------------------------------------

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(path, SENTINEL)):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None,
             blocking: bool = True):
        """Snapshot to host memory now; serialize now or on the saver thread."""
        self.wait()  # at most one async save in flight
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten_with_paths(tree).items()}
        meta = {"step": step, "extra": extra or {},
                "time": time.time()}
        if blocking:
            self._write(step, host, meta)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta),
                daemon=True)
            self._thread.start()

    def _write_guarded(self, step, host, meta):
        try:
            self._write(step, host, meta)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "treedef.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, SENTINEL), "w") as f:
            f.write("ok")
        # fsync every file plus the tmp dir before the rename, and the
        # parent dir after: rename alone orders nothing on most
        # filesystems — a power-loss right after could otherwise publish
        # a committed-looking checkpoint with unwritten array bytes
        for name in os.listdir(tmp):
            _fsync_path(os.path.join(tmp, name))
        _fsync_path(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_path(self.dir)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def restore(self, step: Optional[int], like: PyTree,
                shardings: Optional[PyTree] = None):
        """Restore into the structure of `like`. `shardings` (same structure)
        re-shards each leaf with jax.device_put — the elastic path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "treedef.json")) as f:
            meta = json.load(f)

        flat_like = _flatten_with_paths(like)
        keys = list(flat_like.keys())
        missing = [k for k in keys if k not in data.files]
        # state grown after the checkpoint was written is backfilled from
        # the freshly-initialized template instead of erroring: the int8
        # first-moment "ef" residual (zero-residual ≠ zero *bytes* — the
        # init encoding carries the right packed codes) and the int8_ef
        # "grad_err" carry (zeros). Anything else missing is still fatal.
        optional = [k for k in missing
                    if k.split("/")[-1] == "ef" or k.startswith("grad_err")]
        hard = [k for k in missing if k not in optional]
        if hard:
            raise KeyError(f"checkpoint missing {len(hard)} leaves, e.g. "
                           f"{hard[:3]}")
        if optional:
            import warnings
            warnings.warn(f"checkpoint predates {len(optional)} optional "
                          f"state leaves (e.g. {optional[:2]}); backfilling "
                          "from the initialized template", stacklevel=2)
        leaves = [data[k] if k in data.files else np.asarray(flat_like[k])
                  for k in keys]
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta
