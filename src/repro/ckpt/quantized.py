"""Quantized checkpoint format: COMQ codes packed to their bit width.

A quantized model checkpoint stores, per QTensor: packed codes (int4: two
per byte), f32 scales and int32 zero-points — 4.25 bits/param at b=4 vs 16
for bf16. `pack_tree`/`unpack_tree` convert between the runtime QTensor
pytree and the storage form; CheckpointManager handles the IO.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import is_qtensor
from repro.core.quantizer import pack_int4, unpack_int4


def pack_tree(tree):
    def walk(node):
        if is_qtensor(node):
            codes = node["codes"]
            n_last = codes.shape[-1]
            packed4 = (n_last % 2 == 0 and
                       int(jnp.max(codes)) < 16)
            out = dict(node)
            if packed4:
                out["codes"] = pack_int4(codes)
                out["packed4"] = True
                out["unpacked_last"] = n_last
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(tree)


def unpack_tree(tree):
    def walk(node):
        if is_qtensor(node):
            out = dict(node)
            if out.pop("packed4", False):
                out["codes"] = unpack_int4(node["codes"])
                out.pop("unpacked_last", None)
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(tree)


def strip_for_serving(qparams):
    """Drop the stacked dense copies of quantized layers from a
    `quantize_model` output — the on-disk checkpoint form (4.25 bits/param
    instead of carrying both the codes *and* the superseded dense stack).
    Everything serving needs survives: the top-level params and the
    __qlayers__ table, which stores each layer's dense non-quantized
    leaves (norms, biases) alongside its QTensors — for VLM trees the
    per-group "groups" stacks are dropped the same way. `core.
    serving_params` and `core.materialize` both accept the stripped
    form."""
    return {k: v for k, v in qparams.items()
            if k not in ("layers", "groups")}


def tree_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "size"))
