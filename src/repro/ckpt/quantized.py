"""Quantized checkpoint format: COMQ codes packed to their bit width.

A quantized model checkpoint stores, per QTensor: packed codes (2-bit:
four per byte, 3/4-bit: two per byte, 5..8-bit: one per byte), f32 scales
and int32 zero-points — 4.25 bits/param at b=4 vs 16 for bf16, 2.25 at
b=2 (see DESIGN.md §6 for the bytes-per-param table). The pack width
comes from the QTensor's recorded `bits` (per-leaf mixed-precision
policies make this vary leaf-to-leaf); code values are never inspected.
`pack_tree`/`unpack_tree` convert between the runtime QTensor pytree and
the storage form; CheckpointManager handles the IO. `policy_extra` builds
the checkpoint `extra` metadata that records which policy produced the
codes, so a served checkpoint is self-describing.
"""
from __future__ import annotations

import os
import pickle
import warnings
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import is_qtensor, qtensor_bits
from repro.core.quantizer import pack_codes, unpack_codes


def pack_tree(tree):
    def walk(node):
        if is_qtensor(node):
            bits = qtensor_bits(node)
            codes = node["codes"]
            packed, cpb = pack_codes(codes, bits)
            out = dict(node)
            if cpb > 1:
                out["codes"] = packed
                out["packed_cpb"] = cpb
                out["unpacked_last"] = codes.shape[-1]
                if cpb == 2:
                    # back-compat alias for pre-policy readers
                    out["packed4"] = True
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(tree)


def unpack_tree(tree):
    def walk(node):
        if is_qtensor(node):
            out = dict(node)
            cpb = out.pop("packed_cpb", None)
            if cpb is None and out.get("packed4"):
                cpb = 2            # pre-policy checkpoint
            out.pop("packed4", None)
            if cpb:
                out["codes"] = unpack_codes(node["codes"], int(cpb))
                out.pop("unpacked_last", None)
            if "bits" not in out:
                # pre-policy checkpoint: backfill the width its storage
                # implies (nibble-packed => 4) so a re-pack or the packed
                # serving path keeps the original density instead of
                # defaulting to one code per byte
                out["bits"] = 4 if cpb == 2 else 8
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(tree)


def policy_extra(policy=None, arch: Optional[str] = None,
                 **kw) -> Dict[str, Any]:
    """Checkpoint `extra` metadata for a quantized save: the arch plus the
    serialized QuantPolicy (core.policy.policy_to_dict) so a restore can
    rebuild the exact per-leaf bit assignment without re-measuring."""
    out: Dict[str, Any] = dict(kw)
    if arch is not None:
        out["arch"] = arch
    if policy is not None:
        from repro.core.policy import as_policy, policy_to_dict
        out["policy"] = policy_to_dict(as_policy(policy))
    return out


def restore_policy(extra: Dict[str, Any]):
    """Inverse of policy_extra: the QuantPolicy a checkpoint was solved
    under, or None for pre-policy checkpoints."""
    if not extra or "policy" not in extra:
        return None
    from repro.core.policy import policy_from_dict
    return policy_from_dict(extra["policy"])


def strip_for_serving(qparams):
    """Drop the stacked dense copies of quantized layers from a
    `quantize_model` output — the on-disk checkpoint form (4.25 bits/param
    instead of carrying both the codes *and* the superseded dense stack).
    Everything serving needs survives: the top-level params and the
    __qlayers__ table, which stores each layer's dense non-quantized
    leaves (norms, biases) alongside its QTensors — for VLM trees the
    per-group "groups" stacks are dropped the same way. `core.
    serving_params` and `core.materialize` both accept the stripped
    form."""
    return {k: v for k, v in qparams.items()
            if k not in ("layers", "groups")}


def tree_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "size"))


# -- packed single-file checkpoints (launch/serve --save/--load-quantized) ---

PACKED_FORMAT = "comq-packed-qt"
PACKED_VERSION = 1


class PackedCkptError(RuntimeError):
    """A packed quantized checkpoint failed validation (truncated file,
    checksum mismatch, wrong format/version) — raised with a clear
    message instead of the deep unflatten crash a blind pickle load
    produced."""


def save_packed_ckpt(path: str, tree, fault_cb=None, **meta) -> int:
    """Write a packed quantized tree (host arrays) as a self-describing
    single file: a format/version header plus a crc32 over the pickled
    payload, so a truncated or corrupted file fails loudly at load.

    The write is atomic and durable — tmp + flush + fsync + os.replace —
    so a kill at any instant leaves either the old file or the new one,
    never a torn write. `fault_cb` (fault injection) runs between the
    durable tmp write and the rename: exactly the torn-write window the
    quantization journal's durability ordering must survive. Returns the
    payload crc32 (what the journal records per spilled leaf)."""
    payload = pickle.dumps({"tree": tree, **meta})
    crc = zlib.crc32(payload)
    blob = {"format": PACKED_FORMAT, "version": PACKED_VERSION,
            "crc32": crc, "payload": payload}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        pickle.dump(blob, f)
        f.flush()
        os.fsync(f.fileno())
    if fault_cb is not None:
        fault_cb()
    os.replace(tmp, path)
    return crc


def load_packed_ckpt(path: str, expect_crc: Optional[int] = None
                     ) -> Dict[str, Any]:
    """Load + validate a packed checkpoint; returns the payload dict
    ({"tree": ..., **meta}). Pre-header files (a bare {"tree", "bits",
    "arch"} pickle) still load, with a warning — re-save to upgrade.
    `expect_crc` (the quantization journal's per-leaf record) must match
    the header crc exactly — a valid-but-different file is as wrong as a
    corrupt one when resuming a run."""
    try:
        with open(path, "rb") as f:
            blob = pickle.load(f)
    except (pickle.UnpicklingError, EOFError, AttributeError) as e:
        raise PackedCkptError(
            f"{path}: not a readable packed checkpoint — the file is "
            f"truncated or corrupt ({type(e).__name__}: {e})") from e
    if not isinstance(blob, dict):
        raise PackedCkptError(f"{path}: unexpected object of type "
                              f"{type(blob).__name__}")
    if "format" not in blob:
        if "tree" not in blob:
            raise PackedCkptError(
                f"{path}: neither a headered packed checkpoint nor a "
                "legacy tree blob (keys: " + ", ".join(sorted(blob)) + ")")
        if expect_crc is not None:
            raise PackedCkptError(
                f"{path}: legacy headerless checkpoint has no checksum "
                f"to match the expected {expect_crc:#010x}")
        warnings.warn(f"{path}: legacy headerless packed checkpoint — "
                      "no checksum to verify; re-save to upgrade",
                      stacklevel=2)
        return blob
    if blob["format"] != PACKED_FORMAT:
        raise PackedCkptError(f"{path}: format {blob['format']!r} is not "
                              f"{PACKED_FORMAT!r}")
    if blob["version"] > PACKED_VERSION:
        raise PackedCkptError(
            f"{path}: version {blob['version']} is newer than this "
            f"reader ({PACKED_VERSION}) — upgrade the code")
    payload = blob["payload"]
    crc = zlib.crc32(payload)
    if crc != blob["crc32"]:
        raise PackedCkptError(
            f"{path}: checksum mismatch (stored {blob['crc32']:#010x}, "
            f"computed {crc:#010x}) — the checkpoint is corrupt")
    if expect_crc is not None and crc != int(expect_crc):
        raise PackedCkptError(
            f"{path}: checksum {crc:#010x} does not match the journaled "
            f"{int(expect_crc):#010x} — the spill was replaced or the "
            "journal belongs to a different run")
    return pickle.loads(payload)
