"""Pallas TPU kernel: block-causal flash attention with native GQA.

Grid (BH, Tq/bq, Tk/bk) with the KV dimension innermost; online-softmax
state (m, l, acc) lives in VMEM scratch and is re-initialized at kv step 0.
Causal (and sliding-window) masking is applied with in-register iota
compares on the diagonal band; fully-masked blocks are skipped with
`pl.when`, so — unlike a dense masked attention — no MXU work is issued
above the diagonal or outside the SWA band.

GQA is expressed in the K/V BlockSpec index maps (`bh // group`), so K/V
are never materialized per-q-head in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, scale: float, causal: bool,
            window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = qi * bq
    k0 = ki * bk
    # block is live iff it intersects the causal band
    live = True
    if causal:
        live = k0 <= q0 + bq - 1
        if window > 0:
            live = jnp.logical_and(live, q0 - (k0 + bk - 1) < window)

    @pl.when(live if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = qpos >= kpos
            if window > 0:
                mask = jnp.logical_and(mask, qpos - kpos < window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False) -> Array:
    """q: (BH, Tq, hd); k/v: (BHkv, Tk, hd), BH % BHkv == 0 (GQA groups).

    Returns (BH, Tq, hd) in q.dtype."""
    BH, Tq, hd = q.shape
    BHkv, Tk, _ = k.shape
    group = BH // BHkv
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    while Tq % bq:
        bq //= 2
    while Tk % bk:
        bk //= 2
    n_k = Tk // bk
    scale = 1.0 / float(hd) ** 0.5
    grid = (BH, Tq // bq, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, scale=scale,
                          causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
