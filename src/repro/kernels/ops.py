"""jit'd public wrappers around the Pallas kernels with backend dispatch.

On TPU the real kernels run; on CPU (this container) `interpret=True`
executes the kernel body for correctness tests, and the `xla` mode uses the
pure-jnp oracle (what the dry-run lowers — Pallas does not lower to the
host platform). Mode resolution: explicit arg > REPRO_KERNEL_MODE env >
backend default.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.comq_panel import (comq_panel_dq_pallas,
                                      comq_panel_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (paged_attention_pallas,
                                           paged_attention_quant_pallas)
from repro.kernels.quant_matmul import quant_matmul_pallas

Array = jax.Array


def resolve_mode(mode: Optional[str] = None) -> str:
    if mode:
        return mode
    env = os.environ.get("REPRO_KERNEL_MODE")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("bits", "cpb", "mode", "out_dtype"))
def quant_matmul(x: Array, codes_u: Array, scale: Array, z_lo: Array, *,
                 bits: int = 8, cpb: Optional[int] = None,
                 mode: Optional[str] = None,
                 out_dtype=jnp.float32) -> Array:
    """Y = X · (scale ⊙ (codes + z)) — bits-dispatched.

    `cpb` is the storage density (codes per byte, quantizer.codes_per_byte;
    defaults to the historical rule: nibble-packed iff bits==4). The Pallas
    kernel covers every layout — cpb ∈ {1, 2, 4}: unpacked any-bit codes,
    nibble-packed 3/4-bit codes, and the quad-packed 2-bit 4-per-byte
    layout (in-register quad unpack, so 2-bit decode streams a quarter of
    the bytes instead of paying an XLA unpack materialization)."""
    mode = resolve_mode(mode)
    if cpb is None:
        cpb = 2 if bits == 4 else 1
    if mode == "xla":
        from repro.core.quantizer import unpack_codes
        u = unpack_codes(codes_u, cpb)
        return ref.quant_matmul_ref(x, u, scale, z_lo, out_dtype=out_dtype)
    return quant_matmul_pallas(x, codes_u, scale, z_lo, cpb=cpb,
                               out_dtype=out_dtype,
                               interpret=(mode == "interpret"))


def comq_panel(h_bb: Array, s0: Array, qf: Array, delta: Array, z_lo: Array,
               z_hi: Array, hdiag: Array, *, mode: Optional[str] = None
               ) -> Array:
    mode = resolve_mode(mode)
    if mode == "xla":
        return ref.comq_panel_ref(h_bb, s0, qf, delta, z_lo, z_hi, hdiag)
    return comq_panel_pallas(h_bb, s0, qf, delta,
                             jnp.asarray(z_lo, jnp.float32),
                             jnp.asarray(z_hi, jnp.float32), hdiag,
                             interpret=(mode == "interpret"))


def comq_panel_dq(h_bb: Array, s0: Array, qf: Array, delta: Array,
                  z_lo: Array, z_hi: Array, hdiag: Array, *,
                  mode: Optional[str] = None):
    """Fused panel sweep returning (qf', ΔW) — ΔW = (qf' − qf)·δ feeds the
    blocked solver's trailing update as one dense matmul (DESIGN.md §3.3)."""
    mode = resolve_mode(mode)
    if mode == "xla":
        return ref.comq_panel_dq_ref(h_bb, s0, qf, delta, z_lo, z_hi, hdiag)
    return comq_panel_dq_pallas(h_bb, s0, qf, delta,
                                jnp.asarray(z_lo, jnp.float32),
                                jnp.asarray(z_hi, jnp.float32), hdiag,
                                interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("window", "mode"))
def paged_attention(q: Array, k_pool: Array, v_pool: Array,
                    block_tables: Array, lengths: Array, *,
                    window: int = 0, mode: Optional[str] = None) -> Array:
    """Decode attention over a paged KV pool (serve/kv_cache.py layout):
    q (B, Hp, hd) single query token per slot; block_tables (B, MAXB)
    physical page ids; lengths (B,) valid tokens (0 = inactive slot)."""
    mode = resolve_mode(mode)
    if mode == "xla":
        return ref.paged_attention_ref(q, k_pool, v_pool, block_tables,
                                       lengths, window=window).astype(q.dtype)
    return paged_attention_pallas(q, k_pool, v_pool, block_tables, lengths,
                                  window=window,
                                  interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("window", "kv_bits", "mode"))
def paged_attention_quant(q: Array, k_pool: Array, v_pool: Array,
                          k_scale: Array, v_scale: Array,
                          block_tables: Array, lengths: Array, *,
                          window: int = 0, kv_bits: int = 8,
                          mode: Optional[str] = None) -> Array:
    """Decode attention over a *quantized* paged pool: k_pool/v_pool hold
    integer codes (int8 / packed 4-bit) and k_scale/v_scale (NB, KV) the
    per-(page, kv_head) scales. The Pallas path streams codes and folds
    the scales inside the kernel; `xla` takes the dequantizing oracle."""
    mode = resolve_mode(mode)
    if mode == "xla":
        return ref.paged_attention_quant_ref(
            q, k_pool, v_pool, k_scale, v_scale, block_tables, lengths,
            window=window, kv_bits=kv_bits).astype(q.dtype)
    return paged_attention_quant_pallas(q, k_pool, v_pool, k_scale, v_scale,
                                        block_tables, lengths, window=window,
                                        kv_bits=kv_bits,
                                        interpret=(mode == "interpret"))


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, mode: Optional[str] = None) -> Array:
    mode = resolve_mode(mode)
    if mode == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window).astype(q.dtype)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=(mode == "interpret"))
