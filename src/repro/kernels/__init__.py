"""Pallas TPU kernels for COMQ's compute hot-spots.

- quant_matmul:  dequant-fused GEMM over COMQ int4/int8 codes (serving)
- comq_panel:    in-VMEM lazy coordinate sweep (quantization solve); the
  fused `comq_panel_dq` variant also emits the scaled code delta ΔW that
  drives the blocked solver's trailing update (DESIGN.md §3.2–3.3)
- flash_attention: block-causal flash with GQA index maps (train/prefill)
- paged_attention: decode attention over a block-table KV page pool with
  scalar-prefetched page indexing (continuous-batching serve; DESIGN §5.1)

Each <name>.py holds the pl.pallas_call + BlockSpec; ops.py the jit'd
wrappers; ref.py the pure-jnp oracles used by the shape/dtype sweep tests.
"""
