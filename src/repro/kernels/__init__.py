"""Pallas TPU kernels for COMQ's compute hot-spots.

- quant_matmul:  dequant-fused GEMM over COMQ int4/int8 codes (serving)
- comq_panel:    in-VMEM sequential coordinate sweep (quantization solve)
- flash_attention: block-causal flash with GQA index maps (train/prefill)

Each <name>.py holds the pl.pallas_call + BlockSpec; ops.py the jit'd
wrappers; ref.py the pure-jnp oracles used by the shape/dtype sweep tests.
"""
