"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quant_matmul_ref(x: Array, codes_u: Array, scale: Array, z_lo: Array,
                     out_dtype=jnp.float32) -> Array:
    """x: (M, K); codes_u: (K, N) uint8 offset-binary; scale/z_lo: (N,).

    Y = X · W_q,  W_q[k, n] = scale[n] · (codes_u[k, n] + z_lo[n]).
    """
    w = (codes_u.astype(jnp.float32) + z_lo.astype(jnp.float32)) * scale
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def quant_matmul_packed_ref(x: Array, codes_p: Array, scale: Array,
                            z_lo: Array, *, cpb: int,
                            out_dtype=jnp.float32) -> Array:
    """Packed-storage oracle: codes_p (K, N/cpb) at `cpb` codes per byte
    (quantizer.codes_per_byte — 4 for 2-bit, 2 for 3/4-bit, 1 pass-through)
    is unpacked then contracted; ground truth for every mixed-precision
    storage layout the serve path streams."""
    from repro.core.quantizer import unpack_codes
    return quant_matmul_ref(x, unpack_codes(codes_p, cpb), scale, z_lo,
                            out_dtype=out_dtype)


def paged_attention_ref(q: Array, k_pool: Array, v_pool: Array,
                        block_tables: Array, lengths: Array, *,
                        window: int = 0) -> Array:
    """q: (B, H, hd); k_pool/v_pool: (NB, BS, KV, hd); block_tables:
    (B, MAXB); lengths: (B,). Pure-XLA oracle: gather the slot's pages into
    a contiguous (B, MAXB·BS, KV, hd) view, then masked softmax attention.
    Inactive slots (length 0) return exact zeros, matching the kernel."""
    B, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    S = block_tables.shape[1] * BS
    idx = (block_tables[:, :, None] * BS
           + jnp.arange(BS, dtype=jnp.int32)[None, None]).reshape(B, S)
    kg = k_pool.reshape(NB * BS, KV, hd)[idx].astype(jnp.float32)
    vg = v_pool.reshape(NB * BS, KV, hd)[idx].astype(jnp.float32)
    g = H // KV
    qg = q.astype(jnp.float32).reshape(B, KV, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bkgh,bskh->bkgs", qg, kg) * scale
    kpos = jnp.arange(S, dtype=jnp.int32)[None]
    mask = kpos < lengths[:, None]
    if window > 0:
        mask &= (lengths[:, None] - 1) - kpos < window
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where((lengths > 0)[:, None, None, None], p, 0.0)
    return jnp.einsum("bkgs,bskh->bkgh", p, vg).reshape(B, H, hd)


def paged_attention_quant_ref(q: Array, k_pool: Array, v_pool: Array,
                              k_scale: Array, v_scale: Array,
                              block_tables: Array, lengths: Array, *,
                              window: int = 0, kv_bits: int = 8) -> Array:
    """Quantized-pool oracle: k_pool/v_pool hold integer codes
    (NB, BS, KV, hd/cpb — int8, or packed 4-bit nibble pairs) with one f32
    scale per (page, kv_head) in k_scale/v_scale (NB, KV). Dequantizes
    page-wise with `serve.kv_cache.kv_decode` and delegates to the bf16
    oracle — the ground truth both the Pallas in-kernel dequant and the
    XLA gather fallback must match."""
    from repro.serve.kv_cache import kv_decode
    kd = kv_decode(k_pool, k_scale[:, None], kv_bits)   # (NB, BS, KV, hd)
    vd = kv_decode(v_pool, v_scale[:, None], kv_bits)
    return paged_attention_ref(q, kd, vd, block_tables, lengths,
                               window=window)


def comq_panel_ref(h_bb: Array, s0: Array, qf: Array, delta: Array,
                   z_lo: Array, z_hi: Array, hdiag: Array) -> Array:
    """Intra-panel COMQ sweep oracle — delegates to the core reference."""
    from repro.core.comq_hessian import panel_sweep_ref
    return panel_sweep_ref(h_bb, s0, qf, delta, z_lo, z_hi, hdiag)


def comq_panel_dq_ref(h_bb: Array, s0: Array, qf: Array, delta: Array,
                      z_lo: Array, z_hi: Array, hdiag: Array):
    """Fused (qf', ΔW) panel sweep oracle — delegates to the core ref."""
    from repro.core.comq_hessian import panel_sweep_dq_ref
    return panel_sweep_dq_ref(h_bb, s0, qf, delta, z_lo, z_hi, hdiag)


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0) -> Array:
    """q: (BH, Tq, hd); k/v: (BH_kv, Tk, hd) with BH % BH_kv == 0 (GQA).

    Plain softmax attention oracle in f32.
    """
    g = q.shape[0] // k.shape[0]
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.einsum("btk,bsk->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Tq, Tk = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(Tq)[:, None]
        kpos = jnp.arange(Tk)[None, :]
        mask = qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsk->btk", p, v.astype(jnp.float32))
