"""Pallas TPU kernel: dequant-fused GEMM for COMQ-quantized weights.

Y = X · W_q with W_q = diag-free per-channel form scale[n]·(u[k,n] + z[n]).
The zero-point term factors out of the contraction:

    Y[m,n] = scale[n]·( Σ_k X[m,k]·u[k,n]  +  z[n]·Σ_k X[m,k] )

so the kernel streams uint8 codes HBM→VMEM (4×/8× less HBM traffic than
bf16 weights — this is what moves the decode roofline, EXPERIMENTS.md
§Perf), widens them to bf16 *in VMEM*, runs the MXU dot, and applies
scale/zero in the epilogue on the last K step. int4 codes arrive packed
two-per-byte along N and are unpacked in-register.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation into a VMEM
f32 scratch). Block sizes default to MXU-aligned (128, 128, 512).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, u_ref, scale_ref, z_ref, o_ref, acc_ref, rsum_ref, *,
            n_k: int, cpb: int, out_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rsum_ref[...] = jnp.zeros_like(rsum_ref)

    x = x_ref[...]                                    # (bm, bk)
    u = u_ref[...]                                    # (bk, bn // cpb)
    if cpb == 2:
        lo = (u & jnp.uint8(0x0F)).astype(jnp.uint8)
        hi = ((u >> 4) & jnp.uint8(0x0F)).astype(jnp.uint8)
        u = jnp.stack([lo, hi], axis=-1).reshape(u.shape[0], u.shape[1] * 2)
    elif cpb == 4:
        # quad unpack: four 2-bit fields per byte, lowest bits first
        # (quantizer.pack_int2 layout) — in-register, so the 2-bit path
        # streams 0.25 B/code from HBM instead of XLA-materializing the
        # unpacked codes
        parts = [((u >> (2 * i)) & jnp.uint8(0x03)).astype(jnp.uint8)
                 for i in range(4)]
        u = jnp.stack(parts, axis=-1).reshape(u.shape[0], u.shape[1] * 4)
    xw = x.astype(jnp.bfloat16)
    uw = u.astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot(xw, uw,
                                preferred_element_type=jnp.float32)
    rsum_ref[...] += jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)

    @pl.when(ki == n_k - 1)
    def _epilogue():
        scale = scale_ref[...].astype(jnp.float32)    # (1, bn)
        z = z_ref[...].astype(jnp.float32)            # (1, bn)
        y = acc_ref[...] * scale + rsum_ref[...] * (scale * z)
        o_ref[...] = y.astype(out_dtype)


def quant_matmul_pallas(x: Array, codes_u: Array, scale: Array, z_lo: Array,
                        *, bits: int = 8, cpb: Optional[int] = None,
                        bm: int = 128, bn: int = 128,
                        bk: int = 512, out_dtype=jnp.float32,
                        interpret: bool = False) -> Array:
    """x: (M, K) float; codes_u: (K, N/cpb) uint8 — unpacked (cpb=1),
    nibble-packed 3/4-bit (cpb=2) or quad-packed 2-bit (cpb=4);
    scale/z_lo: (N,). Returns (M, N). cpb defaults from bits (packed iff
    bits==4); every stored layout unpacks in-register."""
    M, K = x.shape
    if cpb is None:
        cpb = 2 if bits == 4 else 1
    assert cpb in (1, 2, 4), \
        f"pallas quant_matmul covers cpb 1/2/4, got {cpb}"
    N = codes_u.shape[1] * cpb
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shape ({M},{K},{N}) not divisible by blocks ({bm},{bk},{bn})"
    assert bn % cpb == 0, f"bn={bn} must align to cpb={cpb}"
    n_k = K // bk
    un = bn // cpb

    scale2 = scale.reshape(1, N).astype(jnp.float32)
    z2 = z_lo.reshape(1, N).astype(jnp.float32)

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, cpb=cpb,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, un), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[
            _vmem((bm, bn), jnp.float32),
            _vmem((bm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, codes_u, scale2, z2)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
