"""Pallas TPU kernel: intra-panel COMQ coordinate sweep (DESIGN.md §3.2).

The blocked COMQ solver (core/comq_hessian.py) reduces each panel's cross-
panel work to a dense MXU matmul; what remains is the strictly sequential
B-step sweep that only touches

    H[blk, blk]  (B×B)   +   S = (H·R)[blk]  (B×n)   +   the Q panel (B×n)

— a working set small enough to pin entirely in VMEM. The kernel runs the
B-step `fori_loop` in-register per column tile; the column grid dimension is
embarrassingly parallel (per-channel COMQ columns are independent given δ,
paper eq. (3)).

The fused variant additionally emits ΔW_blk = (Q' − Q)·δ so the trailing
update of the maintained product P = H·R (DESIGN.md §3.3) is a single dense
(m × B)·(B × n) matmul with no extra elementwise pass over the panel.

Per-program VMEM at B=256, cn=256: H_bb 256 KiB + 3×(S,Q,ΔW) 768 KiB ≈ 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantizer import EPS

Array = jax.Array


def _kernel(h_bb_ref, s_ref, qf_ref, delta_ref, zlo_ref, zhi_ref, hd_ref,
            out_ref, dq_ref, *, panel: int):
    h_bb = h_bb_ref[...]                      # (B, B)
    s0 = s_ref[...]                           # (B, cn)
    qf0 = qf_ref[...]                         # (B, cn)
    delta = delta_ref[...][0]                 # (cn,)
    z_lo = zlo_ref[...][0]
    z_hi = zhi_ref[...][0]
    hdiag = hd_ref[...][:, 0]                 # (B,)

    # lazy sweep (mirrors core.comq_hessian.panel_sweep_dq_ref op-for-op):
    # accumulate scaled deltas ΔW and materialize each step's S row as one
    # (1×B)·(B×cn) matvec — MXU work instead of B·cn VPU writes per step.
    def step(t, carry):
        qf, du = carry
        qg = jax.lax.dynamic_index_in_dim(qf, t, 0, keepdims=False)
        hg = jax.lax.dynamic_index_in_dim(hdiag, t, 0, keepdims=False)
        s0t = jax.lax.dynamic_index_in_dim(s0, t, 0, keepdims=False)
        hrow = jax.lax.dynamic_index_in_dim(h_bb, t, 0, keepdims=False)
        st = s0t - hrow @ du                  # rows ≥ t of du are still 0
        denom = delta * hg
        ratio = st / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg), z_lo, z_hi)
        q_new = jnp.where(hg > EPS, q_new, jnp.clip(jnp.round(qg), z_lo, z_hi))
        du = jax.lax.dynamic_update_index_in_dim(du, (q_new - qg) * delta,
                                                 t, 0)
        qf = jax.lax.dynamic_update_index_in_dim(qf, q_new, t, 0)
        return qf, du

    qf, du = jax.lax.fori_loop(0, panel, step,
                               (qf0, jnp.zeros_like(qf0)))
    out_ref[...] = qf
    dq_ref[...] = du


def _panel_call(h_bb: Array, s0: Array, qf: Array, delta: Array,
                z_lo: Array, z_hi: Array, hdiag: Array, *,
                col_block: int, interpret: bool):
    B, n = qf.shape
    delta = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))
    z_lo = jnp.broadcast_to(jnp.asarray(z_lo, jnp.float32), (n,))
    z_hi = jnp.broadcast_to(jnp.asarray(z_hi, jnp.float32), (n,))
    cn = min(col_block, n)
    while n % cn:
        cn //= 2
    grid = (n // cn,)
    return pl.pallas_call(
        functools.partial(_kernel, panel=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, B), lambda j: (0, 0)),
            pl.BlockSpec((B, cn), lambda j: (0, j)),
            pl.BlockSpec((B, cn), lambda j: (0, j)),
            pl.BlockSpec((1, cn), lambda j: (0, j)),
            pl.BlockSpec((1, cn), lambda j: (0, j)),
            pl.BlockSpec((1, cn), lambda j: (0, j)),
            pl.BlockSpec((B, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, cn), lambda j: (0, j)),
            pl.BlockSpec((B, cn), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n), jnp.float32),
            jax.ShapeDtypeStruct((B, n), jnp.float32),
        ],
        interpret=interpret,
    )(h_bb.astype(jnp.float32), s0.astype(jnp.float32),
      qf.astype(jnp.float32), delta.reshape(1, n), z_lo.reshape(1, n),
      z_hi.reshape(1, n), hdiag.astype(jnp.float32).reshape(B, 1))


def comq_panel_pallas(h_bb: Array, s0: Array, qf: Array, delta: Array,
                      z_lo: Array, z_hi: Array, hdiag: Array, *,
                      col_block: int = 256, interpret: bool = False) -> Array:
    """Drop-in replacement for core.comq_hessian.panel_sweep_ref.

    h_bb: (B, B); s0/qf: (B, n); delta/z_lo/z_hi: (n,) or scalar;
    hdiag: (B,). Returns updated qf (B, n)."""
    qf_new, _ = _panel_call(h_bb, s0, qf, delta, z_lo, z_hi, hdiag,
                            col_block=col_block, interpret=interpret)
    return qf_new


def comq_panel_dq_pallas(h_bb: Array, s0: Array, qf: Array, delta: Array,
                         z_lo: Array, z_hi: Array, hdiag: Array, *,
                         col_block: int = 256, interpret: bool = False):
    """Fused panel sweep: returns (qf', ΔW) with ΔW = (qf' − qf)·δ already
    scaled in-kernel, ready for the trailing update P -= H[:, blk] @ ΔW."""
    return _panel_call(h_bb, s0, qf, delta, z_lo, z_hi, hdiag,
                       col_block=col_block, interpret=interpret)


def panel_fn_interpret(h_bb, s0, qf, delta, z_lo, z_hi, hdiag):
    """panel_fn adapter for comq_quantize_blocked (interpret mode)."""
    return comq_panel_pallas(h_bb, s0, qf, delta,
                             z_lo.astype(jnp.float32),
                             z_hi.astype(jnp.float32), hdiag, interpret=True)


def panel_fn_dq_interpret(h_bb, s0, qf, delta, z_lo, z_hi, hdiag):
    """Fused (qf', ΔW) panel_fn adapter (interpret mode)."""
    return comq_panel_dq_pallas(h_bb, s0, qf, delta,
                                z_lo.astype(jnp.float32),
                                z_hi.astype(jnp.float32), hdiag,
                                interpret=True)
