"""Pallas TPU kernel: intra-panel COMQ coordinate sweep (DESIGN.md §3.2).

The blocked COMQ solver (core/comq_hessian.py) reduces each panel's cross-
panel residual refresh to a dense MXU matmul; what remains is the strictly
sequential B-step sweep that only touches

    H[blk, blk]  (B×B)   +   S = (H·R)[blk]  (B×n)   +   the Q panel (B×n)

— a working set small enough to pin entirely in VMEM. The kernel runs the
B-step `fori_loop` in-register per column tile; the column grid dimension is
embarrassingly parallel (per-channel COMQ columns are independent given δ,
paper eq. (3)).

Per-program VMEM at B=256, cn=256: H_bb 256 KiB + 2×(S,Q) 512 KiB ≈ 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantizer import EPS

Array = jax.Array


def _kernel(h_bb_ref, s_ref, qf_ref, delta_ref, zlo_ref, zhi_ref, hd_ref,
            out_ref, *, panel: int):
    h_bb = h_bb_ref[...]                      # (B, B)
    s = s_ref[...]                            # (B, cn)
    qf = qf_ref[...]                          # (B, cn)
    delta = delta_ref[...][0]                 # (cn,)
    z_lo = zlo_ref[...][0]
    z_hi = zhi_ref[...][0]
    hdiag = hd_ref[...][:, 0]                 # (B,)

    def step(t, carry):
        s, qf = carry
        qg = jax.lax.dynamic_index_in_dim(qf, t, 0, keepdims=False)
        hg = jax.lax.dynamic_index_in_dim(hdiag, t, 0, keepdims=False)
        st = jax.lax.dynamic_index_in_dim(s, t, 0, keepdims=False)
        denom = delta * hg
        ratio = st / jnp.where(denom > 0, denom, 1.0)
        q_new = jnp.clip(jnp.round(ratio + qg), z_lo, z_hi)
        q_new = jnp.where(hg > EPS, q_new, jnp.clip(jnp.round(qg), z_lo, z_hi))
        du = (q_new - qg) * delta
        hcol = jax.lax.dynamic_index_in_dim(h_bb, t, 1, keepdims=False)
        s = s - hcol[:, None] * du[None, :]
        qf = jax.lax.dynamic_update_index_in_dim(qf, q_new, t, 0)
        return s, qf

    _, qf = jax.lax.fori_loop(0, panel, step, (s, qf))
    out_ref[...] = qf


def comq_panel_pallas(h_bb: Array, s0: Array, qf: Array, delta: Array,
                      z_lo: Array, z_hi: Array, hdiag: Array, *,
                      col_block: int = 256, interpret: bool = False) -> Array:
    """Drop-in replacement for core.comq_hessian.panel_sweep_ref.

    h_bb: (B, B); s0/qf: (B, n); delta/z_lo/z_hi: (n,) or scalar;
    hdiag: (B,). Returns updated qf (B, n)."""
    B, n = qf.shape
    delta = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))
    z_lo = jnp.broadcast_to(jnp.asarray(z_lo, jnp.float32), (n,))
    z_hi = jnp.broadcast_to(jnp.asarray(z_hi, jnp.float32), (n,))
    cn = min(col_block, n)
    while n % cn:
        cn //= 2
    grid = (n // cn,)
    return pl.pallas_call(
        functools.partial(_kernel, panel=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, B), lambda j: (0, 0)),
            pl.BlockSpec((B, cn), lambda j: (0, j)),
            pl.BlockSpec((B, cn), lambda j: (0, j)),
            pl.BlockSpec((1, cn), lambda j: (0, j)),
            pl.BlockSpec((1, cn), lambda j: (0, j)),
            pl.BlockSpec((1, cn), lambda j: (0, j)),
            pl.BlockSpec((B, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, cn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.float32),
        interpret=interpret,
    )(h_bb.astype(jnp.float32), s0.astype(jnp.float32),
      qf.astype(jnp.float32), delta.reshape(1, n), z_lo.reshape(1, n),
      z_hi.reshape(1, n), hdiag.astype(jnp.float32).reshape(B, 1))


def panel_fn_interpret(h_bb, s0, qf, delta, z_lo, z_hi, hdiag):
    """panel_fn adapter for comq_quantize_blocked (interpret mode)."""
    return comq_panel_pallas(h_bb, s0, qf, delta,
                             z_lo.astype(jnp.float32),
                             z_hi.astype(jnp.float32), hdiag, interpret=True)
