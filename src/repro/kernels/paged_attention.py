"""Pallas TPU kernel: paged decode attention over a block-table KV pool.

One query token per slot attends over that slot's KV blocks. The pool is
(num_blocks, block_size, KV, hd) in HBM; each slot owns a row of the block
table mapping logical block i -> physical block id. The grid is
(batch, blocks_per_slot) with the block table and per-slot lengths passed
as scalar-prefetch operands, so the K/V BlockSpec index maps read the
table and DMA exactly the pages a slot references — non-contiguous pages
stream HBM->VMEM with no gather materialization (guide: paged attention,
§8-10). Online-softmax state (m, l, acc) lives in VMEM scratch; blocks
past a slot's length are skipped with `pl.when` (zero MXU work), and an
inactive slot (length 0) produces exact zeros.

GQA is expressed by reshaping q to (KV, G, hd) — requires Hp % KV == 0
(every production config after TP head padding; the hymba 5-kv case uses
the XLA gather fallback in models/attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, bs: int, n_blocks: int, scale: float, window: int,
            n_kv: int, group: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    H = n_kv * group

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(i * bs < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(n_kv, group, -1)
        k = k_ref[0].astype(jnp.float32)              # (bs, KV, hd)
        s = jnp.einsum("kgh,skh->kgs", q, k,
                       preferred_element_type=jnp.float32) * scale
        kpos = i * bs + jax.lax.broadcasted_iota(jnp.int32,
                                                 (n_kv, group, bs), 2)
        mask = kpos < length
        if window > 0:   # query sits at position length-1
            mask = jnp.logical_and(mask, (length - 1) - kpos < window)
        s = jnp.where(mask, s, NEG_INF).reshape(H, bs)
        m_prev = m_ref[...]                           # (H, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jnp.einsum("kgs,skh->kgh", p.reshape(n_kv, group, bs), v,
                        preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(H, -1)
        m_ref[...] = m_new

    @pl.when(i == n_blocks - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _quant_kernel(bt_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, bs: int, n_blocks: int,
                  scale: float, window: int, n_kv: int, group: int,
                  kv_bits: int):
    """Quantized-pool variant: k_ref/v_ref stream integer codes (int8, or
    packed 4-bit nibble pairs) and the per-(page, kv_head) scales arrive
    as extra scalar-prefetch operands. Codes unpack in VMEM registers and
    the scales fold into the online-softmax inputs (scores) and the PV
    accumulation — K/V never materialize dequantized in HBM."""
    b = pl.program_id(0)
    i = pl.program_id(1)
    H = n_kv * group

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    def dequant(codes):
        if kv_bits == 8:
            return codes.astype(jnp.float32)
        lo = codes & jnp.uint8(0x0F)
        hi = (codes >> 4) & jnp.uint8(0x0F)
        un = jnp.stack([lo, hi], axis=-1).reshape(bs, n_kv, -1)
        return un.astype(jnp.float32) - 8.0

    @pl.when(i * bs < length)
    def _compute():
        page = bt_ref[b, i]
        # one SMEM scalar read per kv head: the page's K and V scales
        ks = jnp.stack([ks_ref[page, j] for j in range(n_kv)])
        vs = jnp.stack([vs_ref[page, j] for j in range(n_kv)])
        q = q_ref[0].astype(jnp.float32).reshape(n_kv, group, -1)
        k = dequant(k_ref[0])                         # (bs, KV, hd) codes
        s = jnp.einsum("kgh,skh->kgs", q, k,
                       preferred_element_type=jnp.float32) \
            * (scale * ks)[:, None, None]
        kpos = i * bs + jax.lax.broadcasted_iota(jnp.int32,
                                                 (n_kv, group, bs), 2)
        mask = kpos < length
        if window > 0:   # query sits at position length-1
            mask = jnp.logical_and(mask, (length - 1) - kpos < window)
        s = jnp.where(mask, s, NEG_INF).reshape(H, bs)
        m_prev = m_ref[...]                           # (H, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        v = dequant(v_ref[0])
        pv = jnp.einsum("kgs,skh->kgh", p.reshape(n_kv, group, bs), v,
                        preferred_element_type=jnp.float32) \
            * vs[:, None, None]
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(H, -1)
        m_ref[...] = m_new

    @pl.when(i == n_blocks - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_quant_pallas(q: Array, k_pool: Array, v_pool: Array,
                                 k_scale: Array, v_scale: Array,
                                 block_tables: Array, lengths: Array, *,
                                 window: int = 0, kv_bits: int = 8,
                                 interpret: bool = False) -> Array:
    """Quantized-pool paged attention: k_pool/v_pool (NB, BS, KV, hd/cpb)
    integer codes, k_scale/v_scale (NB, KV) f32 per-page scales riding as
    scalar-prefetch operands 3/4. Same grid/softmax structure as the bf16
    kernel; returns (B, Hp, hd) in q.dtype."""
    B, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    MAXB = block_tables.shape[1]
    assert H % KV == 0, "pallas paged kernel needs grouped GQA (Hp % KV == 0)"
    assert kv_bits in (4, 8)
    group = H // KV
    hdp = k_pool.shape[3]
    scale = 1.0 / float(hd) ** 0.5
    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, MAXB),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, i, bt, ln, ks, vs: (b, 0, 0)),
            pl.BlockSpec((1, BS, KV, hdp),
                         lambda b, i, bt, ln, ks, vs: (bt[b, i], 0, 0, 0)),
            pl.BlockSpec((1, BS, KV, hdp),
                         lambda b, i, bt, ln, ks, vs: (bt[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd),
                               lambda b, i, bt, ln, ks, vs: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_quant_kernel, bs=BS, n_blocks=MAXB, scale=scale,
                          window=window, n_kv=KV, group=group,
                          kv_bits=kv_bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      q, k_pool, v_pool)


def paged_attention_pallas(q: Array, k_pool: Array, v_pool: Array,
                           block_tables: Array, lengths: Array, *,
                           window: int = 0,
                           interpret: bool = False) -> Array:
    """q: (B, Hp, hd); k_pool/v_pool: (NB, BS, KV, hd); block_tables:
    (B, MAXB) int32 physical block ids; lengths: (B,) valid tokens per slot
    (0 = inactive -> zero output). Returns (B, Hp, hd) in q.dtype."""
    B, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    MAXB = block_tables.shape[1]
    assert H % KV == 0, "pallas paged kernel needs grouped GQA (Hp % KV == 0)"
    group = H // KV
    scale = 1.0 / float(hd) ** 0.5
    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, MAXB),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, i, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, BS, KV, hd),
                         lambda b, i, bt, ln: (bt[b, i], 0, 0, 0)),
            pl.BlockSpec((1, BS, KV, hd),
                         lambda b, i, bt, ln: (bt[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, i, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=BS, n_blocks=MAXB, scale=scale,
                          window=window, n_kv=KV, group=group),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, v_pool)
