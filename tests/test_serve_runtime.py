"""Continuous-batching paged serving runtime (serve/runtime.py):
scheduler admission + block accounting, paged attention kernel vs
fallback, equivalence vs the static engine and vs solo runs, packed-QT
serving without materialize."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import QuantSpec, materialize, quantize_model, serving_params
from repro.models import BuildPlan, init_params
from repro.models.attention import head_to_kv_map, paged_decode_attend
from repro.serve import Engine, Runtime, ServeConfig
from repro.serve.kv_cache import BlockAllocator, blocks_for
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _f32_setup(arch="qwen2-7b"):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = init_params(KEY, cfg, plan)
    return cfg, plan, params


def _runtime(params, cfg, plan, **kw):
    sc = dict(max_slots=3, block_size=8, num_blocks=24, buckets=(8, 16, 32),
              max_blocks_per_slot=6)
    sc.update(kw)
    return Runtime(params, cfg, plan, ServeConfig(**sc))


# ---------------------------------------------------------------------------
# equivalence: runtime vs static engine / solo runs
# ---------------------------------------------------------------------------

def test_runtime_matches_engine_equal_length():
    cfg, plan, params = _f32_setup()
    prompts = np.asarray(jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size))
    eng = Engine(params, cfg, plan, max_len=32)
    want = eng.generate_batch(prompts, max_new_tokens=8)
    # matched cache extents (2 slots, 4 pages x 8 = engine max_len 32)
    rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=8,
                  buckets=(16,), max_blocks_per_slot=4)
    got = rt.generate([prompts[0], prompts[1]], max_new_tokens=8)
    np.testing.assert_array_equal(np.stack(got), want)


@pytest.mark.parametrize("arch", ["qwen2-7b", "deepseek-67b",
                                  "granite-moe-3b-a800m",
                                  "h2o-danube-1.8b"])
def test_mixed_length_staggered_matches_solo(arch):
    """Mixed prompt lengths arriving over time, with fewer slots than
    requests (slot + block reuse): every request's greedy tokens equal its
    solo run through the same runtime. Covers dense (qkv-bias), dense,
    MoE, and sliding-window archs — the danube lengths push past its
    32-token smoke window so SWA masking + ring prefill scatter bind."""
    cfg, plan, params = _f32_setup(arch)
    rs = np.random.RandomState(1)
    lens = [5, 16, 11, 8]
    if cfg.sliding_window:
        lens = [30, 16, 28, 8]      # 30 + 6 new tokens > window=32
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]

    rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=12)
    reqs = [rt.submit(p, max_new_tokens=6) for p in prompts[:2]]
    rt.step()                       # arrivals staggered across decode steps
    reqs.append(rt.submit(prompts[2], max_new_tokens=6))
    rt.step()
    reqs.append(rt.submit(prompts[3], max_new_tokens=6))
    rt.run()
    mixed = [np.asarray(r.out_tokens) for r in reqs]

    for p, got in zip(prompts, mixed):
        solo_rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=12)
        solo = solo_rt.generate([p], max_new_tokens=6)[0]
        np.testing.assert_array_equal(got, solo)

    # slot/block reuse actually happened and nothing leaked
    assert rt.allocator.peak_in_use <= rt.allocator.num_blocks
    assert rt.allocator.num_free == rt.allocator.num_blocks
    assert not rt.scheduler.running and not rt.scheduler.queue


def test_swa_prefill_bucket_invariance():
    """SWA arch with prompt > window and a bucket larger than the window:
    the right-pad rows must not ring-evict real in-window prompt K/V
    before the paged scatter. Regression: a 40-token danube prompt
    (window=32) served through a 64 bucket must decode identically to the
    same prompt through a 40 bucket (where no eviction is possible)."""
    cfg, plan, params = _f32_setup("h2o-danube-1.8b")
    assert cfg.sliding_window == 32
    p = np.random.RandomState(3).randint(0, cfg.vocab_size,
                                         (40,)).astype(np.int32)
    outs = []
    for buckets in ((40,), (64,)):
        rt = _runtime(params, cfg, plan, max_slots=1, num_blocks=12,
                      buckets=buckets, max_blocks_per_slot=9)
        outs.append(rt.generate([p], max_new_tokens=6)[0])
    np.testing.assert_array_equal(outs[0], outs[1])


def test_backpressure_queue_drains_fcfs():
    """More requests than slots *and* than free pages: admission stalls on
    cache exhaustion, completions free pages, everything finishes FCFS."""
    cfg, plan, params = _f32_setup()
    rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=4,
                  buckets=(8,), max_blocks_per_slot=2)
    prompts = [np.arange(6, dtype=np.int32) % cfg.vocab_size
               for _ in range(5)]
    reqs = [rt.submit(p, max_new_tokens=4) for p in prompts]
    rt.run()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    done_order = [r.rid for r in rt.scheduler.completed]
    assert done_order == sorted(done_order)     # FCFS with equal work
    assert rt.allocator.num_free == rt.allocator.num_blocks


# ---------------------------------------------------------------------------
# paged attention: pallas kernel vs XLA fallback
# ---------------------------------------------------------------------------

def test_paged_attention_ref_vs_fallback():
    from repro.kernels import ops
    B, H, KV, hd, NB, BS, MAXB = 3, 4, 2, 16, 10, 4, 5
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, BS, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, BS, KV, hd), jnp.float32)
    bt = jnp.asarray(np.random.RandomState(0).randint(0, NB, (B, MAXB)),
                     jnp.int32)
    lengths = jnp.asarray([17, 4, 0], jnp.int32)
    hmap = head_to_kv_map(H, H, KV)
    for window in (0, 6):
        # model fallback (gather + _dense_attention)
        o_fb = paged_decode_attend(q, kp, vp, bt, lengths, hmap,
                                   window=window, mode="xla")
        # jnp oracle in kernels/ref.py
        o_ref = ops.paged_attention(q[:, 0], kp, vp, bt, lengths,
                                    window=window, mode="xla")
        # pallas kernel, interpret mode
        o_pl = ops.paged_attention(q[:, 0], kp, vp, bt, lengths,
                                   window=window, mode="interpret")
        np.testing.assert_allclose(np.asarray(o_fb[:2, 0]),
                                   np.asarray(o_ref[:2]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(o_pl[:2]),
                                   np.asarray(o_ref[:2]), atol=1e-5)
        # inactive slot: kernel and oracle both produce exact zeros
        assert float(jnp.abs(o_pl[2]).max()) == 0.0
        assert float(jnp.abs(o_ref[2]).max()) == 0.0


# ---------------------------------------------------------------------------
# block allocator / scheduler
# ---------------------------------------------------------------------------

def test_block_allocator_leak_and_double_free():
    a = BlockAllocator(8)
    x = a.alloc(3)
    y = a.alloc(5)
    assert a.num_free == 0 and a.alloc(1) is None
    a.free(y)
    assert a.num_free == 5 and a.peak_in_use == 8
    with pytest.raises(ValueError):
        a.free(y[:1])               # double free
    with pytest.raises(ValueError):
        a.free([99])                # unknown block
    a.free(x)
    assert a.num_free == 8


def test_scheduler_buckets_and_admission():
    a = BlockAllocator(6)
    s = Scheduler(max_slots=2, allocator=a, buckets=(8, 16), block_size=4,
                  max_blocks_per_slot=4)
    assert s.bucket_for(3) == 8 and s.bucket_for(9) == 16
    with pytest.raises(ValueError):
        s.bucket_for(17)
    r1 = s.submit(Request(prompt=np.arange(8), max_new_tokens=5))
    r2 = s.submit(Request(prompt=np.arange(8), max_new_tokens=5))
    r3 = s.submit(Request(prompt=np.arange(4), max_new_tokens=2))
    adm = s.admit()
    assert [r.rid for r in adm] == [r1.rid, r2.rid]   # 3 pages each
    assert s.admit() == []          # no free slot (and no pages)
    s.release(r1)
    assert [r.rid for r in s.admit()] == [r3.rid]
    s.release(r2)
    s.release(r3)
    assert a.num_free == 6 and s.idle


# ---------------------------------------------------------------------------
# packed-QT serving (no materialize)
# ---------------------------------------------------------------------------

def test_packed_qt_serve_matches_materialized():
    cfg, plan, params = _f32_setup()
    calib = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec)
    packed = serving_params(qparams, cfg)
    mat = materialize(qparams, cfg)

    from repro.core.apply import is_qt
    assert any(is_qt(l) for l in
               jax.tree_util.tree_leaves(packed, is_leaf=is_qt))

    prompts = [np.asarray(jax.random.randint(KEY, (12,), 0,
                                             cfg.vocab_size)),
               np.asarray(jax.random.randint(jax.random.PRNGKey(7), (16,),
                                             0, cfg.vocab_size))]
    rt_q = _runtime(packed, cfg, plan)
    rt_m = _runtime(mat, cfg, plan)
    out_q = rt_q.generate(prompts, max_new_tokens=8)
    out_m = rt_m.generate(prompts, max_new_tokens=8)
    for a, b in zip(out_q, out_m):
        np.testing.assert_array_equal(a, b)

    # logits-level agreement of the fused quant_matmul decode path
    from repro.models import prefill, decode_step
    plan2 = plan.replace(prefill_cache_len=20)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    lq, cq = prefill(packed, cfg, plan2, tokens)
    lm, cm = prefill(mat, cfg, plan2, tokens)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lm), atol=1e-5)
    gq, _ = decode_step(packed, cfg, plan2, cq, tokens[:, :1], jnp.int32(16))
    gm, _ = decode_step(mat, cfg, plan2, cm, tokens[:, :1], jnp.int32(16))
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gm), atol=1e-5)


def test_serving_params_stripped_checkpoint_roundtrip():
    """pack -> strip -> unpack -> serve: byte-light checkpoint reconstructs
    both the packed serving tree and the materialized tree exactly."""
    from repro.ckpt import pack_tree, strip_for_serving, tree_bytes, \
        unpack_tree
    cfg, plan, params = _f32_setup()
    calib = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec)
    stripped = pack_tree(strip_for_serving(qparams))
    assert tree_bytes(stripped) < tree_bytes(pack_tree(qparams))
    restored = unpack_tree(stripped)

    mat_a = materialize(qparams, cfg)
    mat_b = materialize(restored, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(mat_a),
                    jax.tree_util.tree_leaves(mat_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    sp = serving_params(restored, cfg)
    prompts = [np.asarray(jax.random.randint(KEY, (10,), 0,
                                             cfg.vocab_size))]
    out_a = _runtime(sp, cfg, plan).generate(prompts, max_new_tokens=4)
    out_b = _runtime(mat_a, cfg, plan).generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(out_a[0], out_b[0])


def test_serving_params_single_layer_stack():
    """Regression: a 1-layer model's scan-sliced QT (static shape
    (1, d, H, hd), 2D codes) must dequantize to the logical per-layer
    rank, not rebroadcast the unit stack dim."""
    cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32",
                                               n_layers=1)
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32,
                     prefill_cache_len=20)
    params = init_params(KEY, cfg, plan)
    calib = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec)
    sp = serving_params(qparams, cfg)
    mat = materialize(qparams, cfg)
    from repro.models import prefill
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    lq, _ = prefill(sp, cfg, plan, tokens)
    lm, _ = prefill(mat, cfg, plan, tokens)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lm), atol=1e-5)


def test_serving_params_mqa_single_kv_head():
    """Regression: MQA (n_kv_heads=1) wk/wv QTs must resolve their output
    dims to (1, hd), not (hd,) — the unit KV axis is not a stack dim."""
    cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32",
                                               n_kv_heads=1)
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32,
                     prefill_cache_len=20)
    params = init_params(KEY, cfg, plan)
    calib = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec)
    sp = serving_params(qparams, cfg)
    mat = materialize(qparams, cfg)
    from repro.models import decode_step, prefill
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    lq, cq = prefill(sp, cfg, plan, tokens)
    lm, cm = prefill(mat, cfg, plan, tokens)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lm), atol=1e-5)
    gq, _ = decode_step(sp, cfg, plan, cq, tokens[:, :1], jnp.int32(16))
    gm, _ = decode_step(mat, cfg, plan, cm, tokens[:, :1], jnp.int32(16))
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gm), atol=1e-5)


def test_vlm_stripped_checkpoint_materializes():
    """strip_for_serving drops the VLM 'groups' stacks too; materialize
    rebuilds them from the table bit-identically."""
    from repro.ckpt import pack_tree, strip_for_serving, tree_bytes, \
        unpack_tree
    cfg = get_smoke_config("llama-3.2-vision-90b").replace(
        compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = init_params(KEY, cfg, plan)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    ve = jax.random.normal(KEY, (2, cfg.cross_attn.n_vision_tokens,
                                 cfg.cross_attn.vision_dim), jnp.float32)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="cyclic")
    qparams, _ = quantize_model(params, cfg, plan, calib, spec,
                                vision_embeds=ve)
    stripped = pack_tree(strip_for_serving(qparams))
    assert tree_bytes(stripped) < tree_bytes(pack_tree(qparams))
    mat_a = materialize(qparams, cfg)
    mat_b = materialize(unpack_tree(stripped), cfg)
    for a, b in zip(jax.tree_util.tree_leaves(mat_a),
                    jax.tree_util.tree_leaves(mat_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# streaming + metrics surface
# ---------------------------------------------------------------------------

def test_streaming_callback_and_metrics():
    cfg, plan, params = _f32_setup()
    seen = []
    rt = _runtime(params, cfg, plan)
    p = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    req = rt.submit(p, max_new_tokens=5,
                    stream_cb=lambda r, t: seen.append((r.rid, t)))
    m = rt.run()
    assert [t for _, t in seen] == req.out_tokens
    assert m["requests"] == 1 and m["new_tokens"] == 5
    assert m["ttft_s"][0] >= 0.0 and len(req.itl) == 4
    assert 0 < m["cache_peak_occupancy"] <= 1.0
    assert m["finish_reasons"] == ["length"]


# ---------------------------------------------------------------------------
# EOS / stop-token termination
# ---------------------------------------------------------------------------

def test_stop_token_terminates_early_and_frees_pages():
    """A request whose greedy stream hits its stop token retires on that
    step: the stop token is the last emitted token, no tokens follow it,
    slot + every reserved page return to the pool, and run() metrics
    count only the actually-emitted tokens."""
    cfg, plan, params = _f32_setup()
    p = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    # discover what greedy would emit, then stop on its 3rd token
    ref = _runtime(params, cfg, plan).generate([p], max_new_tokens=8)[0]
    stop = int(ref[2])
    rt = _runtime(params, cfg, plan)
    req = rt.submit(p, max_new_tokens=8, stop_tokens=(stop,))
    m = rt.run()
    assert req.finish_reason == "stop_token"
    assert req.out_tokens[-1] == stop
    assert len(req.out_tokens) == 3
    np.testing.assert_array_equal(np.asarray(req.out_tokens), ref[:3])
    assert m["new_tokens"] == 3 and m["finish_reasons"] == ["stop_token"]
    assert rt.allocator.num_free == rt.allocator.num_blocks
    assert not rt.scheduler.running and not rt.scheduler.queue


def test_stop_token_on_first_prefill_token():
    """The TTFT token itself can be the stop token — the request retires
    at admission without entering the decode batch."""
    cfg, plan, params = _f32_setup()
    p = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    first = int(_runtime(params, cfg, plan).generate(
        [p], max_new_tokens=1)[0][0])
    rt = _runtime(params, cfg, plan)
    req = rt.submit(p, max_new_tokens=8, stop_tokens=(first,))
    m = rt.run()
    assert req.out_tokens == [first]
    assert req.finish_reason == "stop_token"
    assert m["decode_steps"] == 0
    assert rt.allocator.num_free == rt.allocator.num_blocks


def test_stop_token_preserves_batchmates_token_identity():
    """One request stopping early must not perturb the other slots: the
    surviving requests' tokens equal their solo runs, and the freed pages
    let a queued request admit sooner."""
    cfg, plan, params = _f32_setup()
    rs = np.random.RandomState(5)
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (9, 12, 7)]
    solo = [_runtime(params, cfg, plan).generate([p], max_new_tokens=8)[0]
            for p in prompts]
    stop = int(solo[0][1])      # request 0 stops after 2 tokens
    # make the stopper's stop token unique to it: if another stream also
    # emits it the test would conflate retirements
    assert stop not in solo[1][:8] and stop not in solo[2][:8]
    rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=12)
    reqs = [rt.submit(prompts[0], max_new_tokens=8, stop_tokens=(stop,)),
            rt.submit(prompts[1], max_new_tokens=8),
            rt.submit(prompts[2], max_new_tokens=8)]   # queued (2 slots)
    rt.run()
    assert reqs[0].finish_reason == "stop_token"
    np.testing.assert_array_equal(np.asarray(reqs[0].out_tokens),
                                  solo[0][:2])
    np.testing.assert_array_equal(np.asarray(reqs[1].out_tokens), solo[1])
    np.testing.assert_array_equal(np.asarray(reqs[2].out_tokens), solo[2])
    assert rt.allocator.num_free == rt.allocator.num_blocks


# ---------------------------------------------------------------------------
# priority admission + preemption-by-page-reclaim
# ---------------------------------------------------------------------------

def test_priority_admission_order():
    """Admission is (priority, rid): priority class first, arrival order
    within a class — and priority=0 everywhere degrades to FCFS."""
    a = BlockAllocator(24)
    s = Scheduler(max_slots=1, allocator=a, buckets=(8,), block_size=4,
                  max_blocks_per_slot=4)
    lo1 = s.submit(Request(prompt=np.arange(4), max_new_tokens=2,
                           priority=5))
    hi = s.submit(Request(prompt=np.arange(4), max_new_tokens=2,
                          priority=0))
    lo2 = s.submit(Request(prompt=np.arange(4), max_new_tokens=2,
                           priority=5))
    order = []
    while not s.idle:
        adm = s.admit()
        assert len(adm) == 1        # one slot
        order.append(adm[0].rid)
        s.release(adm[0])
    assert order == [hi.rid, lo1.rid, lo2.rid]
    assert a.num_free == a.num_blocks


def test_admission_preempts_running_low_priority():
    """A strictly more urgent head reclaims the victim's slot+pages at
    admission; the victim re-queues (state machine only, no model)."""
    a = BlockAllocator(4)
    s = Scheduler(max_slots=1, allocator=a, buckets=(8,), block_size=4,
                  max_blocks_per_slot=4)
    lo = s.submit(Request(prompt=np.arange(8), max_new_tokens=5,
                          priority=5))
    assert s.admit() == [lo]
    lo.out_tokens = [1, 2]          # mid-flight progress
    hi = s.submit(Request(prompt=np.arange(8), max_new_tokens=5,
                          priority=0))
    cleared = []
    adm = s.admit(on_preempt=cleared.append)
    assert adm == [hi] and cleared == [lo]
    assert lo.state == "queued" and lo.slot == -1 and not lo.blocks
    assert lo.n_preempts == 1 and s.preemptions == 1
    # equal urgency must NOT preempt: a same-class later arrival waits
    eq = s.submit(Request(prompt=np.arange(8), max_new_tokens=5,
                          priority=0))
    assert s.admit() == []
    assert eq.state == "queued" and hi.state == "running"
    s.release(hi)
    a.check_integrity()


def test_starvation_freedom_preempted_keeps_rid():
    """A preempted request keeps its rid, so within its priority class it
    re-admits ahead of every later arrival — bounded bypass, no
    starvation."""
    a = BlockAllocator(24)
    s = Scheduler(max_slots=1, allocator=a, buckets=(8,), block_size=4,
                  max_blocks_per_slot=4)
    old = s.submit(Request(prompt=np.arange(4), max_new_tokens=2,
                           priority=1))
    s.admit()
    s.preempt(old)
    newer = s.submit(Request(prompt=np.arange(4), max_new_tokens=2,
                             priority=1))
    assert s.admit() == [old]       # not newer: old's rid is smaller
    assert newer.state == "queued"
    s.release(old)
    assert s.admit() == [newer]
    s.release(newer)


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m"])
def test_preempt_resume_token_identity(arch):
    """Pool too small for all requests' lifetimes: decode growth preempts
    and resumes mid-stream, yet every request's tokens equal its solo run
    (recompute-based resume feeds the last emitted token through the
    normal decode program). Allocator ends clean."""
    cfg, plan, params = _f32_setup(arch)
    rs = np.random.RandomState(7)
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (14, 9, 12)]
    solo = [_runtime(params, cfg, plan).generate([p], max_new_tokens=8)[0]
            for p in prompts]

    # 3 slots but only 6 pages: three 2-page prefills admit, decode growth
    # past each 16-row boundary must reclaim someone's pages
    rt = _runtime(params, cfg, plan, num_blocks=6)
    reqs = [rt.submit(p, max_new_tokens=8) for p in prompts]
    rt.run()
    assert rt.scheduler.preemptions > 0
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
    assert rt.allocator.num_free == rt.allocator.num_blocks
    rt.allocator.check_integrity()
    assert rt.scheduler.idle


def test_priority_latecomer_finishes_first():
    """An urgent request arriving after low-priority traffic saturates the
    pool preempts a victim, runs immediately, and still emits exactly its
    solo tokens — as do the preempted victims after resume."""
    cfg, plan, params = _f32_setup()
    rs = np.random.RandomState(11)
    prompts = [rs.randint(0, cfg.vocab_size, (10,)).astype(np.int32)
               for _ in range(3)]
    solo = [_runtime(params, cfg, plan).generate([p], max_new_tokens=6)[0]
            for p in prompts]
    rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=4)
    lo = [rt.submit(p, max_new_tokens=6, priority=5) for p in prompts[:2]]
    rt.step()                       # the low-priority pair gets going
    hi = rt.submit(prompts[2], max_new_tokens=6, priority=0)
    rt.run()
    assert rt.scheduler.preemptions > 0
    done = [r.rid for r in rt.scheduler.completed]
    assert done.index(hi.rid) < max(done.index(r.rid) for r in lo)
    for r, want in zip(lo + [hi], solo):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
    assert rt.allocator.num_free == rt.allocator.num_blocks


def test_reserve_policy_never_preempts():
    """policy="reserve" keeps the PR-4 contract: full-lifetime pages at
    admission, zero preemptions, exhaustion backpressures the queue."""
    cfg, plan, params = _f32_setup()
    rs = np.random.RandomState(13)
    prompts = [rs.randint(0, cfg.vocab_size, (10,)).astype(np.int32)
               for _ in range(3)]
    rt = _runtime(params, cfg, plan, num_blocks=6, policy="reserve")
    reqs = [rt.submit(p, max_new_tokens=8) for p in prompts]
    rt.run()
    assert rt.scheduler.preemptions == 0
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert rt.allocator.num_free == rt.allocator.num_blocks


def test_allocator_integrity_under_injected_alloc_faults():
    """Seeded page-alloc failures at admission and growth: no leak, no
    double free, no lost request — every stream still matches solo."""
    from repro.ft import FaultInjector
    from repro.serve import ServeConfig
    cfg, plan, params = _f32_setup()
    rs = np.random.RandomState(17)
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (12, 9, 14)]
    solo = [_runtime(params, cfg, plan).generate([p], max_new_tokens=6)[0]
            for p in prompts]
    inj = FaultInjector({"page_alloc": {2, 4, 7}})
    sc = ServeConfig(max_slots=3, block_size=8, num_blocks=24,
                     buckets=(8, 16, 32), max_blocks_per_slot=6)
    rt = Runtime(params, cfg, plan, sc, injector=inj)
    reqs = [rt.submit(p, max_new_tokens=6) for p in prompts]
    rt.run()
    assert [pt for pt, _ in inj.fired] == ["page_alloc"] * 3
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
    assert rt.allocator.num_free == rt.allocator.num_blocks
    rt.allocator.check_integrity()
    assert len(rt.scheduler.completed) == 3     # none lost, none duplicated
    assert sorted(r.rid for r in rt.scheduler.completed) == [0, 1, 2]
