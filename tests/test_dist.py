"""repro.dist: sharded calibration (shard_map Gram + single psum),
compressed collectives, and the launcher partition-spec helpers.

These tests run on whatever devices exist: a 1-device "data" mesh locally,
8 real shards under the CI multidevice job
(XLA_FLAGS=--xla_force_host_platform_device_count=8). The subprocess test
forces 8 host devices regardless, so multi-device fidelity is always
covered (per tests/conftest.py, XLA_FLAGS must not be set in-process).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import QuantSpec, quantize_model
from repro.core.calibrate import batched_gram, gram_from_tap
from repro.dist import (calib_mesh, compressed_psum, data_mesh,
                        init_error_state, shard_batch, sharded_batched_gram,
                        sharded_gram, sharded_solve)
from repro.models import BuildPlan, init_params

KEY = jax.random.PRNGKey(0)


def test_sharded_gram_matches_single():
    """shard_map local-XᵀX + one psum == the single-device Gram."""
    mesh = data_mesh()
    tap = jax.random.normal(KEY, (8, 16, 32))
    h_single = gram_from_tap(tap)
    h_shard = sharded_gram(mesh, tap)
    np.testing.assert_allclose(np.asarray(h_shard), np.asarray(h_single),
                               rtol=2e-5, atol=2e-4)


def test_sharded_batched_gram_matches_single():
    mesh = data_mesh()
    tap = jax.random.normal(KEY, (3, 8, 16))   # (E, C, d), C divisible
    h_single = batched_gram(tap)
    h_shard = sharded_batched_gram(mesh, tap)
    np.testing.assert_allclose(np.asarray(h_shard), np.asarray(h_single),
                               rtol=2e-5, atol=2e-4)


def test_sharded_gram_falls_back_on_indivisible_batch():
    mesh = data_mesh()
    odd = 3 if mesh.shape["data"] > 1 else 8   # indivisible only if multi
    tap = jax.random.normal(KEY, (odd, 16, 32))
    np.testing.assert_allclose(np.asarray(sharded_gram(mesh, tap)),
                               np.asarray(gram_from_tap(tap)),
                               rtol=2e-5, atol=2e-4)


def test_compressed_psum_multileaf_error_feedback():
    """Multi-leaf tree: mean + carried residual reconstruct the input, and
    a second application drains the carried error (EF property)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = data_mesh()
    n = mesh.shape["data"]
    g = {"a": jnp.linspace(-1.0, 1.0, 4 * n).reshape(n, 4),
         "b": jnp.full((n, 2), 0.123)}
    e = init_error_state(g)

    def f(gg, ee):
        return compressed_psum(gg, "data", ee, n)

    out, new_e = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")))(g, e)
    for k in g:
        mean = np.mean(np.asarray(g[k]), axis=0, keepdims=True)
        got = np.asarray(out[k][:1])     # replicated mean on every shard
        assert np.max(np.abs(got - mean)) < np.max(np.abs(g[k])) / 100, k
    if n == 1:   # exact EF identity on one shard
        for k in g:
            np.testing.assert_allclose(np.asarray(out[k] + new_e[k]),
                                       np.asarray(g[k]), atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m"])
def test_sharded_quantize_model_matches_single_device(arch):
    """End-to-end: quantize_model with a data mesh (taps sharded, Grams via
    one psum each; expert taps through sharded_batched_gram or its
    divisibility fallback) agrees with the single-device pipeline."""
    cfg = get_smoke_config(arch)
    plan = BuildPlan(remat=False)
    params = init_params(KEY, cfg, plan)
    tokens = jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="greedy")
    mesh = data_mesh()
    q_sh, r_sh = quantize_model(params, cfg, plan, tokens, spec, mesh=mesh)
    q_single, r_single = quantize_model(params, cfg, plan, tokens, spec)
    a_sh = sum(r.err_after for r in r_sh.layers)
    a_single = sum(r.err_after for r in r_single.layers)
    assert abs(a_sh - a_single) / a_single < 0.02, (a_sh, a_single)
    # codes agree except (rarely) on grid ties moved by summation order
    from repro.core.pipeline import is_qtensor
    checked = 0
    for lkey, lp in q_sh["__qlayers__"].items():
        for mod, leaves in lp.items():
            if not isinstance(leaves, dict) or is_qtensor(leaves):
                continue
            for leaf, qt in leaves.items():
                if not is_qtensor(qt):
                    continue
                ref = q_single["__qlayers__"][lkey][mod][leaf]
                agree = float(jnp.mean(
                    (qt["codes"] == ref["codes"]).astype(jnp.float32)))
                assert agree > 0.99, (lkey, mod, leaf, agree)
                checked += 1
    assert checked > 0


def test_sharded_gram_fallback_warns():
    """The replicated-Gram fallback must never be silent."""
    mesh = data_mesh()
    if mesh.shape["data"] == 1:
        pytest.skip("needs a multi-device data axis")
    odd = mesh.shape["data"] + 1
    with pytest.warns(UserWarning, match="falling back"):
        sharded_gram(mesh, jax.random.normal(KEY, (odd, 4, 8)))
    with pytest.warns(UserWarning, match="moe_capacity_multiple"):
        sharded_batched_gram(mesh, jax.random.normal(KEY, (2, odd, 8)))


def test_moe_capacity_aligns_to_data_axis():
    """With a multi-device data axis, quantize_model rounds the MoE routing
    capacity up so (E, C, d) expert taps divide it — the expert Gram never
    leaves the psum path (no fallback warning)."""
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("granite-moe-3b-a800m")
    plan = BuildPlan(remat=False, moe_capacity_multiple=8)
    params = init_params(KEY, cfg, plan)
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    taps = {}
    moe_mod.apply_moe(lp["moe"], x, cfg, plan.experts_padded(cfg),
                      plan.moe_token_chunk, taps=taps,
                      capacity_multiple=plan.moe_capacity_multiple)
    assert taps["expert_in"].shape[1] % 8 == 0
    assert taps["expert_down_in"].shape[1] % 8 == 0
    # multiple=1 keeps the historical (unrounded) capacity exactly
    from repro.models.common import pad_to_multiple
    N = int(np.prod(x.shape[:2]))
    hist = max(8, int(N * cfg.moe.top_k * cfg.moe.capacity_factor
                      / max(cfg.moe.n_experts, 1)))
    taps1 = {}
    moe_mod.apply_moe(lp["moe"], x, cfg, plan.experts_padded(cfg),
                      plan.moe_token_chunk, taps=taps1)
    assert taps1["expert_in"].shape[1] == hist
    assert taps["expert_in"].shape[1] == pad_to_multiple(hist, 8)


def test_sharded_solve_matches_replicated():
    """Column-sharded solve on whatever local mesh exists: bit-identical
    codes/zero-points to the replicated trailing-update solve, scales to
    f32 rounding, per-column errors to tolerance — incl. padded columns
    and the shared-greedy order (perm precomputed on the full W)."""
    from repro.core.comq_hessian import comq_quantize_blocked, gram
    mesh = calib_mesh(model=jax.device_count())
    for (m, n, order) in ((64, 96, "cyclic"), (64, 90, "cyclic"),
                          (96, 100, "greedy_shared")):
        spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9,
                         sweeps=2, order=order)
        k1, k2 = jax.random.split(jax.random.PRNGKey(m + n))
        h = gram(jax.random.normal(k1, (2 * m, m)))
        w = jax.random.normal(k2, (m, n)) * 0.05
        ref = comq_quantize_blocked(h, w, spec, block=32)
        q, delta, z_lo, e2b, e2a = sharded_solve(mesh, h, w, spec,
                                                 "comq_blocked", block=32)
        assert bool(jnp.all(q == ref.q)), (m, n, order)
        assert bool(jnp.all(z_lo == ref.z_lo))
        np.testing.assert_allclose(np.asarray(delta), np.asarray(ref.delta),
                                   rtol=2e-6)
        # per-column errors sum to the solver's trajectory error
        err = float(jnp.sqrt(jnp.maximum(jnp.sum(e2a), 0.0)))
        np.testing.assert_allclose(err, float(ref.errors[-1]), rtol=1e-3,
                                   atol=1e-4)


def test_sharded_solve_issues_no_collectives():
    """DESIGN.md §4.3: between the Gram psum and the final quantized
    weights the column-sharded solve is zero-communication. Checked via
    the contract API (analysis/contracts.py) — the census covers every
    collective family, not just the ones an ad-hoc grep remembers."""
    from repro.analysis import Contract, check_lowered
    from repro.dist.calibrate import _solve_fn
    mesh = calib_mesh(model=jax.device_count())
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    f = _solve_fn(mesh, spec, "comq_blocked", 32)
    m, n = 64, 96
    h = jnp.eye(m)
    w = jnp.ones((m, n))
    perm = jnp.arange(m, dtype=jnp.int32)
    viol = check_lowered(f, h, w, perm,
                         con=Contract(name="dist.solve", collectives=0))
    assert not viol, viol


def test_sharded_gram_is_one_psum_per_tap():
    """DESIGN.md §4.2: the data-parallel Gram compiles to exactly one
    all-reduce (and no other collective family). On a single device the
    psum compiles away, so the exact-count contract binds only under the
    multi-device CI job."""
    from repro.analysis import Contract, check_lowered
    mesh = data_mesh()
    nd = mesh.shape["data"]
    if nd < 2:
        pytest.skip("psum compiles away on a 1-device data axis")
    from repro.dist.calibrate import _gram_fn
    viol = check_lowered(
        _gram_fn(mesh), jnp.ones((4 * nd, 32)),
        con=Contract(name="dist.gram", collectives={"all-reduce": 1}))
    assert not viol, viol


def test_shard_batch_rejects_indivisible():
    mesh = data_mesh()
    if mesh.shape["data"] == 1:
        pytest.skip("needs a multi-device data axis")
    with pytest.raises(ValueError):
        shard_batch(mesh, jnp.zeros((mesh.shape["data"] + 1, 4)))


def test_sharding_specs_compile_on_2d_mesh():
    """param/input specs + the constrain callback lower a train-loss cell
    on a (data, model) mesh (the dryrun path, shrunk to local devices)."""
    from jax.sharding import Mesh
    from repro.dist.sharding import (batch_dim_spec, input_batch_specs,
                                     make_constrain, named, param_specs,
                                     dp_size, tp_size)
    from repro.models import lm_loss
    n = jax.device_count()
    shape = (2, n // 2) if n >= 2 else (1, 1)
    mesh = Mesh(np.asarray(jax.devices()[:shape[0] * shape[1]]
                           ).reshape(shape), ("data", "model"))
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(tp=tp_size(mesh), remat=False,
                     constrain=make_constrain(mesh, 8))
    params_shape = jax.eval_shape(
        lambda k: init_params(k, cfg, plan), jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, mesh)
    tokens = jax.ShapeDtypeStruct((8, 64), jnp.int32)
    bspec = input_batch_specs({"tokens": tokens}, mesh, 8)["tokens"]
    assert batch_dim_spec(mesh, 8) == "data"
    assert dp_size(mesh) * tp_size(mesh) == mesh.size
    with mesh:
        jax.jit(
            lambda p, t: lm_loss(p, cfg, plan, {"tokens": t, "labels": t})[0],
            in_shardings=(named(mesh, pspecs), named(mesh, bspec)),
        ).lower(params_shape, tokens).compile()


_FORCED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.dist import data_mesh, sharded_gram
from repro.core.calibrate import gram_from_tap
assert jax.device_count() == 8, jax.device_count()
mesh = data_mesh()
tap = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))
np.testing.assert_allclose(np.asarray(sharded_gram(mesh, tap)),
                           np.asarray(gram_from_tap(tap)),
                           rtol=2e-5, atol=2e-4)
from repro.configs import get_smoke_config
from repro.core import QuantSpec, quantize_model
from repro.models import BuildPlan, init_params
cfg = get_smoke_config("qwen2-7b")
plan = BuildPlan(remat=False)
params = init_params(jax.random.PRNGKey(0), cfg, plan)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                            cfg.vocab_size)
spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                 order="greedy")
_, r8 = quantize_model(params, cfg, plan, tokens, spec, method="rtn",
                       mesh=mesh)
_, r1 = quantize_model(params, cfg, plan, tokens, spec, method="rtn")
a8 = sum(r.err_after for r in r8.layers)
a1 = sum(r.err_after for r in r1.layers)
assert abs(a8 - a1) / a1 < 0.02, (a8, a1)
print("FORCED_OK")
"""


def test_forced_8_device_sharded_calibration():
    """Real multi-shard fidelity regardless of the host's device count:
    subprocess forces 8 host devices (conftest forbids in-process XLA_FLAGS)
    and checks sharded Grams + a sharded RTN pipeline against 1-device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _FORCED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FORCED_OK" in out.stdout


_COLSHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.core import QuantSpec
from repro.core.comq_hessian import comq_quantize_blocked, gram
from repro.core.pipeline import _solve_group
from repro.dist import calib_mesh, sharded_solve
import functools

assert jax.device_count() == 8
mesh = calib_mesh(model=4)                     # the forced (2, 4) mesh
assert dict(mesh.shape) == {"data": 2, "model": 4}

# --- dense + padded column counts + shared-greedy order -------------------
for (m, n, order) in ((96, 192, "cyclic"), (96, 100, "cyclic"),
                      (64, 90, "greedy_shared")):
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order=order)
    k1, k2 = jax.random.split(jax.random.PRNGKey(m + n))
    h = gram(jax.random.normal(k1, (2 * m, m)))
    w = jax.random.normal(k2, (m, n)) * 0.05
    ref = comq_quantize_blocked(h, w, spec, block=32)
    q, delta, z_lo, _, _ = sharded_solve(mesh, h, w, spec, "comq_blocked",
                                         block=32)
    assert bool(jnp.all(q == ref.q)), (m, n, order, "codes")
    assert bool(jnp.all(z_lo == ref.z_lo)), (m, n, order, "z_lo")
    np.testing.assert_allclose(np.asarray(delta), np.asarray(ref.delta),
                               rtol=2e-6)

# --- fused shared-tap solve through the pipeline group path ---------------
# three leaves on one Gram, fused into [wq|wk|wv]: the sharded group must
# reproduce the replicated group's QTensors bit-for-bit (codes/z_lo; the
# per-shard reduction tiling moves scales by <= 2 ulp)
spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                 order="cyclic")
m = 96
k = jax.random.PRNGKey(7)
h = gram(jax.random.normal(k, (2 * m, m)))
ws = [jax.random.normal(jax.random.fold_in(k, i), (m, 64 + 13 * i)) * 0.05
      for i in range(3)]                       # ragged: 64, 77, 90 cols
solve_sh = functools.partial(sharded_solve, mesh, method="comq_blocked")
specs = [spec] * len(ws)
rep = _solve_group(ws, h, specs, "comq_blocked")
sh = _solve_group(ws, h, specs, "comq_blocked", solve_sh=solve_sh)
for (qt_r, _, ea_r, _), (qt_s, _, ea_s, _) in zip(rep, sh):
    assert bool(jnp.all(qt_r["codes"] == qt_s["codes"])), "fused codes"
    assert bool(jnp.all(qt_r["z_lo"] == qt_s["z_lo"])), "fused z_lo"
    np.testing.assert_allclose(np.asarray(qt_s["scale"]),
                               np.asarray(qt_r["scale"]), rtol=2e-6)
    np.testing.assert_allclose(float(ea_s), float(ea_r), rtol=1e-3,
                               atol=1e-4)

# --- per-leaf mixed-precision policy group (4/8/2 bits) -------------------
# mixed specs defeat fusion, so each leaf's sharded solve must match its
# own replicated solve bit-for-bit — the policy-aware _col_shardable path
import dataclasses
mspecs = [dataclasses.replace(spec, bits=b) for b in (4, 8, 2)]
rep_m = _solve_group(ws, h, mspecs, "comq_blocked")
sh_m = _solve_group(ws, h, mspecs, "comq_blocked", solve_sh=solve_sh)
for s, (qt_r, _, _, _), (qt_s, _, _, _) in zip(mspecs, rep_m, sh_m):
    assert qt_r["bits"] == qt_s["bits"] == s.bits, "policy bits"
    assert bool(jnp.all(qt_r["codes"] == qt_s["codes"])), "policy codes"
    assert bool(jnp.all(qt_r["z_lo"] == qt_s["z_lo"])), "policy z_lo"
    np.testing.assert_allclose(np.asarray(qt_s["scale"]),
                               np.asarray(qt_r["scale"]), rtol=2e-6)

# --- whole-pipeline mixed policy on the forced mesh -----------------------
# per-solve bit-identity at fixed H is asserted above; end-to-end the
# staged walk's *taps* are computed on mesh-sharded arrays, whose XLA
# partitioning is FP-different from the single-device forward (a few %
# of grid-edge code flips even for the uniform pre-policy pipeline —
# same reason the sharded-calibration test checks error sums, not bits).
# So assert the policy threading end-to-end: every leaf resolves the
# same width through the sharded pipeline, and reconstruction quality
# matches the replicated walk to the calibration test's 2% band.
from repro.configs import get_smoke_config
from repro.core import QuantPolicy, quantize_model
from repro.models import BuildPlan, init_params
cfg = get_smoke_config("qwen2-7b").replace(n_layers=4)
plan = BuildPlan(remat=False)
params = init_params(jax.random.PRNGKey(0), cfg, plan)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                            cfg.vocab_size)
pol = QuantPolicy(base=dataclasses.replace(spec, sweeps=1),
                  rules=(("*.w_down", 8),), first_layer_bits=8)
qp_sh, r_sh = quantize_model(params, cfg, plan, tokens, pol,
                             method="comq_blocked", mesh=mesh)
qp_rep, r_rep = quantize_model(params, cfg, plan, tokens, pol,
                               method="comq_blocked")
n_leaves = 0
for lkey, lp in qp_rep["__qlayers__"].items():
    for mod, leaves in lp.items():
        if not isinstance(leaves, dict):
            continue
        for leaf, v in leaves.items():
            if isinstance(v, dict) and v.get("__qtensor__"):
                o = qp_sh["__qlayers__"][lkey][mod][leaf]
                assert o["bits"] == v["bits"], (lkey, mod, leaf)
                n_leaves += 1
assert n_leaves == 7 * cfg.n_layers, n_leaves
a_sh = sum(r.err_after for r in r_sh.layers)
a_rep = sum(r.err_after for r in r_rep.layers)
assert abs(a_sh - a_rep) / a_rep < 0.02, (a_sh, a_rep)
print("COLSHARD_OK")
"""


def test_forced_2x4_column_sharded_solve_bit_identity():
    """Acceptance: on a forced (2, 4) mesh the column-sharded solve is
    bit-identical to the replicated trailing-update solve — dense, fused
    shared-tap, padded column counts, the shared-greedy order, and
    per-leaf mixed-precision (4/8/2) groups; a whole mixed-policy
    pipeline preserves per-leaf widths + error fidelity (code identity
    across the full sharded walk is FP-limited even pre-policy)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _COLSHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLSHARD_OK" in out.stdout
