"""Numerical-robustness guards (DESIGN.md §8.2): sentinels, dead columns,
escalating damping, the structured fallback chain, degenerate-calibration
completion, and the NaN-tap fault injected through the real pipeline —
each must complete with finite scales/errors and *recorded* guard events
(degradation is never silent), while healthy runs stay bit-identical to
the unguarded path.
"""
import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (GuardContext, QuantSpec, damped_inverse,
                        gptq_quantize, guarded_solve, quantize_model)
from repro.core import pipeline as pl
from repro.core.comq_hessian import gram
from repro.core.guards import DAMP_MULTS, gram_health, sanitize_array
from repro.data import (CalibrationDataError, check_calib_coverage,
                        validate_calib_features, validate_calib_tokens)
from repro.ft import FaultInjector
from repro.models import BuildPlan, init_params

PLAN = BuildPlan(remat=False)
KEY = jax.random.PRNGKey(0)
SPEC = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                 order="greedy")

M, N = 16, 8   # input dim, output columns for unit-level solves


def _xw(key=KEY, n_samples=256):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n_samples, M), jnp.float32)
    w = jax.random.normal(kw, (M, N), jnp.float32)
    return x, w


def _finite(r):
    return (bool(jnp.all(jnp.isfinite(r.delta)))
            and bool(jnp.all(jnp.isfinite(r.errors)))
            and bool(jnp.all(jnp.isfinite(r.q))))


def _kinds(gctx):
    return {e.kind for e in gctx.events}


def _assert_qlayers_finite(qparams):
    for leaf in jax.tree_util.tree_leaves(qparams["__qlayers__"]):
        arr = np.asarray(jax.device_get(leaf))
        if np.issubdtype(arr.dtype, np.floating):
            assert np.isfinite(arr).all()


# ---------------------------------------------------------------------------
# sentinels + dead columns (unit level)
# ---------------------------------------------------------------------------

def test_sanitize_array_noop_when_clean():
    x, _ = _xw()
    out, n = sanitize_array(x)
    assert n == 0 and out is x      # clean inputs pass through untouched


def test_gram_health_counts():
    x, w = _xw()
    h = gram(x.at[:, 3].set(0.0).at[:, 7].set(0.0))
    h = h.at[0, 1].set(jnp.nan)
    nf, dead, wbad = gram_health(h, [w.at[2, 2].set(jnp.inf)])
    assert nf == 1 and dead == 2 and wbad == [1]


@pytest.mark.parametrize("method", ["comq", "comq_blocked", "rtn"])
def test_dead_columns_finite_and_recorded(method):
    """All-zero activation channels: the Gram diagonal dies, every solver
    falls back to plain rounding per dead column, and the guard records
    (without escalating) how many."""
    x, w = _xw()
    h = gram(x.at[:, 4:9].set(0.0))
    gctx = GuardContext()
    r = guarded_solve(h, w, SPEC, method, gctx=gctx)
    assert _finite(r)
    deads = [e for e in gctx.events if e.kind == "dead_columns"]
    assert deads and deads[0].detail["count"] == 5


def test_nonfinite_gram_and_weight_sanitized():
    x, w = _xw()
    h = gram(x).at[0, 0].set(jnp.nan)
    w = w.at[1, 1].set(jnp.inf)
    gctx = GuardContext()
    with pytest.warns(UserWarning, match="nonfinite_"):
        r = guarded_solve(h, w, SPEC, "comq_blocked", gctx=gctx)
    assert _finite(r)
    assert {"nonfinite_gram", "nonfinite_weight"} <= _kinds(gctx)


def test_guarded_healthy_bit_identical():
    """The whole point of the host-checked design: a healthy guarded
    solve is the *same* solve, bit for bit."""
    x, w = _xw()
    h = gram(x)
    gctx = GuardContext()
    for method in ("comq", "comq_blocked", "rtn"):
        r0 = pl.solve(h, w, SPEC, method)
        r1 = guarded_solve(h, w, SPEC, method, gctx=gctx)
        assert np.array_equal(np.asarray(r0.q), np.asarray(r1.q))
        assert np.array_equal(np.asarray(r0.delta), np.asarray(r1.delta))
    assert not [e for e in gctx.events if e.kind != "dead_columns"]


# ---------------------------------------------------------------------------
# damping escalation + fallback chain (forced via solve_fn)
# ---------------------------------------------------------------------------

_BAD = types.SimpleNamespace(q=jnp.zeros((M, N), jnp.int32),
                             delta=jnp.full((N,), jnp.nan),
                             errors=jnp.array([jnp.nan]))


def test_damping_escalation_recorded():
    """A solve that only survives under damping must succeed at the first
    escalation step and record it."""
    x, w = _xw()
    h0 = gram(x)

    def flaky(h, w2d, spec, method, block=256, schedule=None):
        if method != "rtn" and bool(jnp.allclose(h, h0)):
            return _BAD                      # fails undamped
        return pl.solve(h, w2d, spec, method, block=block,
                        schedule=schedule)

    gctx = GuardContext()
    with pytest.warns(UserWarning, match="damping_escalated"):
        r = guarded_solve(h0, w, SPEC, "comq_blocked", gctx=gctx,
                          solve_fn=flaky, presanitized=True)
    assert _finite(r)
    ev = [e for e in gctx.events if e.kind == "damping_escalated"]
    assert ev and ev[0].detail["mult"] == DAMP_MULTS[0]
    assert not [e for e in gctx.events if e.kind == "fallback"]


def test_fallback_chain_lands_on_rtn():
    """Every comq stage diverges → the chain must fall through to the
    H-aware RTN stage and say so loudly."""
    x, w = _xw()

    def broken(h, w2d, spec, method, block=256, schedule=None):
        if method == "rtn":
            return pl.solve(h, w2d, spec, "rtn")
        return _BAD

    gctx = GuardContext()
    with pytest.warns(UserWarning, match="fallback"):
        r = guarded_solve(gram(x), w, SPEC, "comq_blocked", gctx=gctx,
                          solve_fn=broken, presanitized=True)
    assert _finite(r)
    assert any(e.kind == "fallback" and e.detail["solver"] == "rtn"
               for e in gctx.events)


def test_fallback_last_resort_data_free_rtn():
    """Even a poisoned solve_fn for *every* method ends at data-free RTN,
    which is finite by construction."""
    x, w = _xw()

    def hopeless(h, w2d, spec, method, block=256, schedule=None):
        return _BAD

    gctx = GuardContext()
    with pytest.warns(UserWarning, match="fallback"):
        r = guarded_solve(gram(x), w, SPEC, "comq_blocked", gctx=gctx,
                          solve_fn=hopeless, presanitized=True)
    assert _finite(r)
    assert any(e.kind == "fallback" and e.detail["solver"] == "rtn_no_h"
               for e in gctx.events)


def test_expert_group_sanitizes_nonfinite_gram():
    """The vmapped stacked-expert path cannot host-sync per expert; its
    group-batched guard must scrub a NaN-poisoned per-expert Gram and
    still produce finite expert QTensors."""
    E, d, f = 2, 8, 6
    kx, kw = jax.random.split(KEY)
    xs = jax.random.normal(kx, (E, 64, d), jnp.float32)
    hs = jax.vmap(gram)(xs).at[0, 0, 0].set(jnp.nan)
    ws = [jax.random.normal(kw, (E, d, f), jnp.float32)]
    gctx = GuardContext()
    with pytest.warns(UserWarning, match="nonfinite_gram"):
        out = pl._solve_group_experts(ws, hs, [SPEC], "comq_blocked",
                                      gctx=gctx, layer=0, names=["w_up"])
    qt, eb, ea, _ = out[0]
    assert np.isfinite(eb) and np.isfinite(ea)
    for v in qt.values():
        arr = np.asarray(jax.device_get(v))
        if np.issubdtype(arr.dtype, np.floating):
            assert np.isfinite(arr).all()
    assert "nonfinite_gram" in _kinds(gctx)


# ---------------------------------------------------------------------------
# GPTQ baseline shares the damping guard
# ---------------------------------------------------------------------------

def test_gptq_singular_hessian_stays_finite():
    x, w = _xw()
    x = x.at[:, 1:].set(x[:, :1])           # rank-1 activations
    r = gptq_quantize(gram(x), w, SPEC)
    assert _finite(r)


def test_gptq_zero_hessian_stays_finite():
    _, w = _xw()
    r = gptq_quantize(jnp.zeros((M, M)), w, SPEC)
    assert _finite(r)


def test_damped_inverse_escalates_then_scrubs():
    """An Inf-contaminated H never inverts finitely: the while_loop must
    walk every retry (×10 damping each) and the post-loop scrub must
    still hand back finite values for the caller's fallback chain."""
    h = jnp.zeros((M, M)).at[0, 0].set(jnp.inf)
    hinv, mult = damped_inverse(h, start=0.01, max_tries=4)
    assert bool(jnp.all(jnp.isfinite(hinv)))
    assert float(mult) == pytest.approx(0.01 * 10 ** 4)


def test_damped_inverse_healthy_no_escalation():
    x, _ = _xw()
    hinv, mult = damped_inverse(gram(x), start=0.01)
    assert bool(jnp.all(jnp.isfinite(hinv)))
    assert float(mult) == pytest.approx(0.01)


# ---------------------------------------------------------------------------
# calibration-data validation (satellite: data plumbing)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    None,
    np.zeros((0, 8), np.int32),                    # empty
    np.zeros((2, 4, 4), np.int32),                 # rank 3
    np.zeros((2, 8), np.float32),                  # not integer ids
    np.full((2, 8), -1, np.int32),                 # negative ids
    np.full((2, 8), 999, np.int32),                # >= vocab
])
def test_validate_calib_tokens_rejects(bad):
    with pytest.raises(CalibrationDataError):
        validate_calib_tokens(bad, vocab_size=100)


def test_validate_calib_tokens_accepts():
    tok = np.zeros((2, 8), np.int32)
    assert validate_calib_tokens(tok, vocab_size=100) is tok


@pytest.mark.parametrize("bad", [
    None,
    np.zeros((0, 4), np.float32),
    np.zeros((2, 4), np.int32),
    np.array([[1.0, np.nan]], np.float32),
])
def test_validate_calib_features_rejects(bad):
    with pytest.raises(CalibrationDataError):
        validate_calib_features(bad)


def test_coverage_warning():
    with pytest.warns(UserWarning, match="rank-deficient"):
        assert not check_calib_coverage(8, {"d_model": 56})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert check_calib_coverage(1000, {"d_model": 56})


# ---------------------------------------------------------------------------
# degenerate calibration through the real pipeline
# ---------------------------------------------------------------------------

def test_nan_tap_injection_fused_path():
    """Poison the first tap (the fused wq|wk|wv shared tap) with an
    injected NaN: the sentinel scrubs it, records nonfinite_tap for every
    leaf of the group, annotates the per-leaf report, and the run stays
    finite end to end."""
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    inj = FaultInjector({"nan_tap": [1]})
    with pytest.warns(UserWarning, match="nonfinite_tap"):
        qp, rep = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                 method="comq_blocked", injector=inj)
    taps = [e for e in rep.guard_events if e.kind == "nonfinite_tap"]
    assert {e.name for e in taps} == {"attn.wq", "attn.wk", "attn.wv"}
    assert all(e.layer == 0 for e in taps)
    annotated = {lr.name for lr in rep.layers
                 if lr.layer == 0 and "nonfinite_tap" in lr.guard}
    assert annotated == {"attn.wq", "attn.wk", "attn.wv"}
    _assert_qlayers_finite(qp)
    assert all(np.isfinite(lr.err_after) for lr in rep.layers)


def test_nan_tap_injection_moe_all_groups():
    """Poison every tap group of the first MoE layer (attention, shared
    tap, and the stacked-expert taps): each scrub is recorded and the
    whole run stays finite."""
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    inj = FaultInjector({"nan_tap": [1, 2, 3, 4]})
    with pytest.warns(UserWarning, match="nonfinite_tap"):
        qp, rep = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                 method="comq_blocked", injector=inj)
    taps = [e for e in rep.guard_events if e.kind == "nonfinite_tap"]
    assert len({e.name for e in taps}) >= 4
    _assert_qlayers_finite(qp)
    assert all(np.isfinite(lr.err_after) for lr in rep.layers)


def test_constant_activation_calibration_completes():
    """A single repeated token id gives (near) rank-1 activations per
    tap — the run must still complete with finite scales/errors."""
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jnp.full((4, 64), 7, jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        qp, rep = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                 method="comq_blocked")
    _assert_qlayers_finite(qp)
    assert all(np.isfinite(lr.err_after) for lr in rep.layers)


def test_calibration_smaller_than_input_dim_completes():
    """Fewer calibration tokens than the widest leaf input dim: coverage
    warns up front, the rank-deficient Gram leans on the dead-column /
    damping guards, and the run completes finite."""
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (1, 32), 0, cfg.vocab_size)
    with pytest.warns(UserWarning, match="rank-deficient"):
        qp, rep = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                 method="comq_blocked")
    _assert_qlayers_finite(qp)
    assert all(np.isfinite(lr.err_after) for lr in rep.layers)


def test_guards_off_healthy_run_bit_identical():
    """guards=False vs guards=True on a healthy run: same bits."""
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    q0, rep0 = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked", guards=False)
    q1, rep1 = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked", guards=True)
    assert rep1.guard_events == []
    la = jax.tree_util.tree_leaves(q0["__qlayers__"])
    lb = jax.tree_util.tree_leaves(q1["__qlayers__"])
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert np.array_equal(np.asarray(jax.device_get(a)),
                              np.asarray(jax.device_get(b)))
