"""Quantized paged KV pages (DESIGN.md §11): encode/decode round-trip,
write-path scale discipline (prefill scatter + decode append), in-kernel
dequant vs XLA fallback vs oracle agreement across GQA/MQA/SWA shapes,
partitioned allocator, and token identity under scheduling churn
(mixed + staggered arrivals, preemption, crash-replay) for int8 pages
with a bounded-drift gate for 4-bit."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.ft import FaultInjector, Journal, SimulatedKill
from repro.kernels.ref import paged_attention_quant_ref, paged_attention_ref
from repro.models import BuildPlan, init_params
from repro.models.attention import (head_to_kv_map, paged_decode_attend_quant,
                                    paged_insert_quant)
from repro.serve import Runtime, ServeConfig, recover_runtime
from repro.serve.kv_cache import (BlockAllocator, kv_decode, kv_encode,
                                  kv_scale_of, paged_cache_bytes,
                                  write_prefill)

KEY = jax.random.PRNGKey(0)


def _f32_setup(arch="qwen2-7b", kv_bits=0):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32, kv_bits=kv_bits)
    params = init_params(KEY, cfg, plan)
    return cfg, plan, params


def _runtime(params, cfg, plan, **kw):
    sc = dict(max_slots=3, block_size=8, num_blocks=24, buckets=(8, 16, 32),
              max_blocks_per_slot=6)
    sc.update(kw)
    return Runtime(params, cfg, plan, ServeConfig(**sc))


# ---------------------------------------------------------------------------
# encode/decode round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits,tol", [(8, 0.02), (4, 0.35)])
def test_kv_roundtrip_bounded(kv_bits, tol):
    rows = jax.random.normal(KEY, (6, 2, 32), jnp.float32)
    scale = kv_scale_of(jnp.max(jnp.abs(rows), axis=-1), kv_bits)
    back = kv_decode(kv_encode(rows, scale, kv_bits), scale, kv_bits)
    err = np.max(np.abs(np.asarray(back - rows)))
    amax = float(np.max(np.abs(np.asarray(rows))))
    assert err <= tol * amax, (err, amax)


def test_kv_zero_scale_encodes_exact_zero():
    rows = jnp.zeros((4, 2, 16))
    for kv_bits in (8, 4):
        scale = kv_scale_of(jnp.max(jnp.abs(rows), axis=-1), kv_bits)
        codes = kv_encode(rows, scale, kv_bits)
        assert not np.any(np.asarray(kv_decode(codes, scale, kv_bits)))


# ---------------------------------------------------------------------------
# kernel agreement: oracle vs in-kernel dequant vs XLA fallback
# ---------------------------------------------------------------------------

def _quant_pool(key, NB, BS, KV, hd, kv_bits):
    kk, kv_ = jax.random.split(key)
    kf = jax.random.normal(kk, (NB, BS, KV, hd), jnp.float32)
    vf = jax.random.normal(kv_, (NB, BS, KV, hd), jnp.float32)
    ks = kv_scale_of(jnp.max(jnp.abs(kf), axis=(1, 3)), kv_bits)  # (NB, KV)
    vs = kv_scale_of(jnp.max(jnp.abs(vf), axis=(1, 3)), kv_bits)
    kq = kv_encode(kf.transpose(0, 2, 1, 3), ks[:, :, None],
                   kv_bits).transpose(0, 2, 1, 3)
    vq = kv_encode(vf.transpose(0, 2, 1, 3), vs[:, :, None],
                   kv_bits).transpose(0, 2, 1, 3)
    return kq, vq, ks, vs


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_quant_ref_equals_bf16_ref_on_dequantized_pool(kv_bits):
    """The quantized oracle IS the bf16 oracle applied to the dequantized
    pool — exactly, not approximately."""
    NB, BS, KV, hd, B, H, MAXB = 10, 8, 2, 32, 3, 8, 4
    kq, vq, ks, vs = _quant_pool(KEY, NB, BS, KV, hd, kv_bits)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, hd), jnp.float32)
    bt = jnp.asarray(np.random.RandomState(0).randint(0, NB, (B, MAXB)),
                     jnp.int32)
    lengths = jnp.asarray([17, 0, 32], jnp.int32)
    want = paged_attention_ref(
        q, kv_decode(kq.transpose(0, 2, 1, 3), ks[:, :, None],
                     kv_bits).transpose(0, 2, 1, 3),
        kv_decode(vq.transpose(0, 2, 1, 3), vs[:, :, None],
                  kv_bits).transpose(0, 2, 1, 3),
        bt, lengths)
    got = paged_attention_quant_ref(q, kq, vq, ks, vs, bt, lengths,
                                    kv_bits=kv_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kv_bits", [8, 4])
@pytest.mark.parametrize("H,KV,window", [(8, 2, 0),    # GQA
                                         (8, 8, 0),    # MHA
                                         (8, 1, 9),    # MQA + SWA
                                         (8, 2, 9)])   # GQA + SWA
def test_quant_fallback_and_kernel_match_ref(kv_bits, H, KV, window):
    """XLA gather fallback and the interpret-mode Pallas kernel (per-page
    scales folded into online softmax) both match the dequantizing oracle.
    The kernel matches everywhere incl. inactive slots (exact zeros); the
    fallback's dense attend is only defined on active slots."""
    NB, BS, hd, B, MAXB = 10, 8, 32, 3, 4
    kq, vq, ks, vs = _quant_pool(KEY, NB, BS, KV, hd, kv_bits)
    q1 = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd), jnp.float32)
    bt = jnp.asarray(np.random.RandomState(0).randint(0, NB, (B, MAXB)),
                     jnp.int32)
    lengths = jnp.asarray([17, 0, 32], jnp.int32)
    hm = head_to_kv_map(H, H, KV)
    want = np.asarray(paged_attention_quant_ref(
        q1[:, 0], kq, vq, ks, vs, bt, lengths, window=window,
        kv_bits=kv_bits))
    got_x = np.asarray(paged_decode_attend_quant(
        q1, kq, vq, ks, vs, bt, lengths, hm, window=window,
        kv_bits=kv_bits, mode="xla"))[:, 0]
    act = np.asarray(lengths) > 0
    np.testing.assert_allclose(got_x[act], want[act], rtol=2e-5, atol=2e-5)
    got_p = np.asarray(paged_decode_attend_quant(
        q1, kq, vq, ks, vs, bt, lengths, hm, window=window,
        kv_bits=kv_bits, mode="interpret"))[:, 0]
    np.testing.assert_allclose(got_p, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# write paths: prefill scatter + decode append
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [8, 4])
def test_write_prefill_quantizes_and_wipes_stale_scales(kv_bits):
    L, NB, BS, KV, hd, S, MAXB = 2, 6, 4, 2, 8, 10, 3
    cpb = 1 if kv_bits == 8 else 2
    dt = jnp.int8 if kv_bits == 8 else jnp.uint8
    pool = {"k": jnp.zeros((L, NB, BS, KV, hd // cpb), dt),
            "v": jnp.zeros((L, NB, BS, KV, hd // cpb), dt),
            "k_scale": jnp.zeros((L, NB, KV), jnp.float32),
            "v_scale": jnp.zeros((L, NB, KV), jnp.float32)}
    # poison a page this request will reuse: prefill must overwrite its
    # scale, not max against the stale one
    pool["k_scale"] = pool["k_scale"].at[:, 2].set(99.0)
    k_seq = jax.random.normal(KEY, (L, S, KV, hd), jnp.float32)
    v_seq = jax.random.normal(jax.random.PRNGKey(3), (L, S, KV, hd),
                              jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32).at[5].set(-1)   # one masked row
    table = jnp.asarray([2, 0, 4], jnp.int32)            # pages 2, 0, 4
    out = write_prefill(pool, k_seq, v_seq, pos, table, kv_bits=kv_bits)
    assert float(jnp.max(out["k_scale"][:, 2])) < 99.0   # stale wiped
    assert not np.any(np.asarray(out["k_scale"][:, 1]))  # untouched page
    assert not np.any(np.asarray(out["k"][:, 1]))
    # reconstruction within the code width at each page's scale
    for name, seq in (("k", k_seq), ("v", v_seq)):
        rows = kv_decode(out[name].transpose(0, 1, 3, 2, 4),
                         out[name + "_scale"][:, :, :, None],
                         kv_bits).transpose(0, 1, 3, 2, 4)
        for s in range(S):
            if s == 5:
                continue
            page, off = table[s // BS], s % BS
            got = np.asarray(rows[:, page, off])
            want = np.asarray(seq[:, s])
            scale = np.asarray(out[name + "_scale"][:, page])[..., None]
            assert np.max(np.abs(got - want) - 0.51 * scale) <= 0


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_paged_insert_quant_running_max_and_fresh_reset(kv_bits):
    NB, BS, KV, hd, B, MAXB = 6, 4, 2, 8, 2, 3
    kq = jnp.zeros((NB, BS, KV, hd // (1 if kv_bits == 8 else 2)),
                   jnp.int8 if kv_bits == 8 else jnp.uint8)
    ks = jnp.zeros((NB, KV), jnp.float32)
    bt = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    rs = np.random.RandomState(4)

    def tok(scale):
        return jnp.asarray(rs.normal(scale=scale, size=(B, 1, KV, hd)),
                           jnp.float32)

    # fresh page (off == 0): scale resets to this token's absmax
    t0 = tok(1.0)
    kq1, ks1, vq1, vs1 = paged_insert_quant(
        kq, kq, ks, ks, t0, t0, bt, jnp.asarray([0, 0], jnp.int32),
        kv_bits=kv_bits)
    back = kv_decode(kq1.transpose(0, 2, 1, 3), ks1[:, :, None],
                     kv_bits).transpose(0, 2, 1, 3)
    got = np.asarray(back[np.asarray(bt[:, 0]), 0])
    assert np.max(np.abs(got - np.asarray(t0[:, 0]))) \
        <= 0.51 * float(np.max(np.asarray(ks1))) + 1e-6
    # appending a larger token raises the scale; old codes rescale with
    # bounded drift
    t1 = tok(4.0)
    kq2, ks2, _, _ = paged_insert_quant(
        kq1, vq1, ks1, vs1, t1, t1, bt, jnp.asarray([1, 1], jnp.int32),
        kv_bits=kv_bits)
    assert np.all(np.asarray(ks2[np.asarray(bt[:, 0])])
                  >= np.asarray(ks1[np.asarray(bt[:, 0])]) - 1e-7)
    back2 = kv_decode(kq2.transpose(0, 2, 1, 3), ks2[:, :, None],
                      kv_bits).transpose(0, 2, 1, 3)
    drift = np.abs(np.asarray(back2[np.asarray(bt[:, 0]), 0])
                   - np.asarray(back[np.asarray(bt[:, 0]), 0]))
    assert np.max(drift) <= 1.01 * float(np.max(np.asarray(ks2)))
    # inactive slot (-1): nothing written
    kq3, ks3, _, _ = paged_insert_quant(
        kq1, vq1, ks1, vs1, t1, t1, bt, jnp.asarray([1, -1], jnp.int32),
        kv_bits=kv_bits)
    np.testing.assert_array_equal(np.asarray(kq3[3:]), np.asarray(kq1[3:]))
    np.testing.assert_array_equal(np.asarray(ks3[3:]), np.asarray(ks1[3:]))


def test_paged_insert_quant_same_scale_is_byte_stable():
    """Appending a token no larger than the page's current range must not
    rewrite existing codes (ratio = 1 path is exact, not approximate)."""
    NB, BS, KV, hd, B = 4, 4, 2, 8, 1
    kq = jnp.zeros((NB, BS, KV, hd), jnp.int8)
    ks = jnp.zeros((NB, KV), jnp.float32)
    bt = jnp.asarray([[0, 1]], jnp.int32)
    big = jnp.full((B, 1, KV, hd), 2.0, jnp.float32)
    small = jnp.full((B, 1, KV, hd), 0.5, jnp.float32)
    kq1, ks1, vq1, vs1 = paged_insert_quant(
        kq, kq, ks, ks, big, big, bt, jnp.asarray([0], jnp.int32), kv_bits=8)
    kq2, ks2, _, _ = paged_insert_quant(
        kq1, vq1, ks1, vs1, small, small, bt, jnp.asarray([1], jnp.int32),
        kv_bits=8)
    np.testing.assert_array_equal(np.asarray(ks2), np.asarray(ks1))
    np.testing.assert_array_equal(np.asarray(kq2[0, 0]),
                                  np.asarray(kq1[0, 0]))


# ---------------------------------------------------------------------------
# partitioned allocator
# ---------------------------------------------------------------------------

def test_allocator_partitions_own_disjoint_ranges():
    a = BlockAllocator(12, partitions=3)
    assert a.partition_blocks == 4
    got = {p: a.alloc(4, part=p) for p in range(3)}
    for p, pages in got.items():
        assert all(a.partition_of(b) == p for b in pages)
        assert set(pages) == set(range(p * 4, (p + 1) * 4))
        assert a.num_free_in(p) == 0
    assert a.alloc(1, part=1) is None       # partition exhausted
    a.free(got[1])
    assert a.num_free_in(1) == 4
    a.check_integrity()


def test_allocator_single_partition_order_unchanged():
    """partitions=1 must allocate in exactly the legacy LIFO order — the
    solo-run oracle depends on page-id determinism."""
    legacy = BlockAllocator(6)
    assert legacy.alloc(3) == [0, 1, 2]
    part = BlockAllocator(6, partitions=1)
    assert part.alloc(3) == [0, 1, 2]


# ---------------------------------------------------------------------------
# end-to-end: runtime on quantized pages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits,dt,div", [(8, jnp.int8, 1),
                                            (4, jnp.uint8, 2)])
def test_runtime_pool_layout_and_bytes(kv_bits, dt, div):
    cfg, plan, params = _f32_setup(kv_bits=kv_bits)
    rt = _runtime(params, cfg, plan)
    hd = cfg.resolved_head_dim
    assert rt.pool["k"].dtype == dt
    assert rt.pool["k"].shape[-1] == hd // div
    assert rt.pool["k_scale"].shape == (cfg.n_layers, 24, cfg.n_kv_heads)
    ratio = (paged_cache_bytes(cfg, plan.replace(kv_bits=0), 24, 8)
             / paged_cache_bytes(cfg, plan, 24, 8))
    # f32 cache dtype here: int8 halves again on the bf16 deployment plan
    assert ratio >= (3.6 if kv_bits == 8 else 6.0)


@pytest.mark.parametrize("arch", ["qwen2-7b",           # GQA
                                  "h2o-danube-1.8b",    # SWA
                                  "granite-moe-3b-a800m"])   # MoE
def test_int8_pages_token_identity_mixed_staggered(arch):
    """int8 self-identity: under mixed lengths, staggered arrivals, and
    slot/page reuse, every request's greedy tokens equal its solo run on
    the same quantized runtime — quantization error is a function of the
    written pages only, never of scheduling history."""
    cfg, plan, params = _f32_setup(arch, kv_bits=8)
    rs = np.random.RandomState(1)
    lens = [30, 16, 28, 8] if cfg.sliding_window else [5, 16, 11, 8]
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    rt = _runtime(params, cfg, plan, max_slots=2, num_blocks=12)
    reqs = [rt.submit(p, max_new_tokens=6) for p in prompts[:2]]
    rt.step()
    reqs.append(rt.submit(prompts[2], max_new_tokens=6))
    rt.step()
    reqs.append(rt.submit(prompts[3], max_new_tokens=6))
    rt.run()
    for p, r in zip(prompts, reqs):
        solo = _runtime(params, cfg, plan, max_slots=2,
                        num_blocks=12).generate([p], max_new_tokens=6)[0]
        np.testing.assert_array_equal(np.asarray(r.out_tokens), solo)
    assert rt.allocator.num_free == rt.allocator.num_blocks


def test_int8_pages_near_identity_under_preemption():
    """A pool too small for all lifetimes forces preemption-by-page-
    reclaim. A resumed request re-prefills its history, which re-rounds
    page codes once at the final scatter-max scale, where the solo run's
    append path rounded at intermediate running-max scales and rescaled
    — same final scales, codes within 1 LSB. So the gate here is long
    shared prefixes (a near-tie argmax can flip late in a decode), with
    every pre-resume token exact; the bf16 deployment config's exact
    preempted identity is gated in benchmarks/serve_bench.py."""
    cfg, plan, params = _f32_setup(kv_bits=8)
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (8, 7, 8, 6)]
    solo_rt = _runtime(params, cfg, plan, max_slots=1, num_blocks=3,
                       max_blocks_per_slot=3)
    solo = [solo_rt.generate([p], max_new_tokens=17)[0] for p in prompts]
    rt = _runtime(params, cfg, plan, max_slots=4, num_blocks=8,
                  max_blocks_per_slot=3)
    reqs = [rt.submit(p, max_new_tokens=17) for p in prompts]
    m = rt.run()
    assert m["preemptions"] > 0          # the pool genuinely thrashed
    agree = []
    for r, want in zip(reqs, solo):
        got, want = np.asarray(r.out_tokens), np.asarray(want)
        same = got == want
        agree.append((int(np.argmin(same)) if not same.all() else 17) / 17)
    assert np.mean(agree) >= 0.85, agree


def test_int8_pages_crash_replay_token_identity(tmp_path):
    """Kill mid-decode and recover: the quantized-pool runtime journals /
    replays like the bf16 one, and replayed tokens match solo runs."""
    cfg, plan, params = _f32_setup(kv_bits=8)
    rs = np.random.RandomState(23)
    prompts = [rs.randint(0, cfg.vocab_size, (int(l),)).astype(np.int32)
               for l in rs.randint(6, 15, 3)]
    solo = _runtime(params, cfg, plan).generate(prompts, max_new_tokens=8)
    inj = FaultInjector({"kill": {4}})
    sc = ServeConfig(max_slots=3, block_size=8, num_blocks=24,
                     buckets=(8, 16, 32), max_blocks_per_slot=6)
    rt = Runtime(params, cfg, plan, sc, journal=Journal(str(tmp_path)),
                 injector=inj)
    reqs = [rt.submit(p, max_new_tokens=8) for p in prompts]
    with pytest.raises(SimulatedKill):
        rt.run()
    rt2, st = recover_runtime(params, cfg, plan, str(tmp_path), sc)
    assert rt2.kv_bits == 8 and "k_scale" in rt2.pool
    assert set(st.inflight) == {r.rid for r in reqs}
    replayed = {r.rid: r for r in rt2.scheduler.queue}
    rt2.run()
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(
            np.asarray(replayed[r.rid].out_tokens), want)


def test_kv4_pages_bounded_drift_vs_solo():
    """4-bit pages: same preemption workload as the int8 identity test,
    gated on prefix agreement with the 4-bit solo runs instead of
    exactness (15-level rounding shifts near-tie logits a few steps into
    some decodes)."""
    cfg, plan, params = _f32_setup(kv_bits=4)
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (8, 7, 8, 6)]
    solo_rt = _runtime(params, cfg, plan, max_slots=1, num_blocks=3,
                       max_blocks_per_slot=3)
    solo = [solo_rt.generate([p], max_new_tokens=17)[0] for p in prompts]
    rt = _runtime(params, cfg, plan, max_slots=4, num_blocks=8,
                  max_blocks_per_slot=3)
    reqs = [rt.submit(p, max_new_tokens=17) for p in prompts]
    rt.run()
    agree = []
    for r, want in zip(reqs, solo):
        got, want = np.asarray(r.out_tokens), np.asarray(want)
        n = min(len(got), len(want))
        same = got[:n] == want[:n]
        agree.append((int(np.argmin(same)) if not same.all() else n) / 17)
    assert np.mean(agree) >= 0.5, agree


# ---------------------------------------------------------------------------
# TP slot+page sharding (forced 8 host devices, subprocess)
# ---------------------------------------------------------------------------

_TP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs import get_smoke_config
from repro.models import BuildPlan, init_params
from repro.serve import Runtime, ServeConfig
from repro.analysis.contracts import Contract, check_lowered
assert jax.device_count() == 8, jax.device_count()
cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32")
plan = BuildPlan(remat=False, cache_dtype=jnp.float32, kv_bits=8)
params = init_params(jax.random.PRNGKey(0), cfg, plan)
rs = np.random.RandomState(0)
prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
           for l in (9, 14, 7, 12)]
sc = ServeConfig(max_slots=4, block_size=8, num_blocks=16,
                 buckets=(8, 16), max_blocks_per_slot=4)
base = Runtime(params, cfg, plan, sc).generate(prompts, max_new_tokens=8)
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("model",))
rt = Runtime(params, cfg, plan, sc, mesh=mesh)
got = rt.generate(prompts, max_new_tokens=8)
for i, (a, b) in enumerate(zip(base, got)):
    assert np.array_equal(a, b), (i, a, b)
B = sc.max_slots
args = (rt.params, rt.pool, jnp.zeros((B, rt.maxb), jnp.int32),
        jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32))
viol = check_lowered(rt._decode, *args,
                     con=Contract(name="serve.decode_step.tp",
                                  collectives=0, donated=(1,)))
assert not viol, viol
bucket = sc.buckets[0]
_, cache = rt._prefill_fn(bucket)(rt.params,
                                  jnp.zeros((1, bucket), jnp.int32))
kv = cache["kv"]
fn = rt._write_fn(int(kv.k.shape[2]))
wargs = (rt.pool, kv.k[:, 0], kv.v[:, 0], kv.pos[0, 0],
         jnp.int32(bucket), jnp.zeros((rt.maxb,), jnp.int32))
viol = check_lowered(fn, *wargs,
                     con=Contract(name="serve.prefill_write.tp",
                                  collectives=0, donated=(0,)))
assert not viol, viol
print("TP_SERVE_OK")
"""


def test_forced_8_device_tp_quantized_serving():
    """Slot+page-sharded int8-page serving on a forced 4-way model mesh:
    token parity with the meshless runtime, decode step lowers with zero
    collectives and the sharded pool donated, prefill-write likewise
    (tests/test_dist.py subprocess idiom: conftest forbids in-process
    XLA_FLAGS)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _TP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TP_SERVE_OK" in out.stdout
