"""Compile-contract checker + lint gate (repro.analysis, DESIGN.md §9).

Each pass is held to "catches the seeded violation": a planted psum for
the collective census, a dtype-mismatched donation for the donation
audit, a shape-varying loop for the retrace guard, and lint fixture
snippets (with and without waiving pragmas) for each lint rule. The
roofline cost model is locked bit-identically to a saved-HLO golden so
the parser refactor (roofline -> analysis.hlo) stays observationally
invisible.
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (Contract, ContractViolation, RetraceViolation,
                            assert_contract, audit_donation, check_hlo,
                            check_lowered, collective_census, compile_count,
                            contract, contract_of, guard_jit, parse_hlo,
                            parse_io_aliases, reset_guards)
from repro.analysis.lint import lint_source
from repro.analysis.retrace import GuardRecord

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
MIXED_HLO = os.path.join(FIXTURES, "roofline_mixed.hlo")
MIXED_GOLDEN = os.path.join(FIXTURES, "roofline_mixed.golden.json")


def _mixed_text():
    with open(MIXED_HLO, encoding="utf-8") as fh:
        return fh.read()


# ---------------------------------------------------------------------------
# shared HLO parser + roofline bit-identity
# ---------------------------------------------------------------------------

def test_roofline_cost_bit_identical_to_golden():
    """The saved 4-device mixed compile (dot + psum + scan + DUS) costs
    exactly what the pre-refactor parser computed — the parser move into
    analysis/hlo.py changed imports, not numbers."""
    from repro.roofline.analysis import hlo_cost, roofline_terms
    with open(MIXED_GOLDEN, encoding="utf-8") as fh:
        golden = json.load(fh)
    cost = hlo_cost(_mixed_text())
    assert cost.flops == golden["flops"]
    assert cost.bytes_accessed == golden["bytes_accessed"]
    assert dict(cost.collective_bytes) == golden["collective_bytes"]
    terms = roofline_terms(cost, n_chips=4)
    assert terms == golden["terms"]


def test_roofline_reexports_shared_parser():
    """roofline.analysis re-exports the moved parser (back-compat)."""
    import repro.analysis.hlo as hlo
    import repro.roofline.analysis as ra
    assert ra.parse_hlo is hlo.parse_hlo
    assert ra.COLLECTIVES is hlo.COLLECTIVES


def test_parse_hlo_finds_entry_and_scan_trip_count():
    comps, entry = parse_hlo(_mixed_text())
    assert entry in comps
    assert any(i.op == "all-reduce" for c in comps.values()
               for i in c.instrs)


# ---------------------------------------------------------------------------
# collective census
# ---------------------------------------------------------------------------

def test_census_counts_planted_psum():
    census = collective_census(_mixed_text())
    assert census["all-reduce"].count == 1
    assert census["all-reduce"].bytes > 0


def test_census_clean_module_is_empty():
    text = jax.jit(lambda x: x @ x).lower(
        jnp.ones((8, 8))).compile().as_text()
    assert collective_census(text) == {}


def test_contract_catches_planted_psum():
    """collectives=0 must reject the module with the planted psum; the
    exact per-family count must accept it and reject a wrong family."""
    text = _mixed_text()
    viol = check_hlo(text, collectives=0, name="planted")
    assert viol and "all-reduce" in viol[0]
    assert check_hlo(text, collectives={"all-reduce": 1}) == []
    viol = check_hlo(text, collectives={"all-gather": 1})
    assert len(viol) == 2           # missing all-gather AND extra all-reduce
    with pytest.raises(ContractViolation):
        assert_contract(text, Contract(name="planted", collectives=0))


# ---------------------------------------------------------------------------
# donation audit
# ---------------------------------------------------------------------------

def test_donation_audit_accepts_real_aliasing():
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    x = jnp.ones((16, 16))
    assert check_lowered(f, x, con=Contract(name="ok", donated=(0,))) == []


def test_donation_audit_catches_dtype_mismatch():
    """Donating an f32 input to a program with only an int32 output: JAX
    drops the donation with a warning most callers never see — the audit
    reads the alias table and fails loudly."""
    f = jax.jit(lambda x: x.astype(jnp.int32), donate_argnums=(0,))
    x = jnp.ones((16, 16))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        compiled = f.lower(x).compile()
    assert parse_io_aliases(compiled.as_text()) == []
    viol = check_hlo(compiled.as_text(), donated=(0,), example_args=(x,),
                     name="dropped")
    assert viol and "not aliased" in viol[0]


def test_donation_audit_maps_pytree_args():
    """Donated pytree arg: every leaf must alias; one mismatched leaf in
    the donated tree is caught, leaves of undonated args are ignored."""
    def g(state, y):
        return {"a": state["a"] * 2, "b": state["b"].astype(jnp.int32)}, y

    f = jax.jit(g, donate_argnums=(0,))
    state = {"a": jnp.ones((4, 4)), "b": jnp.ones((3,))}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        compiled = f.lower(state, jnp.ones((2,))).compile()
    viol = audit_donation(compiled.as_text(), (0,),
                          example_args=(state, jnp.ones((2,))), name="tree")
    assert viol and "1/2" in viol[0]


def test_contract_decorator_attaches_metadata():
    @contract(collectives=0, donated=(1,), notes="n")
    def fn(a, b):
        return b

    con = contract_of(fn)
    assert con.collectives == 0 and con.donated == (1,)


# ---------------------------------------------------------------------------
# retrace guard
# ---------------------------------------------------------------------------

def test_retrace_guard_trips_on_shape_varying_loop():
    """A loop feeding growing shapes through a budget-1 jit is exactly
    the silent-recompile bug the guard exists for; strict mode (active
    under pytest) raises on the second trace."""
    reset_guards("t.shape_loop")
    g = guard_jit(lambda x: x * 2.0, name="t.shape_loop", max_traces=1)
    g(jnp.ones((4,)))
    assert compile_count("t.shape_loop") == 1
    with pytest.raises(RetraceViolation):
        g(jnp.ones((5,)))


def test_retrace_guard_cache_hits_are_free():
    reset_guards("t.stable")
    g = guard_jit(lambda x: x + 1.0, name="t.stable", max_traces=1)
    for _ in range(5):
        g(jnp.ones((8,)))
    assert compile_count("t.stable") == 1


def test_retrace_per_signature_allows_distinct_shapes():
    reset_guards("t.sweep")
    g = guard_jit(lambda x: x.sum(), name="t.sweep", per_signature=True)
    for n in (4, 8, 16):
        g(jnp.ones((n,)))
    assert compile_count("t.sweep") == 3


def test_retrace_per_signature_flags_repeat_trace():
    """jit never re-traces a cached signature, so the repeat branch is
    exercised on the record directly (it fires on cache thrash)."""
    rec = GuardRecord("t.thrash", per_signature=True)
    assert rec.note_trace(("sig",)) is None
    msg = rec.note_trace(("sig",))
    assert msg and "thrash" in msg


def test_runtime_decode_guard_is_registered():
    """The serve runtime's decode budget is declared where the jit is
    built — one compile per Runtime (the CLI gate asserts the count
    across a real mixed/staggered run)."""
    import inspect

    from repro.serve import runtime
    src = inspect.getsource(runtime.Runtime.__init__)
    assert 'name="serve.decode_step", max_traces=1' in src


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

_HOT_SYNC_SRC = '''
class Runtime:
    def step(self):
        toks = jax.device_get(self._decode(x))
        return toks
'''

_HOT_SYNC_PRAGMA_SRC = '''
class Runtime:
    def step(self):
        # comq: allow(host-sync) streaming tokens is a sync by design
        toks = jax.device_get(self._decode(x))
        return toks
'''


def test_lint_flags_host_sync_in_hot_zone():
    finds = lint_source(_HOT_SYNC_SRC, "serve/runtime.py")
    assert [f.rule for f in finds] == ["host-sync"]
    # same code outside a hot zone: silent
    assert lint_source(_HOT_SYNC_SRC, "serve/other.py") == []


def test_lint_pragma_waives_host_sync():
    assert lint_source(_HOT_SYNC_PRAGMA_SRC, "serve/runtime.py") == []


_TIME_IN_JIT_SRC = '''
import time, jax

def step(x):
    t0 = time.time()
    return x * t0

step_j = jax.jit(step)
lam = jax.jit(lambda x: x + time.perf_counter())
part = partial(jax.jit, static_argnames=("n",))(step)
'''


def test_lint_flags_time_in_jit():
    finds = lint_source(_TIME_IN_JIT_SRC, "core/whatever.py")
    rules = {f.rule for f in finds}
    assert rules == {"time-in-jit"}
    assert len(finds) == 2          # step body + the lambda


def test_lint_time_ok_outside_jit():
    src = "import time\n\ndef wall():\n    return time.time()\n"
    assert lint_source(src, "core/whatever.py") == []


_REPLACE_SRC = '''
import os

def publish(tmp, dst):
    os.replace(tmp, dst)

def publish_durable(tmp, dst, fh):
    os.fsync(fh.fileno())
    os.replace(tmp, dst)
'''


def test_lint_fsync_before_replace_scoped_to_durable_dirs():
    finds = lint_source(_REPLACE_SRC, "ft/journal.py")
    assert [f.rule for f in finds] == ["fsync-before-replace"]
    assert "publish" in finds[0].message
    # outside ft/ and ckpt/ the durability rule does not apply
    assert lint_source(_REPLACE_SRC, "serve/engine.py") == []


def test_lint_repo_tree_is_clean():
    """The shipped source passes its own gate (pragmas included)."""
    from repro.analysis.lint import lint_paths
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    finds = lint_paths([os.path.join(root, "src", "repro")], root=root)
    assert finds == [], [str(f) for f in finds]


# ---------------------------------------------------------------------------
# registry + CLI gate
# ---------------------------------------------------------------------------

def test_registry_solver_entry_passes():
    from repro.analysis.registry import ENTRIES
    assert ENTRIES["solver.comq_blocked"].run() == []


def test_registry_skips_dist_entries_without_devices():
    from repro.analysis.registry import run_gate
    results = {r.name: r for r in run_gate(["dist.solve", "dist.gram"])}
    for name, res in results.items():
        if jax.device_count() < 2:
            assert res.skipped and not res.violations
        else:
            assert res.ok, res.violations


def test_cli_retrace_smoke_one_decode_compile():
    """Acceptance: exactly one decode-step compile across a mixed-length,
    staggered serve run (the CLI gate's --retrace section)."""
    from repro.analysis.cli import run_retrace_smoke
    assert run_retrace_smoke(quiet=True) == 0
    assert compile_count("serve.decode_step") == 1
