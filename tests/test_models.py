"""Per-architecture smoke tests (deliverable f): reduced configs of every
assigned arch run one forward/train step on CPU, assert output shapes and
finiteness; plus decode-vs-forward consistency and causality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs, shapes_for, get_config
from repro.models import (BuildPlan, count_params, decode_step, forward,
                          init_cache, init_params, input_specs, lm_loss,
                          prefill)

PLAN = BuildPlan(remat=False)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, T=32):
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.cross_attn.n_vision_tokens,
                  cfg.cross_attn.vision_dim), jnp.bfloat16)
    if cfg.family == "encoder":
        batch = {"embeds": jax.random.normal(KEY, (B, 197, cfg.d_model),
                                             jnp.bfloat16),
                 "labels": jnp.zeros((B,), jnp.int32)}
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg, PLAN)
    batch = _batch(cfg)
    loss, metrics = lm_loss(params, cfg, PLAN, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # one gradient step must produce finite grads of the right structure
    grads = jax.grad(lambda p: lm_loss(p, cfg, PLAN, batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn), f"{arch}: non-finite grads"
    if cfg.family != "encoder":
        logits, aux, _ = forward(params, cfg, PLAN, batch["tokens"],
                                 vision_embeds=batch.get("vision_embeds"))
        assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_smoke_config(a).family != "encoder"])
def test_decode_matches_forward(arch):
    """prefill(T) + decode(T..T+2) logits must match the full forward pass —
    validates KV caches, ring buffers, SSM/RWKV state carries."""
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    if cfg.moe is not None:
        # capacity-based MoE drops depend on chunk composition; make the
        # smoke config drop-free so prefill/decode are exactly comparable
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=1.2 * cfg.moe.n_experts
            / max(cfg.moe.top_k, 1)))
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32,
                     prefill_cache_len=32)
    params = init_params(KEY, cfg, plan)
    B, T = 2, 24
    tokens = jax.random.randint(KEY, (B, T + 2), 0, cfg.vocab_size)
    ve = None
    if cfg.family == "vlm":
        ve = jax.random.normal(KEY, (B, cfg.cross_attn.n_vision_tokens,
                                     cfg.cross_attn.vision_dim), jnp.float32)
    full_logits, _, _ = forward(params, cfg, plan, tokens, vision_embeds=ve)

    last, cache = prefill(params, cfg, plan, tokens[:, :T], vision_embeds=ve)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, T - 1]),
                               rtol=2e-3, atol=2e-3)
    lg, cache = decode_step(params, cfg, plan, cache, tokens[:, T:T + 1],
                            jnp.int32(T))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, T]),
                               rtol=2e-3, atol=2e-3)
    lg, cache = decode_step(params, cfg, plan, cache, tokens[:, T + 1:T + 2],
                            jnp.int32(T + 1))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full_logits[:, T + 1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mistral-large-123b", "h2o-danube-1.8b",
                                  "rwkv6-7b", "hymba-1.5b"])
def test_causality(arch):
    """Changing future tokens must not change past logits."""
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    plan = BuildPlan(remat=False)
    params = init_params(KEY, cfg, plan)
    B, T = 1, 16
    t1 = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    t2 = t1.at[:, T - 1].set((t1[:, T - 1] + 7) % cfg.vocab_size)
    l1, _, _ = forward(params, cfg, plan, t1)
    l2, _, _ = forward(params, cfg, plan, t2)
    np.testing.assert_allclose(np.asarray(l1[:, : T - 1]),
                               np.asarray(l2[:, : T - 1]), rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, T - 1]), np.asarray(l2[:, T - 1]))


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_matches_analytic(arch):
    cfg = get_smoke_config(arch)
    n = count_params(cfg)
    assert n == cfg.param_count()
    if cfg.moe is not None:
        assert cfg.active_param_count() < n


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_shapes_and_specs(arch):
    """The FULL configs are only exercised via eval_shape (no allocation):
    params build, input specs exist for every runnable shape."""
    cfg = get_config(arch)
    plan = BuildPlan(tp=16)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg, plan),
                            jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(shapes))
    assert total > 0.5 * cfg.param_count()  # padding may add a few %
    for s in shapes_for(cfg):
        specs = input_specs(cfg, s, plan)
        assert "tokens" in specs or cfg.family == "encoder"


def test_sliding_window_restricts_attention():
    cfg = get_smoke_config("h2o-danube-1.8b").replace(
        compute_dtype="float32")
    plan = BuildPlan(remat=False)
    params = init_params(KEY, cfg, plan)
    B, T = 1, 64
    w = cfg.sliding_window
    assert w < T
    t1 = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    # change a token far outside the window of the last position
    t2 = t1.at[:, 0].set((t1[:, 0] + 3) % cfg.vocab_size)
    l1, _, _ = forward(params, cfg, plan, t1)
    l2, _, _ = forward(params, cfg, plan, t2)
    # last-position logits see only the last `w` tokens: token 0 is outside
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)
