"""Property-based tests (hypothesis) for COMQ invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import QuantSpec, comq_quantize, comq_quantize_h, gram
from repro.core.quantizer import (init_per_channel, pack_int4, quantize_rtn,
                                  unpack_int4)

_dims = st.tuples(st.integers(8, 48), st.integers(4, 24), st.integers(2, 6))


@settings(max_examples=12, deadline=None)
@given(_dims, st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 3, 4]))
def test_error_never_worse_than_rtn_on_same_grid(dims, seed, bits):
    """COMQ starts from the RTN grid init; coordinate descent + δ-updates
    can only improve the reconstruction error (monotone argmin steps)."""
    m, n, _ = dims
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed % (2 ** 31)))
    x = jax.random.normal(k1, (2 * m, m))
    w = jax.random.normal(k2, (m, n)) * 0.1
    spec = QuantSpec(bits=bits, granularity="per_channel", lam=1.0,
                     sweeps=3, order="greedy")
    r = comq_quantize(x, w, spec)
    delta, z_lo, z_hi = init_per_channel(w, bits, 1.0)
    rtn_w = quantize_rtn(w, delta, z_lo, z_hi).astype(jnp.float32) * delta
    e_rtn = float(jnp.linalg.norm(x @ (rtn_w - w)))
    e_comq = float(r.errors[-1])
    assert e_comq <= e_rtn * 1.001 + 1e-5


@settings(max_examples=12, deadline=None)
@given(_dims, st.integers(0, 2 ** 31 - 1),
       st.floats(0.25, 4.0, allow_nan=False))
def test_scale_equivariance(dims, seed, c):
    """COMQ(c·W) == c·COMQ(W) for per-channel grids (δ scales linearly,
    codes are identical)."""
    m, n, _ = dims
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed % (2 ** 31)))
    x = jax.random.normal(k1, (2 * m, m))
    w = jax.random.normal(k2, (m, n)) * 0.1
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    r1 = comq_quantize(x, w, spec)
    r2 = comq_quantize(x, w * c, spec)
    assert bool(jnp.all(r1.q == r2.q))
    np.testing.assert_allclose(np.asarray(r2.delta),
                               np.asarray(r1.delta) * c, rtol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 32), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(rows, halfcols, seed):
    rng = np.random.RandomState(seed % (2 ** 31))
    u = jnp.asarray(rng.randint(0, 16, size=(rows, 2 * halfcols)),
                    jnp.uint8)
    assert bool(jnp.all(unpack_int4(pack_int4(u)) == u))


@settings(max_examples=8, deadline=None)
@given(_dims, st.integers(0, 2 ** 31 - 1))
def test_permutation_invariance_of_objective(dims, seed):
    """Permuting input features (rows of W, correspondingly H) must not
    change the achieved reconstruction error for cyclic order solved in
    the permuted space."""
    m, n, _ = dims
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed % (2 ** 31)), 3)
    x = jax.random.normal(k1, (2 * m, m))
    w = jax.random.normal(k2, (m, n)) * 0.1
    perm = jax.random.permutation(k3, m)
    h = gram(x)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="greedy")
    r1 = comq_quantize_h(h, w, spec)
    r2 = comq_quantize_h(h[perm][:, perm], w[perm], spec)
    # greedy order is permutation-covariant => identical codes up to perm
    inv = jnp.argsort(perm)
    assert bool(jnp.all(r1.q == r2.q[inv]))
