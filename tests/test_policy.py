"""Per-leaf mixed-precision policy engine (core/policy.py): resolution
order, the budgeted backprop-free allocator, uniform-policy bit-identity
with the global-QuantSpec path, and mixed-bit packing/serving/ckpt."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (QuantPolicy, QuantSpec, allocate_bits, as_policy,
                        materialize, measure_bit_curves, parse_policy,
                        policy_from_budget, quantize_model, serving_params)
from repro.core.pipeline import is_qtensor, qtensor_bits
from repro.core.quantizer import (codes_per_byte, pack_codes, pack_int2,
                                  unpack_codes, unpack_int2)
from repro.models import BuildPlan, init_params

KEY = jax.random.PRNGKey(0)
PLAN = BuildPlan(remat=False)
SPEC = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                 order="greedy")


def _qtensor_leaves(table):
    out = {}
    for lkey, lp in table.items():
        for mod, leaves in lp.items():
            if not isinstance(leaves, dict):
                continue
            for leaf, v in leaves.items():
                if is_qtensor(v):
                    out[(lkey, mod, leaf)] = v
    return out


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------

def test_resolution_order_rules_then_overrides_then_base():
    pol = QuantPolicy(base=SPEC, rules=(("*.w_down", 8), ("2.attn.wq", 3)),
                      first_layer_bits=8, last_layer_bits=8)
    n = 6
    # pattern rules win over first/last overrides
    assert pol.resolve("mlp.w_down", 0, n).bits == 8
    assert pol.resolve("attn.wq", 2, n).bits == 3         # layer-qualified
    assert pol.resolve("attn.wq", 3, n).bits == 4         # base
    assert pol.resolve("attn.wq", 0, n).bits == 8         # first override
    assert pol.resolve("attn.wq", n - 1, n).bits == 8     # last override
    # only bits vary; everything else stays policy-wide
    r = pol.resolve("mlp.w_down", 3, n)
    assert (r.granularity, r.lam, r.sweeps, r.order) == \
        (SPEC.granularity, SPEC.lam, SPEC.sweeps, SPEC.order)


def test_first_rule_wins_and_uniform_detection():
    pol = QuantPolicy(base=SPEC, rules=(("mlp.*", 2), ("mlp.w_down", 8)))
    assert pol.resolve("mlp.w_down", 1, 4).bits == 2      # first match
    assert not pol.is_uniform()
    assert QuantPolicy(base=SPEC).is_uniform()
    assert as_policy(SPEC).resolve("attn.wq", 0, 4) == SPEC


def test_parse_policy_string():
    pol = parse_policy("*.w_down=8,first=8,last=8,kv=8,3.attn.wq=2", SPEC)
    assert ("*.w_down", 8) in pol.rules and ("3.attn.wq", 2) in pol.rules
    assert pol.first_layer_bits == 8 and pol.last_layer_bits == 8
    assert pol.kv_bits == 8
    with pytest.raises(ValueError):
        parse_policy("w_down", SPEC)


def test_policy_dict_roundtrip():
    from repro.core.policy import policy_from_dict, policy_to_dict
    pol = QuantPolicy(base=SPEC, rules=(("*.w_down", 8),),
                      first_layer_bits=8, kv_bits=8)
    assert policy_from_dict(policy_to_dict(pol)) == pol


# ---------------------------------------------------------------------------
# packing: int2 + bits-dispatched pack_codes
# ---------------------------------------------------------------------------

def test_pack_int2_roundtrip():
    u = jnp.asarray(np.random.RandomState(0).randint(0, 4, (16, 24)),
                    jnp.uint8)
    p = pack_int2(u)
    assert p.shape == (16, 6)
    assert bool(jnp.all(unpack_int2(p) == u))


def test_pack_codes_dispatch_and_alignment_fallback():
    rs = np.random.RandomState(1)
    assert (codes_per_byte(2), codes_per_byte(3), codes_per_byte(4),
            codes_per_byte(8)) == (4, 2, 2, 1)
    u = jnp.asarray(rs.randint(0, 4, (8, 16)), jnp.uint8)
    packed, cpb = pack_codes(u, 2)
    assert cpb == 4 and packed.shape == (8, 4)
    assert bool(jnp.all(unpack_codes(packed, cpb) == u))
    # 3-bit codes fit nibbles
    u3 = jnp.asarray(rs.randint(0, 8, (8, 16)), jnp.uint8)
    packed3, cpb3 = pack_codes(u3, 3)
    assert cpb3 == 2 and bool(jnp.all(unpack_codes(packed3, cpb3) == u3))
    # 8-bit passes through
    u8 = jnp.asarray(rs.randint(0, 256, (8, 16)), jnp.uint8)
    packed8, cpb8 = pack_codes(u8, 8)
    assert cpb8 == 1 and packed8 is not None
    assert bool(jnp.all(packed8 == u8))
    # misaligned last dim: stored unpacked rather than padded
    u_odd = jnp.asarray(rs.randint(0, 4, (8, 15)), jnp.uint8)
    _, cpb_odd = pack_codes(u_odd, 2)
    assert cpb_odd == 1


def test_quant_matmul_bits_dispatch_matches_ref():
    """ops.quant_matmul over every storage density vs the unpacked oracle
    (the 2-bit four-per-byte layout takes the documented XLA fallback)."""
    from repro.kernels import ops, ref
    rs = np.random.RandomState(2)
    x = jnp.asarray(rs.randn(8, 32), jnp.float32)
    for bits in (2, 3, 4, 8):
        u = jnp.asarray(rs.randint(0, 2 ** bits, (32, 16)), jnp.uint8)
        scale = jnp.asarray(rs.rand(16) * 0.1 + 0.01, jnp.float32)
        z = jnp.asarray(rs.randint(-2 ** (bits - 1), 0, (16,)), jnp.int32)
        want = ref.quant_matmul_ref(x, u, scale, z.astype(jnp.float32))
        packed, cpb = pack_codes(u, bits)
        got = ops.quant_matmul(x, packed, scale, z.astype(jnp.float32),
                               bits=bits, cpb=cpb, mode="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=str(bits))
        got_ref = ref.quant_matmul_packed_ref(x, packed, scale,
                                              z.astype(jnp.float32), cpb=cpb)
        np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def _toy_curves():
    # leaf "a" is twice as sensitive as "b"; "c" tiny but very sensitive
    curves = {
        "a": {2: 8.0, 3: 4.0, 4: 2.0, 8: 0.5},
        "b": {2: 4.0, 3: 2.0, 4: 1.0, 8: 0.25},
        "c": {2: 100.0, 3: 10.0, 4: 1.0, 8: 0.0},
    }
    sizes = {"a": 1000, "b": 1000, "c": 10}
    return curves, sizes


def test_allocator_budget_satisfaction_and_endpoints():
    curves, sizes = _toy_curves()
    from repro.core.policy import alloc_bits_per_param
    for budget in (2.0, 2.5, 3.0, 4.0, 5.5, 8.0, 16.0):
        alloc = allocate_bits(curves, sizes, budget)
        assert alloc_bits_per_param(alloc, sizes) <= budget + 1e-9
    # endpoints are satisfied exactly
    assert set(allocate_bits(curves, sizes, 2.0).values()) == {2}
    assert set(allocate_bits(curves, sizes, 8.0).values()) == {8}
    with pytest.raises(ValueError):
        allocate_bits(curves, sizes, 1.0)     # below the smallest choice


def test_allocator_monotone_error_in_budget():
    curves, sizes = _toy_curves()

    def total_err(alloc):
        return sum(curves[l][alloc[l]] for l in alloc)

    prev_err = float("inf")
    prev_alloc = None
    for budget in np.linspace(2.0, 8.0, 25):
        alloc = allocate_bits(curves, sizes, float(budget))
        err = total_err(alloc)
        assert err <= prev_err + 1e-12, (budget, err, prev_err)
        if prev_alloc is not None:     # allocations nest
            assert all(alloc[l] >= prev_alloc[l] for l in alloc)
        prev_err, prev_alloc = err, alloc


def test_allocator_spends_where_it_matters():
    """The tiny, hyper-sensitive leaf upgrades first (best err/bit·param);
    the least sensitive big leaf is the last to leave 2 bits."""
    curves, sizes = _toy_curves()
    alloc = allocate_bits(curves, sizes, 3.0)
    assert alloc["c"] == 8                      # ~nothing to spend, huge gain
    assert alloc["a"] >= alloc["b"]             # a is more sensitive


def test_allocator_handles_nonconvex_curves():
    """A curve whose 3→4 step gains more per bit than 2→3 must not strand
    the leaf at 2 bits (the convexified merged step applies atomically)."""
    curves = {"x": {2: 10.0, 3: 9.9, 4: 1.0, 8: 0.5}}
    sizes = {"x": 100}
    alloc = allocate_bits(curves, sizes, 4.0)
    assert alloc["x"] == 4


def test_measured_curves_monotone_and_allocator_integration():
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    base = dataclasses.replace(SPEC, sweeps=1, order="cyclic")
    curves, sizes = measure_bit_curves(params, cfg, PLAN, tokens, base)
    assert len(curves) == 7 * cfg.n_layers      # dense family leaf count
    for name, c in curves.items():
        assert c[2] >= c[3] >= c[4] >= c[8] >= 0.0, (name, c)
        assert sizes[name] > 0
    policy, alloc, _ = policy_from_budget(params, cfg, PLAN, tokens, base,
                                          4.0)
    from repro.core.policy import alloc_bits_per_param
    assert alloc_bits_per_param(alloc, sizes) <= 4.0 + 1e-9
    assert set(alloc) == set(curves)
    # the emitted policy reproduces the allocation exactly
    for name, bits in alloc.items():
        layer, leaf = name.split(".", 1)
        assert policy.resolve(leaf, int(layer), cfg.n_layers).bits == bits


# ---------------------------------------------------------------------------
# uniform-policy bit-identity with the global-QuantSpec path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m"])
def test_uniform_policy_bit_identical_to_spec(arch):
    """QuantPolicy(base=spec) with no rules must reproduce the global-spec
    pipeline exactly — codes, zero-points, scales, shapes — including the
    fused shared-tap solves the default greedy order triggers."""
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    qp_spec, _ = quantize_model(params, cfg, PLAN, tokens, SPEC)
    qp_pol, _ = quantize_model(params, cfg, PLAN, tokens,
                               QuantPolicy(base=SPEC))
    a = _qtensor_leaves(qp_spec["__qlayers__"])
    b = _qtensor_leaves(qp_pol["__qlayers__"])
    assert a.keys() == b.keys() and len(a) > 0
    for key in a:
        assert bool(jnp.all(a[key]["codes"] == b[key]["codes"])), key
        assert bool(jnp.all(a[key]["z_lo"] == b[key]["z_lo"])), key
        np.testing.assert_array_equal(np.asarray(a[key]["scale"]),
                                      np.asarray(b[key]["scale"]),
                                      err_msg=str(key))
        assert a[key]["shape"] == b[key]["shape"]
        assert qtensor_bits(a[key]) == qtensor_bits(b[key]) == SPEC.bits


# ---------------------------------------------------------------------------
# mixed-bit pipeline + packed serving + checkpoint round-trip
# ---------------------------------------------------------------------------

def _mixed_setup():
    cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32",
                                               n_layers=4)
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = init_params(KEY, cfg, plan)
    calib = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    base = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="cyclic")
    pol = QuantPolicy(base=base, rules=(("*.w_down", 8),),
                      first_layer_bits=8, last_layer_bits=8)
    qparams, _ = quantize_model(params, cfg, plan, calib, pol)
    return cfg, plan, params, qparams


def test_mixed_policy_assigns_per_leaf_bits():
    cfg, plan, _, qparams = _mixed_setup()
    leaves = _qtensor_leaves(qparams["__qlayers__"])
    bits = {k: qtensor_bits(v) for k, v in leaves.items()}
    assert bits[("0", "attn", "wq")] == 8          # first-layer override
    assert bits[("3", "mlp", "w_up")] == 8         # last-layer override
    assert bits[("1", "mlp", "w_down")] == 8       # pattern rule
    assert bits[("1", "attn", "wq")] == 4          # base
    # codes of the 8-bit leaves actually use the wider grid somewhere
    assert int(jnp.max(leaves[("1", "mlp", "w_down")]["codes"])) > 15


def test_mixed_serving_params_segments():
    from repro.core.apply import is_segmented
    cfg, plan, _, qparams = _mixed_setup()
    sp = serving_params(qparams, cfg)
    layers = sp["layers"]
    assert is_segmented(layers)
    assert sum(layers.sizes) == cfg.n_layers
    assert layers.sizes == (1, 2, 1)               # first | bulk | last
    # every segment's QT leaves are homogeneous and packed to their width
    from repro.core.apply import is_qt
    seg_bulk = layers.segments[1]
    wq = seg_bulk["attn"]["wq"]
    wd = seg_bulk["mlp"]["w_down"]
    assert is_qt(wq) and wq.bits == 4 and wq.cpb == 2
    assert is_qt(wd) and wd.bits == 8 and wd.cpb == 1
    first = layers.segments[0]["attn"]["wq"]
    assert first.bits == 8 and first.cpb == 1


def test_mixed_packed_serve_matches_materialized_tokens_and_logits():
    """Acceptance: a 4/8 mixed-policy model serves packed end-to-end (no
    materialize) with tokens identical to the materialized reference and
    matching logits."""
    from repro.serve import Runtime, ServeConfig
    cfg, plan, _, qparams = _mixed_setup()
    sp = serving_params(qparams, cfg)
    mat = materialize(qparams, cfg)

    def rt(p):
        return Runtime(p, cfg, plan,
                       ServeConfig(max_slots=2, block_size=8, num_blocks=16,
                                   buckets=(16,), max_blocks_per_slot=4))

    prompts = [np.asarray(jax.random.randint(KEY, (12,), 0,
                                             cfg.vocab_size)),
               np.asarray(jax.random.randint(jax.random.PRNGKey(7), (9,),
                                             0, cfg.vocab_size))]
    out_q = rt(sp).generate(prompts, max_new_tokens=8)
    out_m = rt(mat).generate(prompts, max_new_tokens=8)
    for a, b in zip(out_q, out_m):
        np.testing.assert_array_equal(a, b)

    from repro.models import decode_step, prefill
    plan2 = plan.replace(prefill_cache_len=20)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    lq, cq = prefill(sp, cfg, plan2, tokens)
    lm, cm = prefill(mat, cfg, plan2, tokens)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lm), atol=1e-5)
    gq, _ = decode_step(sp, cfg, plan2, cq, tokens[:, :1], jnp.int32(16))
    gm, _ = decode_step(mat, cfg, plan2, cm, tokens[:, :1], jnp.int32(16))
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gm), atol=1e-5)


def test_mixed_ckpt_roundtrip_preserves_bits_and_tokens():
    """pack -> strip -> unpack -> serve for a mixed 4/8 table: per-leaf
    pack densities round-trip and the served tokens match materialized."""
    from repro.ckpt import pack_tree, strip_for_serving, unpack_tree
    from repro.serve import Runtime, ServeConfig
    cfg, plan, _, qparams = _mixed_setup()
    packed = pack_tree(strip_for_serving(qparams))
    pl4 = packed["__qlayers__"]["1"]["attn"]["wq"]
    pl8 = packed["__qlayers__"]["1"]["mlp"]["w_down"]
    assert pl4["packed_cpb"] == 2 and "packed_cpb" not in pl8
    restored = unpack_tree(packed)
    a = _qtensor_leaves(qparams["__qlayers__"])
    b = _qtensor_leaves(restored["__qlayers__"])
    for key in a:
        assert bool(jnp.all(a[key]["codes"] == b[key]["codes"])), key
        assert qtensor_bits(a[key]) == qtensor_bits(b[key])

    sp = serving_params(restored, cfg)
    mat = materialize(qparams, cfg)
    prompts = [np.asarray(jax.random.randint(KEY, (10,), 0,
                                             cfg.vocab_size))]

    def rt(p):
        return Runtime(p, cfg, plan,
                       ServeConfig(max_slots=2, block_size=8, num_blocks=16,
                                   buckets=(16,), max_blocks_per_slot=4))

    out_a = rt(sp).generate(prompts, max_new_tokens=4)
    out_b = rt(mat).generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(out_a[0], out_b[0])


def test_policy_ckpt_metadata_roundtrip(tmp_path):
    from repro.ckpt import (CheckpointManager, pack_tree, policy_extra,
                            restore_policy, strip_for_serving, unpack_tree)
    cfg, plan, _, qparams = _mixed_setup()
    pol = QuantPolicy(base=SPEC, rules=(("*.w_down", 8),),
                      first_layer_bits=8)
    packed = pack_tree(strip_for_serving(qparams))
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(0, packed, extra=policy_extra(policy=pol, arch=cfg.name))
    restored, meta = mgr.restore(
        None, jax.tree_util.tree_map(lambda a: a, packed))
    assert meta["extra"]["arch"] == cfg.name
    assert restore_policy(meta["extra"]) == pol
    b = _qtensor_leaves(unpack_tree(restored)["__qlayers__"])
    a = _qtensor_leaves(qparams["__qlayers__"])
    for key in a:
        assert bool(jnp.all(a[key]["codes"] == b[key]["codes"])), key
