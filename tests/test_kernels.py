"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in kernels/ref.py (per assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.comq_panel import comq_panel_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("mkn", [(64, 256, 128), (128, 512, 128),
                                 (32, 128, 256)])
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_sweep(mkn, bits, xdtype):
    M, K, N = mkn
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, M + K + N + bits))
    x = jax.random.normal(k1, (M, K), xdtype)
    u = jax.random.randint(k2, (K, N), 0, 2 ** bits).astype(jnp.uint8)
    scale = jax.random.uniform(k1, (N,), jnp.float32, 0.01, 0.05)
    z = jax.random.randint(k2, (N,), -(2 ** (bits - 1)), 0).astype(jnp.int32)
    want = ref.quant_matmul_ref(x.astype(jnp.float32), u, scale, z)
    codes = u
    if bits == 4:
        from repro.core.quantizer import pack_int4
        codes = pack_int4(u)
    got = quant_matmul_pallas(x, codes, scale, z, bits=bits, bm=32, bn=64,
                              bk=128, interpret=True)
    rel = float(jnp.max(jnp.abs(got - want)) /
                (jnp.max(jnp.abs(want)) + 1e-9))
    assert rel < 3e-2, rel  # bf16 MXU accumulation tolerance


@pytest.mark.parametrize("bn", [(16, 32), (32, 64), (64, 96)])
def test_comq_panel_sweep(bn):
    B, n = bn
    ks = jax.random.split(jax.random.fold_in(KEY, B * n), 5)
    h = jax.random.normal(ks[0], (B, 4 * B))
    h_bb = h @ h.T / (4 * B) + jnp.eye(B) * 0.1
    s0 = jax.random.normal(ks[1], (B, n))
    qf = jax.random.normal(ks[2], (B, n)) * 3
    delta = jax.random.uniform(ks[3], (n,), minval=0.05, maxval=0.2)
    z_lo = jnp.full((n,), -8.0)
    z_hi = jnp.full((n,), 7.0)
    want = ref.comq_panel_ref(h_bb, s0, qf, delta, z_lo, z_hi,
                              jnp.diag(h_bb))
    got = comq_panel_pallas(h_bb, s0, qf, delta, z_lo, z_hi,
                            jnp.diag(h_bb), col_block=32, interpret=True)
    assert bool(jnp.all(want == got)), "panel kernel must be bit-exact"


@pytest.mark.parametrize("cfg", [
    dict(BH=4, BHkv=2, T=256, hd=64, causal=True, window=0),
    dict(BH=8, BHkv=8, T=128, hd=32, causal=True, window=0),
    dict(BH=4, BHkv=1, T=256, hd=64, causal=True, window=96),
    dict(BH=2, BHkv=2, T=128, hd=64, causal=False, window=0),
    dict(BH=6, BHkv=3, T=192, hd=16, causal=True, window=0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(cfg, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, cfg["BH"] * cfg["T"]
                                             + cfg["window"]), 3)
    q = jax.random.normal(ks[0], (cfg["BH"], cfg["T"], cfg["hd"]), dtype)
    k = jax.random.normal(ks[1], (cfg["BHkv"], cfg["T"], cfg["hd"]), dtype)
    v = jax.random.normal(ks[2], (cfg["BHkv"], cfg["T"], cfg["hd"]), dtype)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32),
                                   causal=cfg["causal"],
                                   window=cfg["window"])
    got = flash_attention_pallas(q, k, v, causal=cfg["causal"],
                                 window=cfg["window"], bq=64, bk=64,
                                 interpret=True)
    atol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=atol, rtol=atol)


def test_model_flash_matches_dense_reference():
    """The model's jnp pair-scan flash (models/attention.py) against the
    kernel oracle — same math, different schedule."""
    from repro.models.attention import flash_attention, head_to_kv_map
    B, T, H, KV, hd = 2, 128, 8, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, KV, hd))
    v = jax.random.normal(ks[2], (B, T, KV, hd))
    hmap = head_to_kv_map(H, H, KV)
    out = flash_attention(q, k, v, hmap, causal=True, window=0,
                          block_size=32)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, T, hd),
        k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd),
        v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd), causal=True)
    want = want.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_model_flash_sliding_window():
    from repro.models.attention import flash_attention, head_to_kv_map
    B, T, H, KV, hd, w = 1, 128, 4, 4, 16, 48
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, KV, hd))
    v = jax.random.normal(ks[2], (B, T, KV, hd))
    hmap = head_to_kv_map(H, H, KV)
    out = flash_attention(q, k, v, hmap, causal=True, window=w,
                          block_size=32)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, T, hd),
        k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd),
        v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd), causal=True,
        window=w)
    want = want.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
