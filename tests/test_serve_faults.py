"""Fault-tolerant serving (ft/journal.py, ft/inject.py, serve/runtime.py
recovery): crash-replay token identity, journal durability semantics,
deterministic fault injection, callback containment, packed-checkpoint
header validation. The oracle throughout is bit-determinism: a replayed
or resumed stream must equal the uninterrupted run token for token."""
import json
import os
import pickle
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import PackedCkptError, load_packed_ckpt, save_packed_ckpt
from repro.configs import get_smoke_config
from repro.ft import (FaultInjector, InjectedFault, Journal, JournalCorrupt,
                      SimulatedKill, run_with_restarts)
from repro.models import BuildPlan, init_params
from repro.serve import Runtime, ServeConfig, recover_runtime

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen2-7b"):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = init_params(KEY, cfg, plan)
    return cfg, plan, params


def _serve_cfg(**kw):
    sc = dict(max_slots=3, block_size=8, num_blocks=24, buckets=(8, 16, 32),
              max_blocks_per_slot=6)
    sc.update(kw)
    return ServeConfig(**sc)


def _prompts(n, rs=None, lo=6, hi=15):
    rs = rs or np.random.RandomState(23)
    cfg = get_smoke_config("qwen2-7b")
    return [rs.randint(0, cfg.vocab_size,
                       (int(l),)).astype(np.int32)
            for l in rs.randint(lo, hi, n)]


# ---------------------------------------------------------------------------
# journal unit tests (no model)
# ---------------------------------------------------------------------------

def _fake_req(rid, prompt=(1, 2, 3), seed=7, **kw):
    from repro.serve.scheduler import Request
    r = Request(prompt=np.asarray(prompt, np.int32), max_new_tokens=4,
                seed=seed, **kw)
    r.rid = rid
    return r


def test_journal_roundtrip_classifies_inflight(tmp_path):
    j = Journal(str(tmp_path))
    a, b = _fake_req(0), _fake_req(1, prompt=(9, 8), priority=2)
    j.record_submit(a)
    j.record_submit(b)
    j.record_first_token(a, 42)
    a.out_tokens = [42, 43]
    a.finish_reason = "length"
    j.record_retire(a)
    j.close()
    st = Journal.replay(str(tmp_path))
    assert set(st.completed) == {0} and set(st.inflight) == {1}
    assert st.completed_tokens(0) == [42, 43]
    assert st.first_tokens[0] == 42
    assert st.inflight[1]["priority"] == 2 and st.inflight[1]["seed"] == 7
    assert st.max_rid == 1


def test_journal_torn_tail_dropped_but_midfile_corruption_raises(tmp_path):
    j = Journal(str(tmp_path))
    j.record_submit(_fake_req(0))
    j.record_submit(_fake_req(1))
    j.close()
    path = os.path.join(str(tmp_path), "requests.jsonl")
    with open(path, "a") as f:
        f.write('{"ev": "retire", "rid": 1, "tok')    # crash mid-append
    st = Journal.replay(str(tmp_path))
    assert set(st.inflight) == {0, 1}    # torn retire never happened
    # the same damage NOT at the tail is corruption, not a torn write
    lines = open(path).read().splitlines()
    with open(path, "w") as f:
        f.write("\n".join([lines[0], lines[2], lines[1]]) + "\n")
    with pytest.raises(JournalCorrupt):
        Journal.replay(str(tmp_path))


def test_journal_reopen_truncates_torn_tail_before_append(tmp_path):
    """Recovery reopens the journal for append: a torn tail left by the
    crash must be truncated first, or the next record would merge with it
    into a corrupt *non*-tail line that poisons every later replay —
    breaking the crash-during-recovery convergence guarantee."""
    j = Journal(str(tmp_path))
    j.record_submit(_fake_req(0))
    j.close()
    path = os.path.join(str(tmp_path), "requests.jsonl")
    with open(path, "a") as f:
        f.write('{"ev": "retire", "rid": 0, "tok')   # crash mid-append
    j2 = Journal(str(tmp_path))                      # recovery generation
    r = _fake_req(0)
    r.out_tokens = [5]
    r.finish_reason = "length"
    j2.record_retire(r)
    j2.close()
    st = Journal.replay(str(tmp_path))               # no JournalCorrupt
    assert not st.inflight and st.completed_tokens(0) == [5]
    # a journal that is nothing but one torn line recovers to empty
    with open(path, "w") as f:
        f.write('{"ev": "sub')
    Journal(str(tmp_path)).close()
    assert not Journal.replay(str(tmp_path)).records


def test_journal_seq_monotonic_across_reopen(tmp_path):
    j = Journal(str(tmp_path))
    j.record_submit(_fake_req(0))
    j.record_submit(_fake_req(1))
    j.close()
    j2 = Journal(str(tmp_path))                      # recovery generation
    j2.record_submit(_fake_req(2))
    j2.close()
    seqs = [r["seq"] for r in Journal.replay(str(tmp_path)).records]
    assert seqs == [0, 1, 2]


def test_journal_crc_rejects_bitflip(tmp_path):
    j = Journal(str(tmp_path))
    j.record_submit(_fake_req(0))
    j.record_submit(_fake_req(1))
    j.close()
    path = os.path.join(str(tmp_path), "requests.jsonl")
    lines = open(path).read().splitlines()
    flipped = lines[0].replace('"rid": 0', '"rid": 5')
    with open(path, "w") as f:
        f.write("\n".join([flipped, lines[1]]) + "\n")
    with pytest.raises(JournalCorrupt):
        Journal.replay(str(tmp_path))


def test_journal_dedup_submit_and_last_retire_wins(tmp_path):
    """Recovery appends to the same journal: duplicate submits (original +
    replayed run) must collapse, and a crash *during* recovery converges."""
    j = Journal(str(tmp_path))
    r = _fake_req(0)
    j.record_submit(r)
    j.record_submit(r)                   # replayed run re-records
    r.out_tokens = [1]
    r.finish_reason = "length"
    j.record_retire(r)
    r.out_tokens = [1, 2]
    j.record_retire(r)                   # later retire supersedes
    j.close()
    st = Journal.replay(str(tmp_path))
    assert not st.inflight and st.completed_tokens(0) == [1, 2]


def test_fault_injector_schedule_and_parse():
    inj = FaultInjector.parse("page_alloc:2+4,kill:3")
    hits = [inj.fire("page_alloc") for _ in range(5)]
    assert hits == [False, True, False, True, False]
    assert not inj.fire("decode_step")   # unscheduled point never fires
    with pytest.raises(SimulatedKill):
        for _ in range(3):
            inj.check("kill", SimulatedKill)
    assert inj.fired == [("page_alloc", 2), ("page_alloc", 4), ("kill", 3)]
    # seeded random schedules are reproducible
    a = FaultInjector.random(0, {"x": 0.3}, horizon=50).schedule
    b = FaultInjector.random(0, {"x": 0.3}, horizon=50).schedule
    assert a == b and a["x"]
    # a typo'd point name fails loudly instead of silently never firing
    with pytest.raises(ValueError, match="decode-step"):
        FaultInjector.parse("decode-step:3")


# ---------------------------------------------------------------------------
# crash -> recover_runtime replay
# ---------------------------------------------------------------------------

def test_crash_replay_token_identity(tmp_path):
    """Kill the runtime mid-decode; recovery must finish every in-flight
    request with tokens identical to the uninterrupted run — none lost,
    none duplicated."""
    cfg, plan, params = _setup()
    prompts = _prompts(3)
    solo = Runtime(params, cfg, plan, _serve_cfg()).generate(
        prompts, max_new_tokens=8)

    inj = FaultInjector({"kill": {4}})
    rt = Runtime(params, cfg, plan, _serve_cfg(),
                 journal=Journal(str(tmp_path)), injector=inj)
    reqs = [rt.submit(p, max_new_tokens=8) for p in prompts]
    with pytest.raises(SimulatedKill):
        rt.run()
    partial = [list(r.out_tokens) for r in reqs]
    assert any(0 < len(t) < 8 for t in partial)     # genuinely mid-flight

    rt2, st = recover_runtime(params, cfg, plan, str(tmp_path), _serve_cfg())
    assert set(st.inflight) == {r.rid for r in reqs}
    assert not st.completed
    replayed = {r.rid: r for r in rt2.scheduler.queue}
    assert sorted(replayed) == sorted(r.rid for r in reqs)  # no dup/loss
    rt2.run()
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(
            np.asarray(replayed[r.rid].out_tokens), want)
    # and the post-recovery journal marks everything retired
    final = Journal.replay(str(tmp_path))
    assert not final.inflight and set(final.completed) == set(replayed)


def test_crash_replay_skips_retired_requests(tmp_path):
    """Requests retired before the crash are not re-run: their tokens come
    from the journal, and recovery only replays the true in-flight set."""
    cfg, plan, params = _setup()
    short = np.arange(5, dtype=np.int32)
    long_ = _prompts(1)[0]
    rt = Runtime(params, cfg, plan, _serve_cfg(),
                 journal=Journal(str(tmp_path)),
                 injector=FaultInjector({"kill": {6}}))
    r_short = rt.submit(short, max_new_tokens=2)    # retires early
    r_long = rt.submit(long_, max_new_tokens=12)
    with pytest.raises(SimulatedKill):
        rt.run()
    assert r_short.state == "done"
    rt2, st = recover_runtime(params, cfg, plan, str(tmp_path), _serve_cfg())
    assert set(st.completed) == {r_short.rid}
    assert st.completed_tokens(r_short.rid) == r_short.out_tokens
    assert set(st.inflight) == {r_long.rid}
    rt2.run()
    solo = Runtime(params, cfg, plan, _serve_cfg()).generate(
        [long_], max_new_tokens=12)[0]
    got = rt2.scheduler.completed[-1]
    np.testing.assert_array_equal(np.asarray(got.out_tokens), solo)


def test_double_crash_recovery_converges(tmp_path):
    """Crash during recovery: a second recovery still loses nothing and
    the final streams match the uninterrupted run."""
    cfg, plan, params = _setup()
    prompts = _prompts(2)
    solo = Runtime(params, cfg, plan, _serve_cfg()).generate(
        prompts, max_new_tokens=8)
    rt = Runtime(params, cfg, plan, _serve_cfg(),
                 journal=Journal(str(tmp_path)),
                 injector=FaultInjector({"kill": {3}}))
    rids = [rt.submit(p, max_new_tokens=8).rid for p in prompts]
    with pytest.raises(SimulatedKill):
        rt.run()
    rt2, _ = recover_runtime(params, cfg, plan, str(tmp_path), _serve_cfg(),
                             injector=FaultInjector({"kill": {2}}))
    with pytest.raises(SimulatedKill):
        rt2.run()
    rt3, st = recover_runtime(params, cfg, plan, str(tmp_path), _serve_cfg())
    assert sorted(st.inflight) == sorted(rids)      # still exactly once
    rt3.run()
    done = {r.rid: r for r in rt3.scheduler.completed}
    for rid, want in zip(rids, solo):
        np.testing.assert_array_equal(np.asarray(done[rid].out_tokens), want)


def test_supervised_drain_with_restarts(tmp_path):
    """The launch-style supervisor loop: run_with_restarts + journal
    recovery drains through injected kills, with the retired count as the
    forward-progress signal."""
    cfg, plan, params = _setup()
    prompts = _prompts(3)
    solo = Runtime(params, cfg, plan, _serve_cfg()).generate(
        prompts, max_new_tokens=6)
    inj = FaultInjector({"kill": {2, 7}})           # two separate crashes
    state = {"first": True}

    def attempt(_):
        if state["first"]:
            state["first"] = False
            rt = Runtime(params, cfg, plan, _serve_cfg(),
                         journal=Journal(str(tmp_path)), injector=inj)
            for p in prompts:
                rt.submit(p, max_new_tokens=6)
        else:
            rt, _ = recover_runtime(params, cfg, plan, str(tmp_path),
                                    _serve_cfg(), injector=inj)
        rt.run()
        return rt

    def progress():
        return len(Journal.replay(str(tmp_path)).completed)

    rt = run_with_restarts(attempt, progress, max_restarts=2,
                           exceptions=(SimulatedKill,))
    st = Journal.replay(str(tmp_path))
    assert not st.inflight and len(st.completed) == 3
    assert len(inj.fired) == 2
    for rid, want in enumerate(solo):
        assert st.completed_tokens(rid) == list(want)
    assert rt.allocator.num_free == rt.allocator.num_blocks


def test_launcher_restart_covers_crash_during_staggered_build(
        tmp_path, monkeypatch, capsys):
    """A kill injected while the launcher's build() is still submitting
    (staggered arrivals) must restart in resume mode: journaled submits
    replay under their original rids and the never-journaled prompts are
    re-submitted — rather than rebuilding fresh and appending
    duplicate-rid submit records that conflate distinct requests."""
    from repro.launch import serve as launch_serve
    monkeypatch.setattr("sys.argv", [
        "serve", "--arch", "qwen2-7b", "--smoke",
        "--num-requests", "3", "--stagger", "1",
        "--prompt-len", "8", "--max-new", "4",
        "--journal", str(tmp_path), "--inject", "kill:1",
        "--restarts", "2"])
    launch_serve.main()                 # kill fires on build()'s rt.step()
    metrics = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert metrics["faults_fired"] == [["kill", 1]] or \
        metrics["faults_fired"] == [("kill", 1)]
    assert len(metrics["prompt_lens"]) == 3
    st = Journal.replay(str(tmp_path))
    assert not st.inflight and sorted(st.completed) == [0, 1, 2]


# ---------------------------------------------------------------------------
# in-process fault points
# ---------------------------------------------------------------------------

def test_decode_fault_retries_without_losing_requests():
    """A transient decode-step exception (caught by the caller's
    supervisor) must not corrupt scheduler or allocator state: a fresh
    run() call finishes everything token-identically."""
    cfg, plan, params = _setup()
    prompts = _prompts(2)
    solo = Runtime(params, cfg, plan, _serve_cfg()).generate(
        prompts, max_new_tokens=6)
    rt = Runtime(params, cfg, plan, _serve_cfg(),
                 injector=FaultInjector({"decode_step": {2}}))
    reqs = [rt.submit(p, max_new_tokens=6) for p in prompts]
    with pytest.raises(InjectedFault):
        rt.run()
    rt.allocator.check_integrity()      # fault left no leak behind
    rt.run()                            # in-process retry: state is intact
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
    assert rt.allocator.num_free == rt.allocator.num_blocks


def test_callback_fault_contained_per_request():
    """An injected stream-callback crash is recorded on the offending
    request and must not perturb any stream's tokens."""
    cfg, plan, params = _setup()
    prompts = _prompts(2)
    solo = Runtime(params, cfg, plan, _serve_cfg()).generate(
        prompts, max_new_tokens=6)
    rt = Runtime(params, cfg, plan, _serve_cfg(),
                 injector=FaultInjector({"callback": {2}}))
    seen = []
    reqs = [rt.submit(p, max_new_tokens=6,
                      stream_cb=lambda r, t: seen.append((r.rid, t)))
            for p in prompts]
    rt.run()
    errs = [e for r in reqs for e in r.cb_errors]
    assert len(errs) == 1 and isinstance(errs[0], InjectedFault)
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
    # every emitted token except the swallowed callback call was streamed
    assert len(seen) == sum(len(r.out_tokens) for r in reqs) - 1


def test_seeded_sampling_identical_after_preemption(tmp_path):
    """Temperature>0: per-request seeded sampling is a pure function of
    (seed, token index), so even a preempted+resumed stochastic stream
    matches its solo run draw for draw."""
    cfg, plan, params = _setup()
    prompts = _prompts(3, lo=9, hi=15)
    kw = dict(max_new_tokens=8, temperature=0.8, top_k=5)
    solo = []
    for i, p in enumerate(prompts):
        rt = Runtime(params, cfg, plan, _serve_cfg())
        solo.append(np.asarray(
            rt.generate([p], seed=100 + i, **kw)[0]))
    rt = Runtime(params, cfg, plan, _serve_cfg(num_blocks=6))
    reqs = [rt.submit(p, seed=100 + i, **kw)
            for i, p in enumerate(prompts)]
    rt.run()
    assert rt.scheduler.preemptions > 0
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), want)


# ---------------------------------------------------------------------------
# packed checkpoint header (launch --save/--load-quantized)
# ---------------------------------------------------------------------------

def test_packed_ckpt_roundtrip_and_meta(tmp_path):
    path = str(tmp_path / "q.pkl")
    tree = {"w": np.arange(6, dtype=np.int8).reshape(2, 3)}
    save_packed_ckpt(path, tree, bits=4, arch="qwen2-7b-smoke")
    blob = load_packed_ckpt(path)
    assert blob["bits"] == 4 and blob["arch"] == "qwen2-7b-smoke"
    np.testing.assert_array_equal(blob["tree"]["w"], tree["w"])


def test_packed_ckpt_truncation_fails_clearly(tmp_path):
    path = str(tmp_path / "q.pkl")
    save_packed_ckpt(path, {"w": np.zeros(64)}, bits=4)
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[: len(data) // 2])
    with pytest.raises(PackedCkptError, match="truncated|corrupt"):
        load_packed_ckpt(path)


def test_packed_ckpt_checksum_catches_corruption(tmp_path):
    path = str(tmp_path / "q.pkl")
    save_packed_ckpt(path, {"w": np.zeros(64, np.uint8)}, bits=4)
    data = bytearray(open(path, "rb").read())
    data[-20] ^= 0xFF                   # bitflip inside the payload
    with open(path, "wb") as f:
        f.write(bytes(data))
    with pytest.raises(PackedCkptError,
                       match="checksum mismatch|truncated or corrupt"):
        load_packed_ckpt(path)


def test_packed_ckpt_wrong_format_and_version(tmp_path):
    path = str(tmp_path / "q.pkl")
    payload = pickle.dumps({"tree": {}})
    with open(path, "wb") as f:
        pickle.dump({"format": "other", "version": 1,
                     "crc32": zlib.crc32(payload), "payload": payload}, f)
    with pytest.raises(PackedCkptError, match="format"):
        load_packed_ckpt(path)
    with open(path, "wb") as f:
        pickle.dump({"format": "comq-packed-qt", "version": 99,
                     "crc32": zlib.crc32(payload), "payload": payload}, f)
    with pytest.raises(PackedCkptError, match="newer"):
        load_packed_ckpt(path)


def test_packed_ckpt_legacy_headerless_loads_with_warning(tmp_path):
    """Pre-header files (a bare pickled dict, what PR 4's launcher wrote)
    still load — back-compat — but warn that there is no checksum."""
    path = str(tmp_path / "legacy.pkl")
    legacy = {"tree": {"w": np.ones(3)}, "bits": 4, "arch": "x"}
    with open(path, "wb") as f:
        pickle.dump(legacy, f)
    with pytest.warns(UserWarning, match="legacy headerless"):
        blob = load_packed_ckpt(path)
    assert blob["bits"] == 4
    np.testing.assert_array_equal(blob["tree"]["w"], legacy["tree"]["w"])
    # garbage that is neither headered nor legacy fails loudly
    with open(path, "wb") as f:
        pickle.dump({"something": 1}, f)
    with pytest.raises(PackedCkptError, match="neither"):
        load_packed_ckpt(path)
