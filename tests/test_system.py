"""End-to-end system behaviour: the full COMQ workflow — train a small
model on the structured stream, quantize it with COMQ, verify the
quantized model retains the learned behaviour better than RTN at 3 bits
(the paper's central claim transplanted to this stack)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core import QuantSpec, materialize, quantize_model
from repro.data import SyntheticLM
from repro.models import BuildPlan, lm_loss
from repro.train.trainer import Trainer


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    cfg = get_smoke_config("h2o-danube-1.8b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="h2o-danube-1.8b",
                        ckpt_dir=str(tmp_path_factory.mktemp("ck")),
                        ckpt_every=1000, total_steps=60, learning_rate=3e-3,
                        warmup_steps=5, async_ckpt=False)
    t = Trainer(cfg, plan, run_cfg)
    out = t.run_loop(total_steps=60, seq_len=64, global_batch=8)
    return cfg, plan, out["state"]["params"], out["metrics"]


def _eval_loss(params, cfg, plan, seed=123):
    data = SyntheticLM(cfg.vocab_size, seed=0).sample(8, 64, step=9999)
    batch = {"tokens": jnp.asarray(data["tokens"]),
             "labels": jnp.asarray(data["labels"])}
    return float(lm_loss(params, cfg, plan, batch)[0])


def test_training_learned_structure(trained):
    cfg, plan, params, metrics = trained
    assert metrics[-1]["loss"] < metrics[0]["loss"] - 0.8


def test_comq_beats_rtn_on_trained_model(trained):
    """Paper Tab. 3/4 analogue: at 3 bits, COMQ preserves the trained
    model's eval loss better than RTN on the identical grid."""
    cfg, plan, params, _ = trained
    calib = jnp.asarray(SyntheticLM(cfg.vocab_size, seed=0)
                        .sample(8, 64, step=5000)["tokens"])
    base = _eval_loss(params, cfg, plan)
    losses = {}
    for method in ("comq", "rtn"):
        spec = QuantSpec(bits=3, granularity="per_channel", lam=0.9,
                         sweeps=3, order="greedy")
        qp, _ = quantize_model(params, cfg, plan, calib, spec, method=method)
        losses[method] = _eval_loss(materialize(qp, cfg), cfg, plan)
    assert losses["comq"] <= losses["rtn"] + 1e-4, (base, losses)
    # and COMQ's degradation from fp is bounded
    assert losses["comq"] - base < 1.0, (base, losses)


def test_quantize_then_serve_roundtrip(trained):
    from repro.serve.engine import Engine
    cfg, plan, params, _ = trained
    calib = jnp.asarray(SyntheticLM(cfg.vocab_size, seed=0)
                        .sample(4, 64, step=77)["tokens"])
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="greedy")
    qp, _ = quantize_model(params, cfg, plan, calib, spec)
    eng = Engine(materialize(qp, cfg), cfg, plan)
    prompts = np.asarray(calib[:2, :32])
    out = eng.generate_batch(prompts, max_new_tokens=8)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
