"""Roofline HLO parser: trip-count handling validated against unrolled
references; collective-byte counting on a sharded compile (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (hlo_cost, parse_hlo, roofline_terms,
                                     CostTotals)


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_match_unrolled():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def f_scan(x):
        return jax.lax.scan(body, x, None, length=10)[0]

    def f_unroll(x):
        for _ in range(10):
            x, _ = body(x, None)
        return x

    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cs = hlo_cost(_compile_text(f_scan, spec))
    cu = hlo_cost(_compile_text(f_unroll, spec))
    expected = 10 * 2 * 128 ** 3
    assert 0.9 < cs.flops / cu.flops < 1.1
    assert 0.9 < cs.flops / expected < 1.15


def test_nested_scan_trip_multiplication():
    def inner(c, _):
        return jnp.tanh(c @ c), None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=4)
        return c, None

    def f(x):
        return jax.lax.scan(outer, x, None, length=3)[0]

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = hlo_cost(_compile_text(f, spec))
    expected = 3 * 4 * 2 * 64 ** 3
    assert 0.9 < c.flops / expected < 1.2, c.flops / expected


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = hlo_cost(_compile_text(f, a, b))
    expected = 2 * 4 * 32 * 64 * 16
    assert 0.9 < c.flops / expected < 1.2


def test_roofline_terms_math():
    c = CostTotals(flops=197e12, bytes_accessed=819e9,
                   collective_bytes={"all-gather": 200e9})
    t = roofline_terms(c, n_chips=256)
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 1.0) < 1e-6
    assert abs(t["collective_s"] - 1.0) < 1e-6
    assert t["dominant"] in ("compute", "memory", "collective")


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.roofline.analysis import hlo_cost
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", "model")),
                                     NamedSharding(mesh, P("model", None))),
                    out_shardings=NamedSharding(mesh, P("data", None))
                    ).lower(a, b).compile()
    cost = hlo_cost(c.as_text())
    print(json.dumps({"flops": cost.flops,
                      "coll": cost.collective_bytes}))
""")


def test_collective_bytes_on_sharded_compile():
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC % src_dir],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    # per-device dot: (128, 64) @ (64, 256) = 2*128*64*256
    assert 0.9 < data["flops"] / (2 * 128 * 64 * 256) < 1.3
    # contraction over the sharded dim => all-reduce of the (128, 256) out
    assert "all-reduce" in data["coll"]
    assert data["coll"]["all-reduce"] >= 128 * 256 * 4


# ---------------------------------------------------------------------------
# bytes-per-decode-token model (roofline/kv_bytes.py, DESIGN.md §11.4)
# ---------------------------------------------------------------------------

def test_kv_bytes_model_terms():
    from repro.configs import get_smoke_config
    from repro.models import BuildPlan
    from repro.roofline.kv_bytes import decode_kv_bytes, pool_elem_bytes
    cfg = get_smoke_config("qwen2-7b")
    plan_b = BuildPlan(remat=False, cache_dtype=jnp.float32)
    plan_q = plan_b.replace(kv_bits=8)
    assert pool_elem_bytes(plan_b) == 4.0
    assert pool_elem_bytes(plan_q) == 1.0
    assert pool_elem_bytes(plan_b.replace(kv_bits=4)) == 0.5
    kw = dict(max_slots=4, block_size=16, max_blocks_per_slot=8,
              num_blocks=32)
    for mode in ("xla", "pallas"):
        b = decode_kv_bytes(cfg, plan_b, mode=mode, **kw)
        q = decode_kv_bytes(cfg, plan_q, mode=mode, **kw)
        # quantized codes are exactly storage-ratio smaller; scales only
        # exist on the quantized side and stay a small fraction of codes
        assert q["codes"] == b["codes"] / 4.0
        assert b["scales"] == 0.0 and 0 < q["scales"] < 0.2 * q["codes"]
        assert q["kv_total"] < b["kv_total"]
    # pallas mode bounds live pages by live_tokens
    short = decode_kv_bytes(cfg, plan_q, mode="pallas", live_tokens=16, **kw)
    full = decode_kv_bytes(cfg, plan_q, mode="pallas", **kw)
    assert short["codes"] == full["codes"] / 8   # 1 of 8 pages live
    # xla mode needs the scatter output extent
    with pytest.raises(ValueError):
        decode_kv_bytes(cfg, plan_q, max_slots=4, block_size=16,
                        max_blocks_per_slot=8, mode="xla")


def test_kv_bytes_step_totals_and_weights():
    from repro.configs import get_smoke_config
    from repro.models import BuildPlan, count_params
    from repro.roofline.kv_bytes import (decode_step_bytes,
                                         weight_stream_bytes)
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["init_params"])
        .init_params(jax.random.PRNGKey(0), cfg, plan))
    w = weight_stream_bytes(params)
    assert w == 4 * count_params(cfg, plan)      # f32 master weights
    out = decode_step_bytes(params, cfg, plan, max_slots=4, block_size=16,
                            max_blocks_per_slot=8, num_blocks=32)
    assert out["total"] == pytest.approx(
        w + out["kv_total"] + out["logits"])
    assert out["per_token"] == pytest.approx(out["total"] / 4)
