"""Whole-model COMQ pipeline: quality vs RTN, loss preservation, quantized
serving, and the distributed-solve column independence property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (QuantSpec, comq_quantize_h, gram, materialize,
                        quantize_model)
from repro.core.pipeline import dequant_qtensor, is_qtensor
from repro.models import BuildPlan, init_params, lm_loss

PLAN = BuildPlan(remat=False)
KEY = jax.random.PRNGKey(0)
SPEC = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                 order="greedy")


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m",
                                  "rwkv6-7b", "hymba-1.5b"])
def test_pipeline_improves_over_rtn_and_preserves_loss(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    qparams, report = quantize_model(params, cfg, PLAN, tokens, SPEC)
    assert report.total_improvement() > 0.05, \
        f"COMQ should beat RTN reconstruction, got {report.total_improvement()}"
    mat = materialize(qparams, cfg)
    batch = {"tokens": tokens, "labels": tokens}
    fp = float(lm_loss(params, cfg, PLAN, batch)[0])
    q4 = float(lm_loss(mat, cfg, PLAN, batch)[0])
    assert abs(q4 - fp) < 0.35, (fp, q4)


def test_bits_sweep_orders_errors():
    """Lower bit-width => higher reconstruction error (2 > 3 > 4 bits),
    the paper's central quality axis (Tab. 1)."""
    cfg = get_smoke_config("mistral-large-123b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    errs = {}
    for bits in (2, 3, 4):
        spec = QuantSpec(bits=bits, granularity="per_channel", lam=0.9,
                         sweeps=2, order="greedy")
        _, rep = quantize_model(params, cfg, PLAN, tokens, spec)
        errs[bits] = sum(r.err_after for r in rep.layers)
    assert errs[2] > errs[3] > errs[4], errs


def test_quantized_serving_consistency():
    """Greedy decode from materialized quantized params stays close to fp:
    same ranking on most positions at 8-bit."""
    from repro.serve.engine import Engine
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)
    spec = QuantSpec(bits=8, granularity="per_channel", lam=1.0, sweeps=2,
                     order="greedy")
    qparams, _ = quantize_model(params, cfg, PLAN, tokens, spec)
    mat = materialize(qparams, cfg)
    e_fp = Engine(params, cfg, PLAN)
    e_q = Engine(mat, cfg, PLAN)
    prompts = np.asarray(tokens[:, :32])
    out_fp = e_fp.generate_batch(prompts, max_new_tokens=8)
    out_q = e_q.generate_batch(prompts, max_new_tokens=8)
    agree = float((out_fp == out_q).mean())
    assert agree >= 0.5, f"8-bit greedy decode agreement {agree}"


def test_qtensor_leaves_and_dequant_shapes():
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    qparams, _ = quantize_model(params, cfg, PLAN, tokens, SPEC)
    table = qparams["__qlayers__"]
    assert len(table) == cfg.n_layers
    lp0 = table["0"]
    qt = lp0["attn"]["wq"]
    assert is_qtensor(qt)
    assert qt["codes"].dtype == jnp.uint8
    deq = dequant_qtensor(qt)
    assert deq.shape == params["layers"]["attn"]["wq"].shape[1:]


def test_column_independence_enables_sharded_solve():
    """Per-channel COMQ on a column subset equals those columns of the full
    solve — the property that lets the launcher shard columns across the
    mesh with zero solve-time communication (DESIGN.md §4)."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (128, 48))
    w = jax.random.normal(k2, (48, 32)) * 0.1
    h = gram(x)
    full = comq_quantize_h(h, w, SPEC)
    half = comq_quantize_h(h, w[:, :16], SPEC)
    assert bool(jnp.all(full.q[:, :16] == half.q))
    np.testing.assert_allclose(np.asarray(full.delta[:16]),
                               np.asarray(half.delta), rtol=1e-6)
