"""Whole-model COMQ pipeline: quality vs RTN, loss preservation, quantized
serving, and the distributed-solve column independence property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (QuantSpec, comq_quantize_h, gram, materialize,
                        quantize_model)
from repro.core.pipeline import dequant_qtensor, is_qtensor
from repro.models import BuildPlan, init_params, lm_loss

PLAN = BuildPlan(remat=False)
KEY = jax.random.PRNGKey(0)
SPEC = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                 order="greedy")


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m",
                                  "rwkv6-7b", "hymba-1.5b"])
def test_pipeline_improves_over_rtn_and_preserves_loss(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    qparams, report = quantize_model(params, cfg, PLAN, tokens, SPEC)
    assert report.total_improvement() > 0.05, \
        f"COMQ should beat RTN reconstruction, got {report.total_improvement()}"
    mat = materialize(qparams, cfg)
    batch = {"tokens": tokens, "labels": tokens}
    fp = float(lm_loss(params, cfg, PLAN, batch)[0])
    q4 = float(lm_loss(mat, cfg, PLAN, batch)[0])
    assert abs(q4 - fp) < 0.35, (fp, q4)


def test_bits_sweep_orders_errors():
    """Lower bit-width => higher reconstruction error (2 > 3 > 4 bits),
    the paper's central quality axis (Tab. 1)."""
    cfg = get_smoke_config("mistral-large-123b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    errs = {}
    for bits in (2, 3, 4):
        spec = QuantSpec(bits=bits, granularity="per_channel", lam=0.9,
                         sweeps=2, order="greedy")
        _, rep = quantize_model(params, cfg, PLAN, tokens, spec)
        errs[bits] = sum(r.err_after for r in rep.layers)
    assert errs[2] > errs[3] > errs[4], errs


def test_quantized_serving_consistency():
    """Greedy decode from materialized quantized params stays close to fp:
    same ranking on most positions at 8-bit."""
    from repro.serve.engine import Engine
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)
    spec = QuantSpec(bits=8, granularity="per_channel", lam=1.0, sweeps=2,
                     order="greedy")
    qparams, _ = quantize_model(params, cfg, PLAN, tokens, spec)
    mat = materialize(qparams, cfg)
    e_fp = Engine(params, cfg, PLAN)
    e_q = Engine(mat, cfg, PLAN)
    prompts = np.asarray(tokens[:, :32])
    out_fp = e_fp.generate_batch(prompts, max_new_tokens=8)
    out_q = e_q.generate_batch(prompts, max_new_tokens=8)
    agree = float((out_fp == out_q).mean())
    assert agree >= 0.5, f"8-bit greedy decode agreement {agree}"


def test_qtensor_leaves_and_dequant_shapes():
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    qparams, _ = quantize_model(params, cfg, PLAN, tokens, SPEC)
    table = qparams["__qlayers__"]
    assert len(table) == cfg.n_layers
    lp0 = table["0"]
    qt = lp0["attn"]["wq"]
    assert is_qtensor(qt)
    assert qt["codes"].dtype == jnp.uint8
    deq = dequant_qtensor(qt)
    assert deq.shape == params["layers"]["attn"]["wq"].shape[1:]


def _qtensor_leaves(table):
    out = {}
    for lkey, lp in table.items():
        for mod, leaves in lp.items():
            if not isinstance(leaves, dict):
                continue
            for leaf, v in leaves.items():
                if is_qtensor(v):
                    out[(lkey, mod, leaf)] = v
    return out


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m"])
def test_fused_shared_tap_solves_match_per_leaf(arch, monkeypatch):
    """Shared-tap fusion ([wq|wk|wv] on attn_in, [w_gate|w_up] on mlp_in /
    expert_in) must produce bit-identical QTensors to per-leaf solves —
    per-channel columns are independent given δ (paper eq. (3))."""
    from repro.core import pipeline
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    qp_fused, _ = quantize_model(params, cfg, PLAN, tokens, SPEC)
    monkeypatch.setattr(pipeline, "_fusable", lambda spec, method: False)
    qp_sep, _ = quantize_model(params, cfg, PLAN, tokens, SPEC)
    fused = _qtensor_leaves(qp_fused["__qlayers__"])
    sep = _qtensor_leaves(qp_sep["__qlayers__"])
    assert fused.keys() == sep.keys() and len(fused) > 0
    for key in fused:
        qf, qs = fused[key], sep[key]
        assert bool(jnp.all(qf["codes"] == qs["codes"])), key
        assert bool(jnp.all(qf["z_lo"] == qs["z_lo"])), key
        np.testing.assert_allclose(np.asarray(qf["scale"]),
                                   np.asarray(qs["scale"]), rtol=1e-6,
                                   err_msg=str(key))
        assert qf["shape"] == qs["shape"], key


def test_gram_computed_once_per_tap(monkeypatch):
    """The dense family has 7 mapped leaves but only 4 distinct taps per
    layer — the TapGramCache must issue exactly 4 Gram matmuls per layer."""
    from repro.core import calibrate
    calls = {"n": 0}
    orig = calibrate.gram_from_tap

    def counting(tap):
        calls["n"] += 1
        return orig(tap)

    monkeypatch.setattr(calibrate, "gram_from_tap", counting)
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    quantize_model(params, cfg, PLAN, tokens, SPEC)
    assert calls["n"] == 4 * cfg.n_layers, calls["n"]


def test_layer_report_seconds_reset_per_leaf():
    """Regression: dispatch time was measured from one t0 per *layer*,
    inflating later leaves cumulatively. Each leaf now reports its own
    dispatch time, so the per-layer sum must be far below n_leaves ×
    layer wall time. (`seconds` is the deprecated alias and must keep
    reading the dispatch field.)"""
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    import time as _time
    t0 = _time.time()
    _, report = quantize_model(params, cfg, PLAN, tokens, SPEC)
    wall = _time.time() - t0
    assert all(r.dispatch_seconds >= 0.0 for r in report.layers)
    # the cumulative-t0 bug multiple-counted solve time (leaf k charged the
    # sum of leaves 1..k), pushing the report total well past wall clock;
    # per-leaf timing keeps the total within the actual elapsed time
    total = sum(r.dispatch_seconds for r in report.layers)
    assert total <= wall + 1e-6, (total, wall)
    assert all(r.seconds == r.dispatch_seconds for r in report.layers)
    # no tracer: the walk stays sync-free, wall time is unmeasured
    assert all(r.wall_seconds == 0.0 for r in report.layers)


def test_layer_report_wall_seconds_with_tracer():
    """With a tracer the `leaf_solve` span blocks on the solved codes, so
    every leaf reports a real wall time ≥ its dispatch time, and their
    total stays within the end-to-end run wall."""
    from repro.obs import Tracer
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    tr = Tracer(run="wall-test")
    _, report = quantize_model(params, cfg, PLAN, tokens, SPEC, tracer=tr)
    assert report.layers
    assert all(r.wall_seconds > 0.0 for r in report.layers)
    assert all(r.wall_seconds + 1e-9 >= r.dispatch_seconds
               for r in report.layers)
    assert sum(r.wall_seconds for r in report.layers) \
        <= report.wall_seconds + 1e-6
    spans = [e for e in tr.events if e["name"] == "leaf_solve"]
    assert len(spans) > 0 and all(e["ph"] == "X" for e in spans)


def test_staged_runs_one_layer_forward_per_layer(monkeypatch):
    """The default (staged) schedule must evaluate layer_full exactly once
    per layer — the tap walk quantizes mid-forward and propagates in the
    same evaluation (the legacy schedule needed two)."""
    from repro.models import transformer as tfm
    calls = {"n": 0}
    orig = tfm.layer_full

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(tfm, "layer_full", counting)
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    quantize_model(params, cfg, PLAN, tokens, SPEC)
    assert calls["n"] == cfg.n_layers, calls["n"]


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m"])
def test_staged_err_not_worse_than_legacy(arch):
    """Staged propagation calibrates intra-layer taps on the *quantized*
    upstream sub-blocks, so per-leaf reconstruction error must not degrade
    vs the legacy two-forward schedule (and usually improves). MoE gets a
    wider band: the router re-routes on the quantized stream, so expert
    buffers differ structurally between schedules, not just numerically."""
    cfg = get_smoke_config(arch)
    moe = cfg.moe is not None
    leaf_tol, total_tol = (1.05, 1.01) if moe else (1.02, 1.001)
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    _, r_staged = quantize_model(params, cfg, PLAN, tokens, SPEC)
    _, r_legacy = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                 propagation="legacy")
    assert len(r_staged.layers) == len(r_legacy.layers) > 0
    for a, b in zip(r_staged.layers, r_legacy.layers):
        assert a.name == b.name and a.layer == b.layer
        # per-leaf: within bf16 propagation noise of the legacy error
        assert a.err_after <= b.err_after * leaf_tol, (a.name, a.err_after,
                                                       b.err_after)
    total_s = sum(r.err_after for r in r_staged.layers)
    total_l = sum(r.err_after for r in r_legacy.layers)
    assert total_s <= total_l * total_tol, (total_s, total_l)


def test_staged_vlm_pipeline():
    """Staged walk through the VLM group structure (self layers via
    layer_full callbacks, cross layers via cross_layer_full): same leaf
    inventory as legacy, COMQ still beats the RTN grid init."""
    cfg = get_smoke_config("llama-3.2-vision-90b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    ve = jax.random.normal(KEY, (2, cfg.cross_attn.n_vision_tokens,
                                 cfg.cross_attn.vision_dim), jnp.bfloat16)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="greedy")
    qs, rs = quantize_model(params, cfg, PLAN, tokens, spec,
                            vision_embeds=ve)
    _, rl = quantize_model(params, cfg, PLAN, tokens, spec,
                           vision_embeds=ve, propagation="legacy")
    assert [r.name for r in rs.layers] == [r.name for r in rl.layers]
    assert rs.total_improvement() > 0.05
    assert any(r.name.startswith("cross.") for r in rs.layers)
    assert len(qs["__qlayers__"]) > 0


def test_staged_rejects_unknown_propagation():
    cfg = get_smoke_config("qwen2-7b")
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    with pytest.raises(ValueError):
        quantize_model(params, cfg, PLAN, tokens, SPEC, propagation="eager")


def test_column_independence_enables_sharded_solve():
    """Per-channel COMQ on a column subset equals those columns of the full
    solve — the property that lets the launcher shard columns across the
    mesh with zero solve-time communication (DESIGN.md §4)."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (128, 48))
    w = jax.random.normal(k2, (48, 32)) * 0.1
    h = gram(x)
    full = comq_quantize_h(h, w, SPEC)
    half = comq_quantize_h(h, w[:, :16], SPEC)
    assert bool(jnp.all(full.q[:, :16] == half.q))
    np.testing.assert_allclose(np.asarray(full.delta[:16]),
                               np.asarray(half.delta), rtol=1e-6)
