"""Training integration: loss decreases on structured synthetic data,
microbatch-accumulation equivalence, optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.models import BuildPlan, init_params
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine)
from repro.train.train_step import init_train_state, make_train_step


def test_loss_decreases_end_to_end(tmp_path):
    """(deliverable b analogue, CPU-scale): train the reduced qwen2 on the
    structured synthetic stream; loss must drop well below the first step."""
    from repro.train.trainer import Trainer
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="qwen2-7b", ckpt_dir=str(tmp_path),
                        ckpt_every=100, total_steps=30, learning_rate=3e-3,
                        warmup_steps=5, async_ckpt=False)
    t = Trainer(cfg, plan, run_cfg)
    out = t.run_loop(total_steps=30, seq_len=64, global_batch=8)
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_microbatch_accumulation_equivalence():
    """nm=1 and nm=4 must produce (numerically) the same update."""
    cfg = get_smoke_config("mistral-large-123b")
    plan = BuildPlan(remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg, plan)
    acfg = AdamWConfig()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                     cfg.vocab_size),
    }
    outs = []
    for nm in (1, 4):
        run_cfg = RunConfig(arch="m", microbatches=nm, learning_rate=1e-3,
                            warmup_steps=1, total_steps=10)
        step = make_train_step(cfg, plan, run_cfg, acfg)
        state = init_train_state(params, acfg)
        new_state, metrics = jax.jit(step)(state, batch)
        outs.append((new_state, metrics))
    p1 = jax.tree_util.tree_leaves(outs[0][0]["params"])
    p2 = jax.tree_util.tree_leaves(outs[1][0]["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=2e-4)


def test_adamw_matches_reference_math():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    st = adamw_init(p, cfg)
    newp, st = adamw_update(g, st, p, cfg, jnp.float32(0.1))
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mh, vh = m / 0.1, v / 0.001
    want = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


@pytest.mark.parametrize("shape", [(64, 300), (7, 130)])
def test_int8_moments_track_f32(shape):
    """The int8 trajectory must track f32 within 10% of the max param
    change — the first-moment codec's 2-bit error-feedback residual keeps
    the EMA recursion from compounding rounding error (optim/adamw.py);
    both shapes are block-unaligned (300 and 130 pad to 512/256)."""
    k = jax.random.PRNGKey(3)
    p = {"w": jax.random.normal(k, shape)}
    cfg8 = AdamWConfig(moment_dtype="int8")
    cfg32 = AdamWConfig(moment_dtype="float32")
    s8, s32 = adamw_init(p, cfg8), adamw_init(p, cfg32)
    p8 = p32 = p
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.fold_in(k, i), shape)}
        p8, s8 = adamw_update(g, s8, p8, cfg8, jnp.float32(1e-2))
        p32, s32 = adamw_update(g, s32, p32, cfg32, jnp.float32(1e-2))
    diff = float(jnp.max(jnp.abs(p8["w"] - p32["w"])))
    scale = float(jnp.max(jnp.abs(p32["w"] - p["w"])))
    assert diff < 0.1 * scale, (diff, scale)


def test_int8_moment_memory_shrinks():
    p = {"w": jnp.zeros((256, 1024))}
    s8 = adamw_init(p, AdamWConfig(moment_dtype="int8"))
    s32 = adamw_init(p, AdamWConfig(moment_dtype="float32"))
    b8 = sum(l.size * l.dtype.itemsize
             for l in jax.tree_util.tree_leaves(s8))
    b32 = sum(l.size * l.dtype.itemsize
              for l in jax.tree_util.tree_leaves(s32))
    assert b8 < 0.3 * b32


def test_grad_clip_and_schedule():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(90.0), rtol=1e-5)
    cn = float(jnp.linalg.norm(clipped["a"]))
    assert abs(cn - 1.0) < 1e-5
    lrs = [float(warmup_cosine(s, base_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6 and lrs[3] < 0.2


def test_train_step_int8_ef_grad_compression():
    """RunConfig.grad_compression="int8_ef": the train step all-reduces
    gradients through compressed_psum under shard_map, with the carried
    residual threaded through the state by init_train_state. On a 1-shard
    axis the compressed step must match the uncompressed one to int8-EF
    rounding, and the error state must be populated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist import data_mesh

    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg, plan)
    acfg = AdamWConfig()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    rc = dict(arch="q", learning_rate=1e-3, warmup_steps=1, total_steps=10)
    run_c = RunConfig(**rc, grad_compression="int8_ef")
    run_n = RunConfig(**rc)

    with pytest.raises(ValueError, match="axis_name"):
        make_train_step(cfg, plan, run_c, acfg)

    step_c = make_train_step(cfg, plan, run_c, acfg, axis_name="data")
    step_n = make_train_step(cfg, plan, run_n, acfg)
    state_c = init_train_state(params, acfg, run_c)
    state_n = init_train_state(params, acfg, run_n)
    assert "grad_err" in state_c and "grad_err" not in state_n

    mesh = data_mesh(1)
    new_c, metrics_c = jax.jit(shard_map(
        step_c, mesh=mesh, in_specs=(P(), P("data")),
        out_specs=(P(), P()), check_rep=False))(state_c, batch)
    new_n, _ = jax.jit(step_n)(state_n, batch)

    # residual is populated (quantization error is carried, not dropped)
    errs = jax.tree_util.tree_leaves(new_c["grad_err"])
    assert any(float(jnp.max(jnp.abs(e))) > 0 for e in errs)
    # params match the uncompressed step to int8-EF rounding (scale/254
    # per grad leaf, one step at lr=1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(new_c["params"]),
                    jax.tree_util.tree_leaves(new_n["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    assert float(metrics_c["loss"]) > 0


def test_dryrun_opt_specs_cover_int8_moment_state():
    """_opt_specs must structurally match the int8 moment codec — incl.
    the packed "ef" residual on m (absent on v) and the int8_ef grad_err
    tree — or big-arch dryrun train cells fail to unflatten."""
    from jax.sharding import PartitionSpec as PS
    from repro.launch.dryrun import _opt_specs
    params = {"a": {"w": jnp.zeros((8, 512))}, "b": jnp.zeros((256,))}
    pspecs = {"a": {"w": PS(None, "data")}, "b": PS(None)}
    run_c = RunConfig(arch="x", grad_compression="int8_ef")
    state = jax.eval_shape(
        lambda p: init_train_state(p, AdamWConfig(moment_dtype="int8"),
                                   run_c), params)
    specs = _opt_specs(state, pspecs)
    mspec = specs["opt"]["m"]["a"]["w"]
    assert set(mspec) == {"q", "scale", "ef"}
    assert mspec["q"] == PS(None, "data")
    assert set(specs["opt"]["v"]["a"]["w"]) == {"q", "scale"}
    assert specs["grad_err"] == pspecs
    # every state leaf gets a spec (unflatten would throw otherwise)
    jax.tree_util.tree_map(lambda s, l: None, specs, state,
                           is_leaf=lambda x: isinstance(x, PS))


def test_trainer_runs_with_int8_ef(tmp_path):
    """End-to-end Trainer with grad_compression="int8_ef": the step runs
    under the 1-shard shard_map wrap, grad_err is threaded through the
    state (and checkpoints), and restoring a checkpoint written *without*
    the new optional state backfills it instead of erroring."""
    from repro.train.trainer import Trainer
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="qwen2-7b", ckpt_dir=str(tmp_path),
                        ckpt_every=2, total_steps=3, learning_rate=1e-3,
                        warmup_steps=1, async_ckpt=False,
                        grad_compression="int8_ef")
    t = Trainer(cfg, plan, run_cfg)
    out = t.run_loop(total_steps=3, seq_len=32, global_batch=4)
    assert out["final_step"] == 3
    assert "grad_err" in out["state"]
    errs = jax.tree_util.tree_leaves(out["state"]["grad_err"])
    assert any(float(jnp.max(jnp.abs(e))) > 0 for e in errs)

    # old-checkpoint compat: drop grad_err from the saved arrays and
    # restore into the new (grad_err-carrying) template
    import numpy as onp
    step_dir = t.ckpt.dir + "/step_2"
    data = dict(onp.load(step_dir + "/arrays.npz"))
    stripped = {k: v for k, v in data.items()
                if not k.startswith("grad_err")}
    onp.savez(step_dir + "/arrays.npz", **stripped)
    with pytest.warns(UserWarning, match="backfilling"):
        state, meta = t.ckpt.restore(2, t.init_state())
    assert meta["step"] == 2 and "grad_err" in state


def test_grad_compression_error_feedback():
    """compressed_psum on a 1-device 'mesh': mean == dequantized value and
    the residual carries the quantization error."""
    from repro.dist.collectives import compressed_psum, init_error_state
    import jax.experimental.shard_map as shard_map
    from repro.launch.mesh import make_smoke_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_smoke_mesh()
    g = {"w": jnp.asarray([[0.11, -0.52, 0.33]])}
    e = init_error_state(g)

    def f(gg, ee):
        return compressed_psum(gg, "data", ee, 1)

    out, new_e = shard_map.shard_map(
        f, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data")))(g, e)
    # int8 quantization error is bounded by scale/2 and kept in the state
    np.testing.assert_allclose(np.asarray(out["w"] + new_e["w"]),
                               np.asarray(g["w"]), atol=1e-6)
