"""Checkpointing: atomicity, async, GC, elastic restore, quantized format."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, pack_tree, tree_bytes, unpack_tree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(5, t, extra={"foo": 1})
    out, meta = mgr.restore(None, jax.tree_util.tree_map(jnp.zeros_like, t))
    assert meta["step"] == 5 and meta["extra"]["foo"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t, blocking=False)
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    # simulate a crash mid-write: step_2 exists without the sentinel
    os.makedirs(tmp_path / "step_2")
    (tmp_path / "step_2" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1


def test_elastic_restore_resharding(tmp_path):
    """Restore with explicit shardings (the elastic path: checkpoint saved
    under one topology restores onto another)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_smoke_mesh
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    mesh = make_smoke_mesh()
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t)
    out, _ = mgr.restore(1, t, shardings=shardings)
    for leaf in jax.tree_util.tree_leaves(out):
        assert isinstance(leaf, jax.Array)


def test_missing_leaf_errors(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        mgr.restore(1, {"a": jnp.zeros(3), "b": jnp.zeros(4)})


def test_quantized_pack_roundtrip():
    from repro.core.pipeline import make_qtensor
    q = jnp.asarray(np.random.RandomState(0).randint(-8, 8, (32, 64)))
    qt = make_qtensor(q, jnp.full((64,), 0.1), jnp.full((64,), -8,
                                                        jnp.int32),
                      (32, 64), bits=4)
    packed = pack_tree({"w": qt})
    assert packed["w"].get("packed4")
    assert packed["w"].get("packed_cpb") == 2
    assert tree_bytes(packed) < tree_bytes({"w": qt})
    restored = unpack_tree(packed)
    np.testing.assert_array_equal(np.asarray(restored["w"]["codes"]),
                                  np.asarray(qt["codes"]))


def test_quantized_pack_dispatches_on_bits_not_values():
    """Regression: pack_tree probed `max(codes) < 16` — an 8-bit solve
    whose codes landed below 16 was silently nibble-packed (and paid a
    host sync per leaf). The recorded bit width now decides: 8-bit stays
    one code per byte even for tiny code values, 2-bit packs 4/byte."""
    from repro.core.pipeline import make_qtensor
    q8 = jnp.asarray(np.random.RandomState(1).randint(0, 12, (16, 32)))
    z = jnp.zeros((32,), jnp.int32)
    qt8 = make_qtensor(q8, jnp.full((32,), 0.1), z, (16, 32), bits=8)
    p8 = pack_tree({"w": qt8})
    assert "packed_cpb" not in p8["w"] and not p8["w"].get("packed4")
    assert p8["w"]["codes"].shape == (16, 32)

    q2 = jnp.asarray(np.random.RandomState(2).randint(0, 4, (16, 32)))
    qt2 = make_qtensor(q2, jnp.full((32,), 0.1), z, (16, 32), bits=2)
    p2 = pack_tree({"w": qt2})
    assert p2["w"]["packed_cpb"] == 4
    assert p2["w"]["codes"].shape == (16, 8)
    restored = unpack_tree(p2)
    np.testing.assert_array_equal(np.asarray(restored["w"]["codes"]),
                                  np.asarray(qt2["codes"]))


def test_pre_policy_checkpoint_backfills_bits():
    """A pre-PR5 packed tree (no 'bits' key, 'packed4' flag) must unpack
    to a QTensor whose backfilled width keeps the nibble density on
    re-pack — not fall to the 8-bit one-per-byte default."""
    from repro.core.pipeline import qtensor_bits
    from repro.core.quantizer import pack_int4
    u = jnp.asarray(np.random.RandomState(3).randint(0, 16, (8, 32)),
                    jnp.uint8)
    legacy = {"__qtensor__": True, "codes": pack_int4(u),
              "scale": jnp.full((32,), 0.1), "z_lo": jnp.zeros((32,),
                                                              jnp.int32),
              "shape": (8, 32), "packed4": True, "unpacked_last": 32}
    restored = unpack_tree({"w": legacy})
    assert qtensor_bits(restored["w"]) == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]["codes"]),
                                  np.asarray(u))
    repacked = pack_tree(restored)
    assert repacked["w"]["packed_cpb"] == 2     # density preserved
    # unpacked legacy leaves (never nibble-packed) stay 8-bit
    legacy8 = {"__qtensor__": True, "codes": u, "scale": legacy["scale"],
               "z_lo": legacy["z_lo"], "shape": (8, 32)}
    assert qtensor_bits(unpack_tree({"w": legacy8})["w"]) == 8
