"""COMQ solver correctness: X-space (paper-faithful) vs H-space vs blocked
equivalence, greedy-vs-cyclic advantage, baseline ordering, Tab.7 K-sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantSpec, comq_quantize, comq_quantize_blocked,
                        comq_quantize_h, gptq_quantize, gram, rtn_quantize)
from repro.core.comq_hessian import _h_error


def _problem(seed=0, n_samples=256, m=96, n=48, scale=0.05):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n_samples, m)) * (1.0 + jnp.arange(m) / m)
    w = jax.random.normal(k2, (m, n)) * scale
    return x, w


@pytest.mark.parametrize("gran", ["per_layer", "per_channel"])
@pytest.mark.parametrize("order", ["cyclic", "greedy", "greedy_shared"])
def test_x_space_equals_h_space(gran, order):
    x, w = _problem()
    spec = QuantSpec(bits=4, granularity=gran, lam=0.9, sweeps=3, order=order)
    rx = comq_quantize(x, w, spec)
    rh = comq_quantize_h(gram(x), w, spec)
    assert bool(jnp.all(rx.q == rh.q)), "bit-codes diverge between solvers"
    np.testing.assert_allclose(np.asarray(rx.delta), np.asarray(rh.delta),
                               rtol=2e-5, atol=1e-7)


@pytest.mark.parametrize("gran", ["per_layer", "per_channel"])
@pytest.mark.parametrize("order", ["cyclic", "greedy_shared"])
@pytest.mark.parametrize("block", [16, 32, 96])
def test_blocked_equals_row_at_a_time(gran, order, block):
    x, w = _problem()
    h = gram(x)
    spec = QuantSpec(bits=4, granularity=gran, lam=0.9, sweeps=2, order=order)
    rh = comq_quantize_h(h, w, spec)
    rb = comq_quantize_blocked(h, w, spec, block=block)
    assert bool(jnp.all(rh.q == rb.q))


def test_blocked_with_pallas_panel_kernel():
    from repro.kernels.comq_panel import panel_fn_interpret
    x, w = _problem(m=64, n=32)
    h = gram(x)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="cyclic")
    ref = comq_quantize_blocked(h, w, spec, block=32)
    ker = comq_quantize_blocked(h, w, spec, block=32,
                                panel_fn=panel_fn_interpret)
    assert bool(jnp.all(ref.q == ker.q))


def test_blocked_with_fused_dq_pallas_kernel():
    """The fused kernel emits (qf', ΔW); the trailing update consumes ΔW
    directly — codes must still match the row-at-a-time solver."""
    from repro.kernels.comq_panel import panel_fn_dq_interpret
    x, w = _problem(m=64, n=32)
    h = gram(x)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=2,
                     order="greedy_shared")
    rh = comq_quantize_h(h, w, spec)
    ker = comq_quantize_blocked(h, w, spec, block=32,
                                panel_fn=panel_fn_dq_interpret)
    assert bool(jnp.all(rh.q == ker.q))


@pytest.mark.parametrize("gran", ["per_layer", "per_channel"])
@pytest.mark.parametrize("order", ["cyclic", "greedy_shared"])
def test_trailing_blocked_padded_rows(gran, order):
    """Bit-identity regression for the trailing-update schedule when m is
    not divisible by the panel size (96 -> padded to 128 at block=64)."""
    x, w = _problem()
    h = gram(x)
    spec = QuantSpec(bits=4, granularity=gran, lam=0.9, sweeps=3, order=order)
    rh = comq_quantize_h(h, w, spec)
    rb = comq_quantize_blocked(h, w, spec, block=64)
    assert bool(jnp.all(rh.q == rb.q))
    np.testing.assert_allclose(np.asarray(rh.delta), np.asarray(rb.delta),
                               rtol=2e-5, atol=1e-7)


@pytest.mark.parametrize("gran", ["per_layer", "per_channel"])
def test_trailing_equals_refresh_schedule(gran):
    """The maintained-P trailing schedule and the legacy per-panel-refresh
    schedule are the same math — identical codes and error trajectories."""
    x, w = _problem()
    h = gram(x)
    spec = QuantSpec(bits=4, granularity=gran, lam=0.9, sweeps=3,
                     order="greedy_shared")
    rt = comq_quantize_blocked(h, w, spec, block=32)
    rr = comq_quantize_blocked(h, w, spec, block=32, schedule="refresh")
    assert bool(jnp.all(rt.q == rr.q))
    np.testing.assert_allclose(np.asarray(rt.errors), np.asarray(rr.errors),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_monotone_descent(bits):
    """Coordinate descent never increases the objective after the first
    (projection) sweep — each univariate step is an exact argmin (paper §3)."""
    x, w = _problem(seed=bits)
    spec = QuantSpec(bits=bits, granularity="per_channel", lam=0.9, sweeps=5,
                     order="greedy")
    r = comq_quantize(x, w, spec)
    errs = np.asarray(r.errors)[1:]      # post-projection trajectory
    assert np.all(np.diff(errs) <= errs[0] * 1e-4 + 1e-6), errs


def test_greedy_beats_cyclic():
    """Paper Tab. 8 / Fig. 3: greedy order reduces the layer-wise error."""
    wins = 0
    for seed in range(5):
        x, w = _problem(seed=seed)
        eg = float(comq_quantize(
            x, w, QuantSpec(bits=3, granularity="per_channel", lam=0.9,
                            sweeps=3, order="greedy")).errors[-1])
        ec = float(comq_quantize(
            x, w, QuantSpec(bits=3, granularity="per_channel", lam=0.9,
                            sweeps=3, order="cyclic")).errors[-1])
        wins += eg <= ec * 1.005
    assert wins >= 4, f"greedy won only {wins}/5 runs"


def test_comq_beats_rtn_and_competitive_with_gptq():
    x, w = _problem()
    h = gram(x)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=3,
                     order="greedy")

    def err(r):
        return float(_h_error(h, w, r.q.astype(jnp.float32) * r.delta))

    e_rtn = err(rtn_quantize(w, spec, h=h))
    e_gptq = err(gptq_quantize(h, w, spec))
    e_comq = err(comq_quantize_h(h, w, spec))
    assert e_comq < e_rtn, (e_comq, e_rtn)
    assert e_comq < e_gptq * 1.05, (e_comq, e_gptq)


def test_more_sweeps_saturate():
    """Paper Tab. 7: K=3-4 is enough; further sweeps don't help much."""
    x, w = _problem()
    errs = []
    for k in (1, 3, 6):
        spec = QuantSpec(bits=4, granularity="per_layer", sweeps=k,
                         order="greedy")
        errs.append(float(comq_quantize(x, w, spec).errors[-1]))
    assert errs[1] <= errs[0] * 1.001
    assert abs(errs[2] - errs[1]) < 0.05 * errs[1] + 1e-6


def test_codes_within_range():
    x, w = _problem()
    for bits in (2, 4, 8):
        spec = QuantSpec(bits=bits, granularity="per_channel", lam=0.8,
                         sweeps=2, order="greedy")
        r = comq_quantize(x, w, spec)
        assert bool(jnp.all(r.q >= r.z_lo[None, :]))
        assert bool(jnp.all(r.q <= r.z_hi[None, :]))
        assert int(r.z_hi[0] - r.z_lo[0]) == 2 ** bits - 1
