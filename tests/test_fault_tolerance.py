"""Fault tolerance: injected failures + restart, straggler detection,
heartbeats/recovery planning, exact-resume semantics."""
import os
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.ft import Heartbeat, Watchdog, plan_recovery, run_with_restarts
from repro.models import BuildPlan
from repro.train.trainer import Trainer


def test_watchdog_flags_stragglers():
    wd = Watchdog(straggler_factor=3.0, warmup_steps=1)
    for i in range(6):
        wd.step_start()
        time.sleep(0.001)
        wd.step_end(i)
    wd.step_start()
    time.sleep(0.05)
    ev = wd.step_end(99)
    assert ev is not None and ev.step == 99


def test_heartbeat_and_recovery_plan(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0)
    hb1 = Heartbeat(str(tmp_path), 1)
    hb0.beat(10)
    hb1.beat(10)
    plan = plan_recovery(str(tmp_path), expected_hosts=4,
                         latest_ckpt_step=10, dead_after_s=60)
    assert plan.healthy_hosts == [0, 1]
    assert plan.lost_hosts == [2, 3]
    assert plan.resume_step == 10


def test_train_crash_restart_resumes(tmp_path):
    """Kill the trainer mid-run; the restart must resume from the last
    committed checkpoint and finish, with a contiguous loss history."""
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="qwen2-7b", ckpt_dir=str(tmp_path),
                        ckpt_every=5, total_steps=12, async_ckpt=False,
                        learning_rate=1e-3, warmup_steps=2)
    crashed = {"done": False}

    def bomb(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    def attempt(resume_step):
        t = Trainer(cfg, plan, run_cfg, failure_hook=bomb)
        out = t.run_loop(total_steps=12, seq_len=32, global_batch=4)
        return out["final_step"]

    def latest():
        from repro.ckpt import CheckpointManager
        return CheckpointManager(str(tmp_path)).latest_step()

    final = run_with_restarts(attempt, latest, max_restarts=2)
    assert final == 12
    assert crashed["done"]
    assert latest() == 12


def test_restart_budget_exhausted():
    calls = {"n": 0}

    def attempt(_):
        calls["n"] += 1
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_with_restarts(attempt, lambda: None, max_restarts=2)
    assert calls["n"] == 3


def test_restart_only_listed_exceptions():
    """Exception types outside the configured tuple propagate immediately
    — a KeyboardInterrupt or assertion must never be retried."""
    calls = {"n": 0}

    def attempt(_):
        calls["n"] += 1
        raise ValueError("not retryable here")

    with pytest.raises(ValueError):
        run_with_restarts(attempt, lambda: None, max_restarts=5,
                          exceptions=(RuntimeError,))
    assert calls["n"] == 1
    calls["n"] = 0
    with pytest.raises(ValueError):
        run_with_restarts(attempt, lambda: None, max_restarts=2,
                          exceptions=(RuntimeError, ValueError))
    assert calls["n"] == 3          # now it is retryable


def test_restart_budget_resets_on_progress():
    """max_restarts bounds *consecutive no-progress* crashes: a job that
    keeps advancing its checkpoint survives arbitrarily many failures."""
    state = {"calls": 0, "step": 0}

    def attempt(_):
        state["calls"] += 1
        state["step"] += 1          # every attempt commits progress
        if state["calls"] < 7:
            raise RuntimeError("crash after progress")
        return state["step"]

    assert run_with_restarts(attempt, lambda: state["step"],
                             max_restarts=1) == 7
    assert state["calls"] == 7      # 6 crashes survived with budget 1


def test_restart_backoff_capped_exponential():
    sleeps = []

    def attempt(_):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(attempt, lambda: None, max_restarts=4,
                          backoff_s=1.0, backoff_cap_s=4.0,
                          sleep_fn=sleeps.append)
    assert sleeps == [1.0, 2.0, 4.0, 4.0]


def test_heartbeat_atomic_publish(tmp_path, monkeypatch):
    """A crash mid-beat must leave the previous heartbeat intact: the
    write goes to a temp file and renames over the live path. Regression
    for the direct-truncating-open beat, where a reader (or a crash)
    between open and write observed an empty/torn file and the host was
    misread as dead."""
    import json as _json

    hb = Heartbeat(str(tmp_path), 0)
    hb.beat(10)

    real_dump = _json.dump

    def exploding_dump(obj, f, **kw):
        f.write('{"step": 11, "ti')       # partial bytes, then the crash
        raise OSError("disk full mid-write")

    monkeypatch.setattr(_json, "dump", exploding_dump)
    with pytest.raises(OSError):
        hb.beat(11)
    monkeypatch.setattr(_json, "dump", real_dump)

    # the published file still holds the *complete* previous beat and the
    # host is still considered alive; no temp debris accumulates
    with open(hb.path) as f:
        assert _json.load(f)["step"] == 10
    assert 0 in Heartbeat.alive_hosts(str(tmp_path), dead_after_s=60)
    assert os.listdir(str(tmp_path)) == ["heartbeat_0"]


def test_heartbeat_reader_never_sees_torn_json(tmp_path):
    """alive_hosts during concurrent beats: every read parses (rename is
    atomic), so a beating host can never be misclassified as dead."""
    hb = Heartbeat(str(tmp_path), 3)
    for step in range(50):
        hb.beat(step)
        alive = Heartbeat.alive_hosts(str(tmp_path), dead_after_s=60)
        assert 3 in alive and alive[3]["step"] == step
