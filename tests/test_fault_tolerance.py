"""Fault tolerance: injected failures + restart, straggler detection,
heartbeats/recovery planning, exact-resume semantics."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.ft import Heartbeat, Watchdog, plan_recovery, run_with_restarts
from repro.models import BuildPlan
from repro.train.trainer import Trainer


def test_watchdog_flags_stragglers():
    wd = Watchdog(straggler_factor=3.0, warmup_steps=1)
    for i in range(6):
        wd.step_start()
        time.sleep(0.001)
        wd.step_end(i)
    wd.step_start()
    time.sleep(0.05)
    ev = wd.step_end(99)
    assert ev is not None and ev.step == 99


def test_heartbeat_and_recovery_plan(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0)
    hb1 = Heartbeat(str(tmp_path), 1)
    hb0.beat(10)
    hb1.beat(10)
    plan = plan_recovery(str(tmp_path), expected_hosts=4,
                         latest_ckpt_step=10, dead_after_s=60)
    assert plan.healthy_hosts == [0, 1]
    assert plan.lost_hosts == [2, 3]
    assert plan.resume_step == 10


def test_train_crash_restart_resumes(tmp_path):
    """Kill the trainer mid-run; the restart must resume from the last
    committed checkpoint and finish, with a contiguous loss history."""
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    run_cfg = RunConfig(arch="qwen2-7b", ckpt_dir=str(tmp_path),
                        ckpt_every=5, total_steps=12, async_ckpt=False,
                        learning_rate=1e-3, warmup_steps=2)
    crashed = {"done": False}

    def bomb(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    def attempt(resume_step):
        t = Trainer(cfg, plan, run_cfg, failure_hook=bomb)
        out = t.run_loop(total_steps=12, seq_len=32, global_batch=4)
        return out["final_step"]

    def latest():
        from repro.ckpt import CheckpointManager
        return CheckpointManager(str(tmp_path)).latest_step()

    final = run_with_restarts(attempt, latest, max_restarts=2)
    assert final == 12
    assert crashed["done"]
    assert latest() == 12


def test_restart_budget_exhausted():
    calls = {"n": 0}

    def attempt(_):
        calls["n"] += 1
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_with_restarts(attempt, lambda: None, max_restarts=2)
    assert calls["n"] == 3
